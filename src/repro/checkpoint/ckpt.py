"""Aquifer-backed checkpointing: TrainState / serving state ⇄ paged snapshots.

This is where the paper becomes a first-class framework feature:

* **save** — flatten the state pytree into named arrays, build a
  ``StateImage``, zero-detect (optimizer moments are predominantly zero
  early in training; KV arenas and workspaces are zero at snapshot time),
  profile hotness, and publish to the two-tier pool through the pool master
  (ownership protocol, §3.3).
* **restore** — borrow + clflush + pre-install the hot set (params), then
  demand-page the cold set (optimizer moments / rare vocab rows) — compute
  can resume on the hot set before the RDMA tier finishes (§3.4).
* **elastic restore** — pages are location-independent (offset-array
  indirection), so the restored arrays can be device_put onto a *different*
  mesh than the one that saved them.

Hotness defaults for training state: params hot, Adam moments cold.
Serving-state hotness comes from the offline profiler (core/profiler.py).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    Manifest,
    Orchestrator,
    PoolMaster,
    StateImage,
)
from ..core.profiler import AccessRecorder


# --------------------------------------------------------------------------
# pytree <-> named arrays
# --------------------------------------------------------------------------

def flatten_state(tree) -> Dict[str, np.ndarray]:
    flat: Dict[str, np.ndarray] = {}

    def walk(path, leaf):
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        flat[name] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(walk, tree)
    return flat


def unflatten_state(template, arrays: Dict[str, np.ndarray]):
    names: List[str] = []

    def collect(path, leaf):
        names.append("/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        ))
        return leaf

    jax.tree_util.tree_map_with_path(collect, template)
    leaves, treedef = jax.tree.flatten(template)
    new_leaves = []
    for name, leaf in zip(names, leaves):
        arr = arrays[name]
        new_leaves.append(jnp.asarray(arr.reshape(np.shape(leaf))))
    return jax.tree.unflatten(treedef, new_leaves)


# --------------------------------------------------------------------------
# save / restore
# --------------------------------------------------------------------------

def default_train_hotness(manifest: Manifest) -> np.ndarray:
    """Params hot; Adam moments (opt/m, opt/v) cold; step counter hot."""
    rec = AccessRecorder(manifest)
    for e in manifest.extents:
        if not ("/m/" in f"/{e.name}/" or "/v/" in f"/{e.name}/"
                or e.name.startswith(("opt/m", "opt/v", "1/m", "1/v"))):
            rec.touch_array(e.name)
    return rec.working_set()


def save_checkpoint(
    master: PoolMaster,
    name: str,
    state,
    step: int,
    working_set: Optional[Sequence[int]] = None,
    metadata: Optional[dict] = None,
) -> Tuple[StateImage, dict]:
    """Publish `state` as snapshot `name`. Returns (image, stats)."""
    arrays = flatten_state(state)
    image = StateImage.build(arrays)
    if working_set is None:
        working_set = default_train_hotness(image.manifest)
    meta = {"step": step, **(metadata or {})}
    t0 = time.perf_counter()
    regions = master.publish(name, image, working_set, metadata=meta)
    stats = {
        "publish_s": time.perf_counter() - t0,
        "total_pages": regions.total_pages,
        "zero": regions.n_zero,
        "hot": regions.n_hot,
        "cold": regions.n_cold,
        "cxl_bytes": regions.cxl_size,
        "rdma_bytes": regions.rdma_size,
    }
    return image, stats


def restore_checkpoint(
    orch: Orchestrator,
    name: str,
    template,
) -> Tuple[Any, dict]:
    """Borrow + restore `name`; returns (state, stats).

    The hot set (params) is pre-installed from the CXL tier; cold pages
    (optimizer moments) are demand-paged from the RDMA tier — we record the
    time-to-hot separately from time-to-full, which is the paper's headline
    effect (resume before the slow tier finishes).
    """
    t0 = time.perf_counter()
    ri = orch.restore(name)
    if ri is None:
        raise FileNotFoundError(f"no published snapshot named {name!r}")
    t_hot = time.perf_counter() - t0

    # demand-page everything else (async RDMA engine fills; we touch to force)
    for page in range(ri.instance.image.total_pages):
        if not ri.instance.present[page]:
            ri.engine.access(page)
    t_full = time.perf_counter() - t0

    manifest, meta = ri.engine.reader.machine_state()
    arrays = {e.name: ri.instance.image.read_array(e.name) for e in manifest.extents}
    state = unflatten_state(template, arrays)
    stats = {
        "time_to_hot_s": t_hot,
        "time_to_full_s": t_full,
        "modeled": dict(ri.ledger.seconds),
        "instance": dict(ri.instance.stats),
        "meta": meta,
    }
    ri.shutdown()
    return state, stats


def reshard(state, mesh, spec_tree):
    """Elastic restore: place a host-resident state onto a (new) mesh."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, state, spec_tree)
