"""Inter-pod data-plane routing: priced reads of a remote pod's tiers.

A host whose home pod holds no replica (or whose MHD ports are exhausted)
reaches a remote pod over the RDMA fabric plus one switch hop.  The price
goes through the same machinery as intra-pod reads: a per-(host, remote
pod) :class:`~repro.core.pool.LinkArbiter` over an inter-pod
:class:`~repro.core.pool.CostModel` built from the
``strategies.INTER_POD_*`` constants, so the executed path and the
analytic model (``strategies.interpod_bulk_read_s``) share one set of
numbers.

Partitions are data-plane only: a downed link refuses bulk reads
(:class:`PodLinkDown`) while the control plane — catalog atomics, lease
words — keeps working, matching a fabric cut that spares the management
network.
"""
from __future__ import annotations

import threading
from typing import Dict, Tuple

import numpy as np

from ..core.pool import CostModel, LinkArbiter
from ..serve.strategies import (
    INTER_POD_BW,
    INTER_POD_INFLIGHT,
    INTER_POD_LAT_S,
)

#: The inter-pod fabric: RNIC path plus one switch hop (DESIGN.md §16).
INTER_POD_COST = CostModel(op_latency_s=INTER_POD_LAT_S,
                           bandwidth_Bps=INTER_POD_BW,
                           max_inflight=INTER_POD_INFLIGHT)


class PodLinkDown(RuntimeError):
    """The data-plane link between two pods is partitioned."""


class InterPodRouter:
    """Routes and prices one host's bulk reads of remote pods' tiers."""

    def __init__(self, group):
        self.group = group
        self._lock = threading.Lock()
        self._arbiters: Dict[Tuple[str, int], LinkArbiter] = {}
        self.stats = {"interpod_reads": 0, "interpod_bytes": 0,
                      "partition_refusals": 0}

    def arbiter_for(self, host: str, dst_pod: int) -> LinkArbiter:
        """The contention arbiter for `host`'s fabric path to `dst_pod`
        (distinct remote pods ride distinct switch paths; streams from one
        host to one pod share)."""
        with self._lock:
            key = (host, dst_pod)
            arb = self._arbiters.get(key)
            if arb is None:
                arb = self._arbiters[key] = LinkArbiter(INTER_POD_COST)
            return arb

    def check_reachable(self, host: str, dst_pod: int) -> None:
        """Raise :class:`PodLinkDown` when the data-plane path from
        `host`'s home pod to `dst_pod` is partitioned or the pod is dead."""
        home = self.group.home_pod(host)
        if not self.group.link_up(home, dst_pod):
            with self._lock:
                self.stats["partition_refusals"] += 1
            raise PodLinkDown(
                f"pod link {home} -> {dst_pod} is down (host {host!r})")

    def charge_read(self, host: str, dst_pod: int, nbytes: int,
                    ops: int = 1) -> float:
        """Modeled seconds for `host` reading `nbytes` from `dst_pod` over
        the inter-pod fabric (pipelined one-sided reads, fair-shared with
        the host's other active inter-pod streams)."""
        self.check_reachable(host, dst_pod)
        t = self.arbiter_for(host, dst_pod).charge_pipelined(nbytes, ops)
        with self._lock:
            self.stats["interpod_reads"] += 1
            self.stats["interpod_bytes"] += int(nbytes)
        return t

    def read(self, host: str, dst_pod: int, tier_tag: int, offset: int,
             nbytes: int) -> Tuple[np.ndarray, float]:
        """Real bytes from the remote pod's tier + the modeled charge."""
        self.check_reachable(host, dst_pod)
        data = self.group.pod(dst_pod).pool.tier(tier_tag).read(offset, nbytes)
        return data, self.charge_read(host, dst_pod, nbytes)
