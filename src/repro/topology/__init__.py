"""Multi-pod topology: port-limited pods, replication, routing, migration.

What this package owns (DESIGN.md §16, docs/ARCHITECTURE.md):

* the **pod layer** — :class:`PodGroup` of per-pod pool/catalog/master
  triples, each with its own CXL budget and an MHD :class:`PortLimiter`
  on concurrent host attach (Octopus-style sparse pods);
* the **inter-pod data plane** — :class:`InterPodRouter` pricing remote
  reads through ``LinkArbiter`` over the ``strategies.INTER_POD_*`` cost
  model, with data-plane-only partitions (:class:`PodLinkDown`);
* the **replication layer** — :class:`ReplicaManager`, the cluster-level
  single writer (invariant I8) driving per-pod owner protocols in
  lockstep so replicas stay version- and bit-coherent (invariant I7);
* **migration** — :class:`MigrationManager`, break-even-gated replica
  movement toward demand via ``strategies.migration_economics``.

Coherence obligations: all group writes go through ``ReplicaManager``
(publishing a managed name directly on a pod master bypasses I8 and the
sim checker flags it); every replica mutation drains that pod's borrows
through the unchanged per-pod ownership protocol.
"""
from .migration import MigrationManager
from .pod import Pod, PodGroup, PortLimiter, UNLIMITED_PORTS
from .replication import ReplicaManager, split_pod_label
from .router import INTER_POD_COST, InterPodRouter, PodLinkDown

__all__ = [
    "INTER_POD_COST",
    "InterPodRouter",
    "MigrationManager",
    "Pod",
    "PodGroup",
    "PodLinkDown",
    "PortLimiter",
    "ReplicaManager",
    "UNLIMITED_PORTS",
    "split_pod_label",
]
