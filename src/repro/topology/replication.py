"""k-replica snapshot publication across pods under the ownership protocol.

The :class:`ReplicaManager` is the cluster-level writer over a
:class:`~repro.topology.pod.PodGroup`.  It adds exactly two obligations on
top of the per-pod protocol (I1–I6 unchanged inside each pod):

I7  **replica coherence** — every PUBLISHED replica of a ``name`` is at
    one version, and replicas of ``(name, version)`` are bit-identical.
    Enforced by construction: the manager assigns ONE group-level version
    per write (passed to every pod master via the ``version=`` override)
    and drives the per-pod ``publish_steps`` generators in *lockstep* —
    every pod is held at its pre-republish barrier (``built_new`` /
    ``rebuilt``, i.e. after its own tombstone → drain → rebuild) before
    any pod republishes.  At no step are replicas of two different
    versions simultaneously borrowable.  Updates and deletes drain every
    replica through each pod's own tombstone/drain window.

I8  **single writer across pods** — at most one in-flight group write per
    name, tracked in ``_writers``; any pod master busy on a managed name
    without the group writer lock is a protocol bypass (the sim's
    ``check_single_writer`` catches it).

Reads are routed by :meth:`borrow_route`: home-pod CXL when an MHD port
grants, else inter-pod RDMA to the least-served reachable replica
(load-balancing), falling back to a cold start when every replica is
partitioned away or dead.  Replica demand is tracked per home pod — the
signal :class:`~repro.topology.migration.MigrationManager` rebalances on.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from ..core.snapshot import reconstruct_image
from .pod import PodGroup
from .router import InterPodRouter

#: Per-pod publish labels are namespaced ``pod<i>:<label>``.
def split_pod_label(label: str) -> Tuple[Optional[int], str]:
    """``"pod3:draining"`` → ``(3, "draining")``; plain labels → ``(None,
    label)`` — the sim wrapper's parse of namespaced generator yields."""
    if label.startswith("pod") and ":" in label:
        head, base = label.split(":", 1)
        try:
            return int(head[3:]), base
        except ValueError:
            return None, label
    return None, label


class ReplicaManager:
    """Cluster-level replicated writes + routed reads over a pod group."""

    def __init__(self, group: PodGroup, router: Optional[InterPodRouter] = None):
        self.group = group
        self.router = router or InterPodRouter(group)
        self._lock = threading.Lock()
        self._writers: Dict[str, object] = {}      # name -> writer token (I8)
        self._versions: Dict[str, int] = {}        # group-level version counter
        self._replicas: Dict[str, Dict[int, int]] = {}   # name -> {pod: version}
        self._working_sets: Dict[str, List[int]] = {}
        self.demand: Dict[str, Dict[int, int]] = {}      # name -> {home_pod: n}
        self.served: Dict[str, Dict[int, int]] = {}      # name -> {pod: reads}
        self.stats = {"group_publishes": 0, "group_deletes": 0,
                      "replicas_added": 0, "replicas_dropped": 0,
                      "port_fallthrough": 0, "promotions": 0,
                      "routed_local": 0, "routed_interpod": 0,
                      "routed_none": 0}

    # -- introspection (the I7/I8 checkers read these) ---------------------
    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._replicas)

    def replica_pods(self, name: str) -> List[int]:
        with self._lock:
            return sorted(self._replicas.get(name, {}))

    def version_of(self, name: str) -> Optional[int]:
        with self._lock:
            v = self._versions.get(name)
        return v

    def holds_writer(self, name: str) -> bool:
        with self._lock:
            return name in self._writers

    # -- the group writer lock (I8) ----------------------------------------
    def _claim_writer(self, name: str) -> Iterator[Tuple[str, str]]:
        """Spin for the group writer lock, yielding ``("group_busy",
        name)`` per failed poll; returns the token via StopIteration."""
        token = object()
        while True:
            with self._lock:
                if name not in self._writers:
                    self._writers[name] = token
                    return token
            yield ("group_busy", name)

    def _release_writer(self, name: str, token: object) -> None:
        with self._lock:
            if self._writers.get(name) is token:
                del self._writers[name]

    # -- replicated publish / update (I7 lockstep) -------------------------
    def publish_steps(self, name: str, image, working_set: Sequence[int],
                      pods: Optional[Sequence[int]] = None,
                      dedup: Optional[bool] = None,
                      **kw) -> Iterator[Tuple[str, object]]:
        """Publish (or update) ``name`` on every target pod at ONE group
        version, yielding each pod's protocol phases as ``pod<i>:<label>``.

        Phase A drives every pod to its pre-republish barrier — for an
        update that means the pod has tombstoned, drained ITS replica's
        borrows, freed the old bytes, and rebuilt; yields ``("barrier",
        version)`` once all pods are held there.  Phase B then republishes
        pod by pod.  Because every replica tombstones before any
        republishes, the set of PUBLISHED replica versions is always a
        subset of {old} before the barrier and {new} after it — never
        mixed (I7).  Terminal: ``("done", {pod: regions})``.
        """
        token = yield from self._claim_writer(name)
        try:
            with self._lock:
                targets = (sorted(pods) if pods is not None
                           else sorted(self._replicas.get(name, {})) or [0])
                version = self._versions.get(name, -1) + 1
                self._versions[name] = version
                self._working_sets[name] = list(working_set)
            held = []
            for pid in targets:
                gen = self.group.pod(pid).master.publish_steps(
                    name, image, working_set, version=version, dedup=dedup,
                    **kw)
                for label, val in gen:
                    yield (f"pod{pid}:{label}", val)
                    if label in ("built_new", "rebuilt"):
                        break
                held.append((pid, gen))
            yield ("barrier", version)
            done: Dict[int, object] = {}
            for pid, gen in held:
                for label, val in gen:
                    yield (f"pod{pid}:{label}", val)
                    if label == "done":
                        done[pid] = val
            with self._lock:
                self._replicas[name] = {pid: version for pid in done}
                self.stats["group_publishes"] += 1
        finally:
            self._release_writer(name, token)
        yield ("done", done)

    # -- replicated delete (drains every replica) --------------------------
    def delete_steps(self, name: str,
                     gc_polls: int = 64) -> Iterator[Tuple[str, object]]:
        """Tombstone every replica first (no new borrows anywhere), then
        drain/GC each pod; yields ``pod<i>:gc_pending`` while a replica's
        borrows are still live.  Terminal: ``("done", name)``."""
        token = yield from self._claim_writer(name)
        try:
            with self._lock:
                targets = sorted(self._replicas.get(name, {}))
            if not targets:
                yield ("missing", name)
                return
            for pid in targets:
                m = self.group.pod(pid).master
                if m.delete(name, gc_now=False):
                    yield (f"pod{pid}:tombstoned", name)
                else:
                    yield (f"pod{pid}:missing", name)
            for pid in targets:
                m = self.group.pod(pid).master
                for _ in range(gc_polls):
                    if m.gc() or not m._pending_reclaim:
                        break
                    yield (f"pod{pid}:gc_pending", name)
                yield (f"pod{pid}:gc_done", name)
            with self._lock:
                self._replicas.pop(name, None)
                self.demand.pop(name, None)
                self.served.pop(name, None)
                self.stats["group_deletes"] += 1
        finally:
            self._release_writer(name, token)
        yield ("done", name)

    # -- replica-set changes (migration, promotion repair) -----------------
    def add_replica_steps(self, name: str, dst_pod: int,
                          dedup: Optional[bool] = None) -> Iterator[Tuple[str, object]]:
        """Materialize one more replica of ``name`` on ``dst_pod`` at the
        CURRENT group version: reconstruct the image from a reachable
        source replica (pinned while read), then publish it on the target
        pod with the ``version=`` override — same version, bit-identical
        bytes, so I7 holds through the whole step.  Terminal on success:
        ``("done", (name, dst_pod))``."""
        token = yield from self._claim_writer(name)
        try:
            with self._lock:
                reps = dict(self._replicas.get(name, {}))
            if not reps:
                yield ("missing", name)
                return
            if dst_pod in reps:
                yield ("already", dst_pod)
                return
            src = None
            for pid in sorted(reps):
                if self.group.pod(pid).alive and self.group.link_up(dst_pod, pid):
                    src = pid
                    break
            if src is None:
                yield ("unreachable", name)
                return
            pod = self.group.pod(src)
            pin = pod.catalog.borrow(name)
            if pin is None or pin.regions is None:
                if pin is not None:
                    pin.release()
                yield ("missing", name)
                return
            try:
                version = pin.version
                image = reconstruct_image(pod.pool, pin.regions)
            finally:
                pin.release()
            yield ("reconstructed", (src, version))
            gen = self.group.pod(dst_pod).master.publish_steps(
                name, image, self._working_sets.get(name, []),
                version=version, dedup=dedup)
            for label, val in gen:
                yield (f"pod{dst_pod}:{label}", val)
            with self._lock:
                self._replicas.setdefault(name, {})[dst_pod] = version
                self.stats["replicas_added"] += 1
        finally:
            self._release_writer(name, token)
        yield ("done", (name, dst_pod))

    def drop_replica_steps(self, name: str, pod_id: int,
                           gc_polls: int = 64) -> Iterator[Tuple[str, object]]:
        """Retire one replica (never the last copy): tombstone + drain that
        pod's borrows, then GC.  Terminal: ``("done", (name, pod_id))``."""
        token = yield from self._claim_writer(name)
        try:
            with self._lock:
                reps = self._replicas.get(name, {})
                if pod_id not in reps:
                    yield ("missing", pod_id)
                    return
                if len(reps) <= 1:
                    yield ("last_replica", pod_id)
                    return
            m = self.group.pod(pod_id).master
            if m.delete(name, gc_now=False):
                yield (f"pod{pod_id}:tombstoned", name)
            for _ in range(gc_polls):
                if m.gc() or not m._pending_reclaim:
                    break
                yield (f"pod{pod_id}:gc_pending", name)
            with self._lock:
                self._replicas.get(name, {}).pop(pod_id, None)
                self.served.get(name, {}).pop(pod_id, None)
                self.stats["replicas_dropped"] += 1
        finally:
            self._release_writer(name, token)
        yield ("done", (name, pod_id))

    # -- read routing ------------------------------------------------------
    def note_demand(self, name: str, home_pod: int) -> None:
        with self._lock:
            d = self.demand.setdefault(name, {})
            d[home_pod] = d.get(home_pod, 0) + 1

    def borrow_route(self, host: str,
                     name: str) -> Optional[Tuple[str, int]]:
        """Pick the replica pod serving ``host``'s next borrow of ``name``.

        Returns ``("cxl", pod)`` with an MHD port HELD (caller must
        ``group.pod(pod).ports.detach(host)`` after release) when the home
        pod has a live replica and a port grants; ``("interpod", pod)``
        for the least-served reachable replica otherwise (exhausted ports
        fall through to the fabric — including to the home pod itself);
        None when every replica is partitioned away or dead (cold start).
        """
        home = self.group.home_pod(host)
        self.note_demand(name, home)
        with self._lock:
            reps = sorted(self._replicas.get(name, {}))
        reps = [p for p in reps if self.group.pod(p).alive]
        if not reps:
            self.stats["routed_none"] += 1
            return None
        if home in reps:
            pod = self.group.pod(home)
            if pod.ports.try_attach(host):
                self._note_served(name, home)
                self.stats["routed_local"] += 1
                return ("cxl", home)
            pod.ports.note_fallthrough()
            self.stats["port_fallthrough"] += 1
        reachable = [p for p in reps if self.group.link_up(home, p)]
        if not reachable:
            self.stats["routed_none"] += 1
            return None
        with self._lock:
            served = self.served.setdefault(name, {})
            pick = min(reachable, key=lambda p: (served.get(p, 0), p))
        self._note_served(name, pick)
        self.stats["routed_interpod"] += 1
        return ("interpod", pick)

    def _note_served(self, name: str, pod_id: int) -> None:
        with self._lock:
            served = self.served.setdefault(name, {})
            served[pod_id] = served.get(pod_id, 0) + 1

    # -- pod loss ----------------------------------------------------------
    def promote(self, dead_pod: int) -> List[str]:
        """Owner-pod loss: mark the pod dead and promote survivors — every
        replica set simply drops the dead pod (surviving replicas are
        already PUBLISHED at the group version, so promotion is a routing
        change, not a data copy).  Returns names that lost their LAST
        replica (restorable only from a fresh publish)."""
        self.group.mark_dead(dead_pod)
        lost: List[str] = []
        with self._lock:
            for name, reps in self._replicas.items():
                if dead_pod in reps:
                    reps.pop(dead_pod)
                    self.stats["promotions"] += 1
                    if not reps:
                        lost.append(name)
            for name in lost:
                del self._replicas[name]
        return lost
