"""Port-limited CXL pods and the group that composes them into a cluster.

Octopus (PAPERS.md) shows real CXL pods are built from multi-headed
devices (MHDs) with a fixed number of head ports, so at most ``ports``
distinct hosts can be CXL-attached to a pod at once — fleets are
necessarily many small pods, not one big one.  Pond bounds pool reach to
small pod sizes for latency.  This module models exactly that:

* :class:`PortLimiter` — the per-pod MHD port budget on concurrent host
  attach.  Attach is refcounted per host (all of a host's sessions share
  its one physical port); a host beyond the limit queues
  (:meth:`PortLimiter.attach_steps`) or falls through to the inter-pod
  RDMA path (:meth:`PortLimiter.try_attach` returns False).
* :class:`Pod` — one pod: its own :class:`~repro.core.pool.HierarchicalPool`
  (own ``CXLBudget`` via the master's capacity manager), catalog, master,
  and port limiter.
* :class:`PodGroup` — the pods plus the cluster-level wiring: host →
  home-pod assignment, pod liveness, and pairwise data-plane link state
  (``set_partition`` downs a link; the control plane — catalog atomics —
  is unaffected, matching a fabric partition that cuts bulk reads but not
  the management network).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Tuple

from ..core.clock import Clock, REAL_CLOCK
from ..core.coherence import Catalog
from ..core.master import PoolMaster
from ..core.pool import HierarchicalPool

#: Effectively-unlimited port count (single-pod back-compat default).
UNLIMITED_PORTS = 1 << 30


class PortLimiter:
    """Multi-headed-device port budget on concurrent host attach.

    ``try_attach`` grants a port when the host already holds one (attach is
    refcounted per host) or a head port is free; otherwise it returns False
    and the caller must either poll (``attach_steps``) or fall through to
    reaching the pod over the RDMA fabric.  ``detach`` releases one
    reference; the port frees when the host's last session detaches.
    """

    def __init__(self, ports: int = UNLIMITED_PORTS):
        self.ports = int(ports)
        self._lock = threading.Lock()
        self._attached: Dict[str, int] = {}
        self.stats = {"grants": 0, "releases": 0, "rejects": 0,
                      "fallthrough": 0, "peak": 0}

    def try_attach(self, host: str) -> bool:
        with self._lock:
            n = self._attached.get(host)
            if n is not None:
                self._attached[host] = n + 1
                self.stats["grants"] += 1
                return True
            if len(self._attached) >= self.ports:
                self.stats["rejects"] += 1
                return False
            self._attached[host] = 1
            self.stats["grants"] += 1
            self.stats["peak"] = max(self.stats["peak"], len(self._attached))
            return True

    def detach(self, host: str) -> None:
        with self._lock:
            n = self._attached.get(host, 0) - 1
            if n <= 0:
                self._attached.pop(host, None)
            else:
                self._attached[host] = n
            self.stats["releases"] += 1

    def attached(self, host: str) -> bool:
        with self._lock:
            return host in self._attached

    def in_use(self) -> int:
        with self._lock:
            return len(self._attached)

    def note_fallthrough(self) -> None:
        """Record that a rejected host fell through to the RDMA path."""
        with self._lock:
            self.stats["fallthrough"] += 1

    def attach_steps(self, host: str,
                     max_polls: Optional[int] = None) -> Iterator[Tuple[str, str]]:
        """Generator attach for simulator programs: yields ``("port_wait",
        host)`` per failed poll, terminally ``("attached", host)`` on a
        grant or ``("fallthrough", host)`` once ``max_polls`` is exhausted
        (the caller then serves over the inter-pod fabric instead)."""
        polls = 0
        while True:
            if self.try_attach(host):
                yield ("attached", host)
                return
            polls += 1
            if max_polls is not None and polls >= max_polls:
                self.note_fallthrough()
                yield ("fallthrough", host)
                return
            yield ("port_wait", host)


@dataclasses.dataclass
class Pod:
    """One pod: pool + catalog + master + MHD port limiter, with liveness."""

    pod_id: int
    pool: HierarchicalPool
    catalog: Catalog
    master: PoolMaster
    ports: PortLimiter
    alive: bool = True


class PodGroup:
    """A cluster of port-limited pods with host homing and link state.

    Every pod gets its own pool (own ``CXLBudget`` when ``cxl_budget`` is
    set — the budget is per pod, matching per-MHD capacity), catalog, and
    master under one shared clock.  Hosts are homed to a pod with
    :meth:`assign_host` (default: pod 0); data-plane links between pod
    pairs default up and can be partitioned independently of pod liveness.
    """

    def __init__(self, n_pods: int = 2, cxl_capacity: int = 64 << 20,
                 rdma_capacity: int = 128 << 20, catalog_capacity: int = 64,
                 ports_per_pod: Optional[int] = None,
                 cxl_budget: Optional[int] = None,
                 clock: Optional[Clock] = None, dedup: bool = False):
        self.clock = clock or REAL_CLOCK
        self.pods: List[Pod] = []
        for pid in range(n_pods):
            pool = HierarchicalPool(cxl_capacity, rdma_capacity,
                                    clock=self.clock)
            catalog = Catalog(catalog_capacity, clock=self.clock)
            master = PoolMaster(pool, catalog, cxl_budget=cxl_budget,
                                dedup=dedup)
            ports = PortLimiter(UNLIMITED_PORTS if ports_per_pod is None
                                else ports_per_pod)
            self.pods.append(Pod(pid, pool, catalog, master, ports))
        self._home: Dict[str, int] = {}
        self._links_down: set = set()       # frozenset({a, b}) pairs

    def __len__(self) -> int:
        return len(self.pods)

    def pod(self, pod_id: int) -> Pod:
        return self.pods[pod_id]

    def alive_pods(self) -> List[Pod]:
        return [p for p in self.pods if p.alive]

    # -- host homing -------------------------------------------------------
    def assign_host(self, host: str, pod_id: int) -> None:
        self._home[host] = pod_id

    def home_pod(self, host: str) -> int:
        return self._home.get(host, 0)

    # -- data-plane link state ---------------------------------------------
    def link_up(self, a: int, b: int) -> bool:
        """True when pod `a`'s hosts can bulk-read pod `b`'s tiers: the
        DESTINATION pod is alive and the pair's fabric link is not
        partitioned.  Only `b`'s liveness matters — losing a pod kills its
        memory, not its hosts' RNICs, so hosts homed on a dead pod still
        reach surviving pods over the fabric (a pod's hosts always reach
        their own pod's fabric when it is alive)."""
        if not self.pods[b].alive:
            return False
        return a == b or frozenset((a, b)) not in self._links_down

    def set_partition(self, a: int, b: int, up: bool = False) -> None:
        """Down (or restore, ``up=True``) the data-plane link between two
        pods.  Affects bulk reads only — catalog atomics keep working."""
        if up:
            self._links_down.discard(frozenset((a, b)))
        else:
            self._links_down.add(frozenset((a, b)))

    def mark_dead(self, pod_id: int) -> None:
        """Pod loss: the pod's catalog/pool are unreachable from every
        host; routing must promote the surviving replicas."""
        self.pods[pod_id].alive = False
