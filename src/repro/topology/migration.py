"""Break-even-gated migration of warm snapshots toward demand.

Copying a snapshot to the pod where its readers live trades a one-time
inter-pod bulk copy (hot + cold bytes over the fabric, plus the rebuild
on the destination) against a per-read saving (local CXL chunks instead
of inter-pod reads).  :func:`repro.serve.strategies.migration_economics`
prices that trade; :class:`MigrationManager` consults it and only
migrates past break-even — a snapshot with too few expected reads stays
where it is (``skipped_uneconomic``).

A migration is an :meth:`~repro.topology.replication.ReplicaManager.
add_replica_steps` at the CURRENT group version (reconstructed bytes, so
I7 bit-identity holds throughout), optionally followed by retiring the
least-demanded source replica — "migrate" degenerates to "replicate"
when the source stays.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

from ..core.pagestore import PAGE_SIZE
from ..serve.strategies import migration_economics
from .replication import ReplicaManager, split_pod_label


class MigrationManager:
    """Economics-gated replica placement toward observed demand."""

    def __init__(self, manager: ReplicaManager):
        self.manager = manager
        self.stats = {"considered": 0, "migrated": 0,
                      "skipped_uneconomic": 0, "skipped_no_source": 0,
                      "dropped": 0}

    def economics_for(self, name: str, expected_reads: int,
                      conc: int = 1) -> Optional[Dict[str, float]]:
        """Price migrating ``name`` from byte counts of a live replica;
        None when no replica's regions are readable."""
        for pid in self.manager.replica_pods(name):
            pod = self.manager.group.pod(pid)
            if not pod.alive:
                continue
            entry = pod.catalog.find(name)
            if entry is None or entry.regions is None:
                continue
            r = entry.regions
            return migration_economics(int(r.hot_bytes),
                                       int(r.n_cold) * PAGE_SIZE,
                                       expected_reads, conc)
        return None

    def migrate_steps(self, name: str, dst_pod: int, expected_reads: int,
                      conc: int = 1,
                      drop_source: bool = False) -> Iterator[Tuple[str, object]]:
        """One gated migration: yields ``("economics", econ)`` then either
        ``("skipped", econ)`` (below break-even) or the full
        ``add_replica_steps`` sequence; ``drop_source=True`` then retires
        the least-demanded OTHER replica (a move rather than a copy).
        Terminal on success: ``("migrated", (name, dst_pod))``."""
        self.stats["considered"] += 1
        econ = self.economics_for(name, expected_reads, conc)
        if econ is None:
            self.stats["skipped_no_source"] += 1
            yield ("skipped", None)
            return
        yield ("economics", econ)
        if not econ["worthwhile"]:
            self.stats["skipped_uneconomic"] += 1
            yield ("skipped", econ)
            return
        ok = False
        for label, val in self.manager.add_replica_steps(name, dst_pod):
            yield (label, val)
            base = split_pod_label(label)[1]
            if label == "done":
                ok = True
            elif base in ("missing", "unreachable") and label != "done":
                pass
        if not ok:
            self.stats["skipped_no_source"] += 1
            return
        self.stats["migrated"] += 1
        if drop_source:
            victim = self._least_demanded(name, exclude=dst_pod)
            if victim is not None:
                for label, val in self.manager.drop_replica_steps(name, victim):
                    yield (label, val)
                self.stats["dropped"] += 1
        yield ("migrated", (name, dst_pod))

    def _least_demanded(self, name: str,
                        exclude: int) -> Optional[int]:
        """The replica pod serving the fewest routed reads (ties break on
        lowest pod id), never the one just added."""
        pods = [p for p in self.manager.replica_pods(name) if p != exclude]
        if not pods:
            return None
        served = self.manager.served.get(name, {})
        return min(pods, key=lambda p: (served.get(p, 0), p))
