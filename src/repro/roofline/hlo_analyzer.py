"""Trip-count-aware HLO cost analyzer.

XLA's built-in ``HloCostAnalysis`` (exposed via ``compiled.cost_analysis()``)
counts a ``while`` body ONCE on the CPU backend — a scanned-layers model
under-reports FLOPs/bytes/collective-bytes by ~n_layers.  This analyzer
re-derives the three roofline inputs directly from the optimized HLO text,
weighting every computation by its call multiplicity:

  * while loops: body & condition × trip count (the loop bound constant in
    the condition region — canonical ``iter < N`` scan form);
  * fusions: internal dot/elementwise FLOPs counted, but HBM bytes counted
    only at the fusion boundary (operands + outputs) — internals live in
    registers/VMEM, which is also how a fused TPU kernel executes;
  * dots: 2 × |output| × K from dot_dimension_numbers;
  * elementwise/reduce: 1 flop per output element (transcendentals 1 — a
    slight under-count for exp/log-heavy code, noted in EXPERIMENTS.md);
  * dynamic-slice / gather-style ops: bytes = 2x slice size, not the full
    sliced operand;
  * collectives: operand bytes × multiplicity, by kind.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "cosine", "sine", "logistic", "exponential-minus-one",
    "log-plus-one", "select", "compare", "and", "or", "xor", "not", "clamp",
    "remainder", "atan2", "round-nearest-afz", "round-nearest-even",
}
_FREE = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
         "after-all", "custom-call", "partition-id", "replica-id",
         "opt-barrier"}
_MOVES = {"copy", "transpose", "broadcast", "reshape", "convert",
          "concatenate", "reverse", "iota", "rng-bit-generator"}
_SLICEY = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter",
           "slice", "pad"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_SHAPE_RE = re.compile(r"^([a-z0-9]+)\[([\d,]*)\](\{[^}]*\})?")
_SHAPE_FIND_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _arr_bytes_elems(dt: str, dims_str: str) -> Tuple[int, int]:
    if dt not in _DTYPE_BYTES:
        return 0, 0
    n = 1
    for d in dims_str.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dt], n


def _all_shapes_bytes(s: str) -> Tuple[int, int]:
    tb = te = 0
    for dt, dims in _SHAPE_FIND_RE.findall(s):
        b, e = _arr_bytes_elems(dt, dims)
        tb += b
        te += e
    return tb, te


def _split_shape_op(rest: str) -> Tuple[str, List[int], str, str]:
    """rest = text after '%name = '.
    Returns (shape_str, result_dims_or_None, opcode, remainder_after_opcode)."""
    rest = rest.strip()
    dims: List[int] = []
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = rest[: i + 1]
                    rem = rest[i + 1:]
                    break
        else:
            return rest, dims, "", ""
    else:
        m = _ARRAY_SHAPE_RE.match(rest)
        if not m:
            return rest, dims, "", ""
        shape = m.group(0)
        dims = [int(d) for d in m.group(2).split(",") if d.strip()]
        rem = rest[m.end():]
    om = re.match(r"\s*([\w\-]+)\s*\(", rem)
    op = om.group(1) if om else ""
    rem2 = rem[om.end() - 1:] if om else rem
    return shape, dims, op, rem2


def _call_operands(rem: str) -> List[str]:
    """names inside the call's first balanced paren group."""
    if not rem.startswith("("):
        return []
    depth = 0
    for i, ch in enumerate(rem):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return re.findall(r"%([\w.\-]+)", rem[: i + 1])
    return re.findall(r"%([\w.\-]+)", rem)


@dataclasses.dataclass
class Instr:
    """One parsed HLO instruction (name, result shape, op, operands)."""

    name: str
    shape: str
    dims: List[int]
    op: str
    rem: str                 # text from call parens onward (attrs included)
    out_bytes: int
    out_elems: int


@dataclasses.dataclass
class CompCost:
    """Accumulated flop/byte/collective cost of one computation body."""

    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (target, mult, kind, boundary_bytes); kind in {"fusion","while","ctrl"}
    calls: List[Tuple[str, float, str, float]] = dataclasses.field(default_factory=list)


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")


def parse_computations(text: str):
    comps: Dict[str, List[Instr]] = {}
    order: List[str] = []
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{") and "->" in line:
                hm = _HEADER_RE.match(line)
                if hm:
                    cur = hm.group(2)
                    comps[cur] = []
                    order.append(cur)
                    if hm.group(1):
                        entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rest = dm.groups()
        shape, dims, op, rem = _split_shape_op(rest)
        ob, oe = _all_shapes_bytes(shape)
        comps[cur].append(Instr(name, shape, dims, op, rem, ob, oe))
    return comps, entry or (order[-1] if order else None)


def _trip_count(cond_instrs: List[Instr]) -> int:
    """Max integer constant in the loop-condition region (canonical scan
    conditions compare the induction variable against the length)."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant" and ins.shape.startswith(("s32", "u32", "s64", "u64")):
            cm = re.match(r"\((\d+)\)", ins.rem.strip())
            if cm:
                best = max(best, int(cm.group(1)))
    return best


def analyze_hlo(text: str) -> Dict[str, float]:
    comps, entry = parse_computations(text)

    bytes_by_name: Dict[str, int] = {}
    dims_by_name: Dict[str, List[int]] = {}
    for instrs in comps.values():
        for ins in instrs:
            bytes_by_name[ins.name] = ins.out_bytes
            dims_by_name[ins.name] = ins.dims

    local: Dict[str, CompCost] = {}
    for cname, instrs in comps.items():
        cost = CompCost()
        for ins in instrs:
            op, rem = ins.op, ins.rem
            operands = _call_operands(rem)
            if op == "dot":
                k = 1
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rem)
                if m and operands:
                    lhs = dims_by_name.get(operands[0], [])
                    for di in m.group(1).split(","):
                        if di.strip() and int(di) < len(lhs):
                            k *= lhs[int(di)]
                cost.flops += 2.0 * ins.out_elems * max(1, k)
                cost.bytes += ins.out_bytes + sum(
                    bytes_by_name.get(o, 0) for o in operands[:2])
            elif op == "convolution":
                cost.flops += 2.0 * ins.out_elems
                cost.bytes += 2.0 * ins.out_bytes
            elif op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", rem)
                if fm:
                    cost.calls.append((fm.group(1), 1.0, "fusion", 0.0))
                cost.bytes += ins.out_bytes + sum(
                    bytes_by_name.get(o, 0) for o in operands)
            elif op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", rem)
                cm2 = re.search(r"condition=%?([\w.\-]+)", rem)
                trip = _trip_count(comps.get(cm2.group(1), [])) if cm2 else 1
                boundary = ins.out_bytes + sum(
                    bytes_by_name.get(o, 0) for o in operands)
                if bm:
                    cost.calls.append((bm.group(1), float(trip), "while", float(boundary)))
            elif op in ("call", "conditional", "map", "sort", "reduce-window",
                        "select-and-scatter"):
                for target in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)", rem):
                    cost.calls.append((target, 1.0, "ctrl", 0.0))
                if op == "sort":
                    cost.bytes += 2.0 * ins.out_bytes
            elif any(op.startswith(c) and not op.endswith("-done") for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                ob = sum(bytes_by_name.get(o, 0) for o in operands)
                cost.coll[kind] = cost.coll.get(kind, 0.0) + ob
                cost.bytes += ins.out_bytes + ob
            elif op in ("reduce",):
                ob = sum(bytes_by_name.get(o, 0) for o in operands[:1])
                cost.flops += max(ob / 4.0, float(ins.out_elems))
                cost.bytes += ins.out_bytes + ob
                for target in re.findall(r"to_apply=%?([\w.\-]+)", rem):
                    cost.calls.append((target, 0.0, "ctrl", 0.0))  # tiny
            elif op in _SLICEY:
                cost.bytes += 2.0 * ins.out_bytes
            elif op in _ELEMENTWISE:
                cost.flops += float(ins.out_elems)
                cost.bytes += ins.out_bytes + sum(
                    bytes_by_name.get(o, 0) for o in operands[:3])
            elif op in _MOVES:
                cost.bytes += 2.0 * ins.out_bytes
            # _FREE and unknown ops: no cost
        local[cname] = cost

    totals = {"flops": 0.0, "bytes": 0.0}
    coll_tot: Dict[str, float] = {}

    KERNEL_TRIP_MAX = 16  # blocked-kernel loops (chunked attn / SSD chunks)

    def visit(cname: str, mult: float, no_bytes: bool = False,
              loop_depth: int = 0, depth: int = 0):
        if cname not in local or mult <= 0 or depth > 50:
            return
        c = local[cname]
        totals["flops"] += c.flops * mult
        if not no_bytes:
            totals["bytes"] += c.bytes * mult
        for k, v in c.coll.items():
            coll_tot[k] = coll_tot.get(k, 0.0) + v * mult
        for sub, m, kind, boundary in c.calls:
            if kind == "fusion":
                # fused computations execute in registers/VMEM; the call
                # site already accounted the boundary bytes
                visit(sub, mult, True, loop_depth, depth + 1)
            elif kind == "while":
                kernel_region = loop_depth >= 1 or m <= KERNEL_TRIP_MAX
                if kernel_region and not no_bytes:
                    # blocked-kernel surrogate (Pallas on TPU): HBM traffic
                    # happens at the region boundary; the blocked working
                    # set stays in VMEM
                    totals["bytes"] += boundary * mult
                visit(sub, mult * m, no_bytes or kernel_region,
                      loop_depth + 1, depth + 1)
            else:
                visit(sub, mult * m, no_bytes, loop_depth, depth + 1)

    if entry:
        visit(entry, 1.0)
    out = {"flops": totals["flops"], "bytes": totals["bytes"]}
    for k in _COLLECTIVES:
        out[f"coll_{k}"] = coll_tot.get(k, 0.0)
    out["collective_bytes"] = sum(coll_tot.values())
    return out
