"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = HLO_FLOPs  / (chips × peak_FLOP/s)
    memory term     = HLO_bytes  / (chips × HBM_bw)
    collective term = coll_bytes / (chips × link_bw)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) program, so
per-device_cost / per-chip_rate == total_cost / (chips × rate); we record
both per-device and fleet-total numbers.

collective_bytes is not in cost_analysis: we parse the optimized HLO text,
build a {instruction → bytes} table from every definition's result shape,
and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (per-shard operand shapes ⇒ per-device
wire bytes).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]"
)
_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * b


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """-> {collective_kind: summed operand bytes} over the HLO module."""
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name = m.group(1).lstrip("%")
            sizes[name] = _shape_bytes(m.group(2), m.group(3))
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(line)
        if m is None:
            continue
        kind = None
        rest = stripped.split("=", 1)[1] if "=" in stripped else ""
        for k in _COLLECTIVE_KINDS:
            if re.search(rf"(^|\s){k}(-start)?\(", rest):
                kind = k
                break
        if kind is None:
            continue
        # operands inside the call parens
        call = rest[rest.index("("):]
        ops = re.findall(r"%?([\w.\-]+)", call)
        total = 0
        for o in ops:
            if o in sizes:
                total += sizes[o]
        out[kind] += total
    return out


@dataclasses.dataclass
class RooflineTerms:
    """Per-device FLOP/byte/collective totals feeding the roofline model."""

    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, int]
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0       # 6·N·D (dense) or 6·N_active·D (MoE)
    xla_cost_analysis_flops: float = 0.0   # raw (trip-count-blind) reference
    xla_cost_analysis_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            **dataclasses.asdict(self),
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "useful_flops_fraction": self.useful_flops_fraction,
        }


def movement_roofline(name: str, bytes_read: float, bytes_written: float,
                      flops: float = 0.0, bw: float = HBM_BW) -> dict:
    """Roofline terms for a data-movement kernel (the snapshot data plane).

    The snapshot sweeps (zero-detect, checksum, gather/scatter, and their
    fused forms — DESIGN.md §13) do O(1) integer math per byte streamed, so
    on the modeled TPU they sit on the memory roof: bound time is total
    HBM traffic / ``bw``.  ``benchmarks/kernel_bench.py`` feeds each op's
    *actual* per-invocation traffic (counted from its real input/output
    shapes, so an accidental extra pass shows up here and in the CI gate)
    through this helper to get deterministic modeled times and the derived
    per-page constants committed to ``experiments/kernel_calibration.json``.
    """
    total = float(bytes_read) + float(bytes_written)
    memory_s = total / bw
    compute_s = float(flops) / PEAK_FLOPS
    bound_s = max(memory_s, compute_s)
    return {
        "name": name,
        "bytes_read": float(bytes_read),
        "bytes_written": float(bytes_written),
        "bytes_total": total,
        "flops": float(flops),
        "memory_s": memory_s,
        "compute_s": compute_s,
        "bound_s": bound_s,
        "bound_GBps": (total / bound_s / 1e9) if bound_s else 0.0,
        "dominant": "compute" if compute_s > memory_s else "memory",
    }


def analyze(compiled, chips: int, model_flops: float = 0.0,
            hlo_text: Optional[str] = None) -> RooflineTerms:
    """Terms come from the trip-count-aware HLO analyzer (hlo_analyzer.py):
    XLA's own cost_analysis() counts while bodies once on this backend, which
    under-reports scanned-layer models by ~n_layers.  Raw cost_analysis
    values are kept in the record for reference."""
    from .hlo_analyzer import analyze_hlo

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # some backends return [dict]
        ca = ca[0] if ca else {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    h = analyze_hlo(text)
    flops = float(h["flops"])
    byts = float(h["bytes"])
    colls = {k.replace("coll_", ""): v for k, v in h.items() if k.startswith("coll_")}
    cbytes = float(h["collective_bytes"])
    terms = RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=cbytes,
        collectives={k: int(v) for k, v in colls.items()},
        chips=chips,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cbytes / LINK_BW,
        model_flops=model_flops,
    )
    terms.xla_cost_analysis_flops = float(ca.get("flops", 0.0))
    terms.xla_cost_analysis_bytes = float(ca.get("bytes accessed", 0.0))
    return terms


def model_flops_for(cfg, shape) -> float:
    """6·N·D training FLOPs (3·N·D for inference-only steps)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
