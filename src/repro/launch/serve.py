"""Serving launcher: warm-restore an arch from the pool (publishing it first
if absent) and serve batched greedy-decoding requests.

    PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --requests 4
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import all_arch_names, get_config
from ..core import HierarchicalPool, Orchestrator, PoolMaster
from ..checkpoint.ckpt import save_checkpoint
from ..models.model_zoo import build
from ..serve.coldstart import SkeletonPool, restore_server


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b", choices=all_arch_names())
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(vocab=2048)
    if cfg.is_encdec:
        print("enc-dec serving requires encoder features; see examples/")
        return 2
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    pool = HierarchicalPool(1 << 30, 2 << 30)
    master = PoolMaster(pool)
    _, stats = save_checkpoint(master, cfg.name, {"params": params}, step=0)
    print(f"published {cfg.name}: {stats['total_pages']} pages "
          f"(hot={stats['hot']} cold={stats['cold']} zero={stats['zero']})")

    orch = Orchestrator("serve-host", pool, master.catalog)
    sp = SkeletonPool(cfg, batch=args.requests, max_len=args.max_len,
                      target_size=1, background=False)
    t0 = time.perf_counter()
    out = restore_server(orch, cfg.name, sp.claim(), params)
    st = out["stats"]
    print(f"warm restore: hot={st['time_to_hot_s']*1e3:.0f}ms "
          f"full={st['time_to_full_s']*1e3:.0f}ms "
          f"(modeled pool time {sum(st['modeled'].values())*1e3:.2f}ms)")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.requests, args.prompt_len)), jnp.int32)
    toks = out["instance"].generate(prompts, args.gen_tokens)
    dt = time.perf_counter() - t0
    for i in range(args.requests):
        print(f"  req{i}: {toks[i].tolist()}")
    print(f"served {args.requests} requests x {args.gen_tokens} tokens "
          f"in {dt:.2f}s wall (CPU container)")
    sp.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
