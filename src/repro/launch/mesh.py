"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the pod axis
carries cross-DCN data parallelism; Aquifer's pool hierarchy maps onto it
(pod-local CXL tier ↔ intra-pod, RDMA tier ↔ cross-pod).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; smoke tests and
benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    """jax.sharding.AxisType landed after 0.4.x; Auto is the default there."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for tests."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(1, data)))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kw(2))
