"""Training launcher: any assigned arch, optional mesh dry-run of its own
train step, Aquifer fault tolerance on.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --resume

Full-size configs don't fit a CPU container; by default the arch's reduced()
config trains (same family/code paths). Pass --full only on real hardware.
"""
import argparse
import sys

from ..configs.base import all_arch_names, get_config
from ..core import HierarchicalPool, PoolMaster
from ..data.pipeline import DataConfig, SyntheticLMData
from ..models.model_zoo import build
from ..train.loop import LoopConfig, Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b", choices=all_arch_names())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (real hardware only)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_config(args.arch).reduced(vocab=2048)
    if cfg.is_encdec:
        print("enc-dec arch: use examples/ for the seq2seq driver; training "
              "the decoder-only path is not defined for", cfg.name)
        return 2
    model = build(cfg)
    print(f"arch={cfg.name} family={cfg.family} params≈{cfg.param_count()/1e6:.1f}M")

    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
    master = PoolMaster(HierarchicalPool(2 << 30, 4 << 30))
    trainer = Trainer(model, data, master=master,
                      loop_cfg=LoopConfig(steps=args.steps,
                                          ckpt_every=args.ckpt_every,
                                          log_every=10,
                                          ckpt_name=f"{cfg.name}-train"))
    trainer.run(resume=args.resume)
    for m in trainer.metrics_log:
        if "loss" in m:
            print(f"  step {m['step']:>5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.2f}")
    if trainer.ckpt_stats:
        s = trainer.ckpt_stats[-1]
        print(f"checkpoint: {s['total_pages']} pages zero={s['zero']} "
              f"hot={s['hot']} cold={s['cold']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
