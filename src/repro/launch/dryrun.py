"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory/cost/roofline data.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b --shape decode_32k --multipod

Outputs one JSON per cell under experiments/dryrun/.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks device count on first init.

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import all_arch_names, get_config
from ..configs.shapes import SHAPES, cell_supported
from ..models.model_zoo import build
from ..roofline.analysis import analyze, model_flops_for
from ..sharding.partition import (
    batch_specs,
    cache_specs,
    param_specs,
)
from ..train.trainstep import TrainState, make_train_step
from ..train.optimizer import AdamWState
from .mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _state_specs(params_sds):
    ps = param_specs(params_sds)
    opt = AdamWState(step=P(), m=ps, v=jax.tree.map(lambda x: x, ps))
    return TrainState(params=ps, opt=opt)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
               overrides: dict | None = None):
    import dataclasses as _dc

    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build(cfg)
    t0 = time.perf_counter()

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_sds)
    in_specs = model.input_specs(shape)

    jax.sharding.set_mesh(mesh)  # populate the abstract mesh for constrain()
    with mesh:
        if shape.kind == "train":
            state_sds = TrainState(
                params=params_sds,
                opt=AdamWState(
                    step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_sds),
                    v=jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_sds),
                ),
            )
            sspecs = _state_specs(params_sds)
            bspecs = batch_specs(in_specs, mesh)
            step = make_train_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(_named(mesh, sspecs), _named(mesh, bspecs)),
                out_shardings=(_named(mesh, sspecs), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, in_specs)
        elif shape.kind == "prefill":
            bspecs = batch_specs(in_specs, mesh)

            def prefill(params, batch):
                logits, aux = model.forward(params, batch)
                return logits

            jitted = jax.jit(
                prefill,
                in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
            )
            lowered = jitted.lower(params_sds, in_specs)
        else:  # decode
            caches_sds = jax.eval_shape(
                lambda: model.init_caches(None, shape.global_batch, shape.seq_len)
            )
            cspecs = cache_specs(caches_sds, cfg, mesh, shape.global_batch)
            bspecs = batch_specs(in_specs, mesh)

            def serve_step(params, batch, caches):
                return model.decode_step(params, batch, caches)

            jitted = jax.jit(
                serve_step,
                in_shardings=(
                    _named(mesh, pspecs), _named(mesh, bspecs), _named(mesh, cspecs),
                ),
                out_shardings=(None, _named(mesh, cspecs)),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_sds, in_specs, caches_sds)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    terms = analyze(compiled, chips, model_flops_for(cfg, shape), hlo_text=hlo_text)

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                           + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "roofline": terms.to_dict(),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {'2x16x16' if multi_pod else '16x16'}] "
              f"compile={t_compile:.1f}s  "
              f"mem(arg={result['memory']['argument_bytes']}, "
              f"temp={result['memory']['temp_bytes']})  "
              f"terms: C={terms.compute_s:.4f}s M={terms.memory_s:.4f}s "
              f"X={terms.collective_s:.4f}s dom={terms.dominant}")
        print("  memory_analysis:", mem)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf knobs), e.g. "
                         "--set seq_parallel=true; result JSON gets an @opt tag")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v)

    if args.list:
        for a in all_arch_names():
            for s in SHAPES:
                ok, reason = cell_supported(get_config(a), SHAPES[s])
                print(f"{a:24s} {s:12s} {'ok' if ok else reason}")
        return 0

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    archs = all_arch_names() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    opt_tag = ("@" + ",".join(f"{k}={v}" for k, v in sorted(overrides.items()))
               if overrides else "")
    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}{opt_tag}"
        out_path = OUT_DIR / f"{tag}.json"
        try:
            result = lower_cell(arch, shape, mp, overrides=overrides)
        except Exception as e:  # noqa: BLE001
            failures += 1
            result = {"arch": arch, "shape": shape, "multi_pod": mp,
                      "status": "error", "error": f"{type(e).__name__}: {e}",
                      "traceback": traceback.format_exc()[-3000:]}
            print(f"[{tag}] FAILED: {e}")
        out_path.write_text(json.dumps(result, indent=2, default=str))
    print(f"done: {len(cells)} cells, {failures} failures -> {OUT_DIR}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
