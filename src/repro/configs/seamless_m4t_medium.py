"""SeamlessM4T-medium [arXiv:2308.11596; hf].

Enc-dec backbone (12+12L, d_model=1024, 16H, d_ff=4096, vocab=256206).
The audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, T_frames, d_model).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    d_head=64,
    frontend="audio",
    rope_theta=1e4,
))
