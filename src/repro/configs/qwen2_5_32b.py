"""Qwen2.5-32B [hf:Qwen/Qwen2.5-0.5B family; hf]. GQA kv=8, QKV bias."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab=152064,
    d_head=128,
    qkv_bias=True,
    rope_theta=1e6,
))
