"""Model/config system: one dataclass covers every assigned architecture
family (dense / moe / ssm / hybrid / audio enc-dec / vlm).

Full configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation); smoke tests use ``reduced()`` configs of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for one model family instance."""

    name: str
    family: str                       # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None      # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # attention variant
    attn_kind: str = "gqa"            # "gqa" | "mla"
    # MLA (DeepSeek-V3) dims
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0           # leading dense FFN layers (DeepSeek: 3)
    capacity_factor: float = 1.25
    mtp: bool = False                 # multi-token prediction head

    # SSM / hybrid
    ssm_state: int = 0                # Mamba2 d_state
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    attn_every: int = 0               # hybrid: shared attn block every k layers
    # xLSTM
    slstm_every: int = 2              # alternate sLSTM/mLSTM blocks

    # enc-dec (audio)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    frontend: str = ""                # "audio" | "vision" stub frontends

    # vlm
    mrope: bool = False
    vision_prefix: int = 256          # stub patch-embedding prefix length
    vision_grid: Tuple[int, int] = (16, 16)

    # perf knobs (§Perf hillclimb; defaults = paper-faithful baseline)
    attn_batch_shard: bool = False    # shard attention over batch, replicate heads
    seq_parallel: bool = False        # sequence-parallel residual stream (SP)
    mla_absorb: bool = False          # MLA decode weight absorption (DeepSeek-V2 §)
    flash_decoding: bool = False      # shard decode caches over seq (TP axis)
    moe_impl: str = "dispatch"        # "dispatch" (GShard dropping) | "sorted"

    # numerics
    param_dtype: str = "float32"      # master weights
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    logits_fp32: bool = True

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a TP-divisible size (pad logits are masked)."""
        return -(-self.vocab // 16) * 16

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing → long_500k cell runs."""
        return self.family in ("ssm", "hybrid")

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model FLOPs, §Roofline)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.attn_kind == "mla":
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        dense_ffn = 3 * d * f  # SwiGLU
        if self.family == "moe":
            fe = self.d_ff_expert
            moe_ffn = (self.n_experts * 3 * d * fe
                       + self.n_shared_experts * 3 * d * fe + d * self.n_experts)
            n_moe = L - self.n_dense_layers
            ffn_total = self.n_dense_layers * dense_ffn + n_moe * moe_ffn
            return emb + L * attn + ffn_total
        if self.family == "ssm":
            # xLSTM-ish: per block ~ 8 d^2 (up/down proj + gates)
            return emb + L * 8 * d * d
        if self.family == "hybrid":
            d_in = self.ssm_heads * self.ssm_head_dim
            blk = (d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads)  # in_proj
                   + d_in * d                                            # out_proj
                   + 4 * (d_in + 2 * self.ssm_state) + 3 * self.ssm_heads + d_in)
            shared_attn = 4 * d * d + 3 * d * f
            return emb + L * blk + shared_attn
        if self.is_encdec:
            Lsum = self.n_enc_layers + self.n_dec_layers
            cross = self.n_dec_layers * 2 * d * d
            return emb + Lsum * (attn + dense_ffn) + cross
        return emb + L * (attn + dense_ffn)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: shared + top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, fe, L = self.d_model, self.d_ff_expert, self.n_layers
        hd = self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.attn_kind == "mla":
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        act_ffn = (self.top_k + self.n_shared_experts) * 3 * d * fe
        dense_ffn = 3 * d * self.d_ff
        n_moe = L - self.n_dense_layers
        return emb + L * attn + self.n_dense_layers * dense_ffn + n_moe * act_ffn

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small: Dict = dict(
            n_layers=min(self.n_layers, 2 if not self.is_encdec else 0),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            d_head=32,
            rope_theta=1e4,
            scan_layers=self.n_layers > 1,
            remat=False,
        )
        if self.attn_kind == "mla":
            small.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                         qk_rope_head_dim=16, v_head_dim=32)
        if self.family == "moe":
            small.update(n_experts=8, top_k=2, d_ff_expert=64,
                         n_shared_experts=min(self.n_shared_experts, 1),
                         n_dense_layers=min(self.n_dense_layers, 1), n_layers=3)
        if self.family in ("ssm", "hybrid"):
            small.update(ssm_state=16, ssm_heads=4, ssm_head_dim=32, ssm_chunk=32)
        if self.family == "hybrid":
            small.update(attn_every=2, n_layers=4)
        if self.is_encdec:
            small.update(n_enc_layers=2, n_dec_layers=2, n_layers=2)
        if self.family == "vlm":
            small.update(vision_prefix=16, vision_grid=(4, 4))
        small.update(overrides)
        return dataclasses.replace(self, **small)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def all_arch_names():
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


def load_all():
    from . import (  # noqa: F401
        qwen2_vl_72b, qwen2_5_32b, qwen2_5_14b, mistral_large_123b,
        phi4_mini_3_8b, xlstm_125m, deepseek_v3_671b, olmoe_1b_7b,
        zamba2_2_7b, seamless_m4t_medium,
    )
