"""The four assigned input shapes (per-arch cells = arch × shape)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One named workload shape (sequence/batch geometry + kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic sequence mixing."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "SKIP(full-attn): 512k-token decode needs sub-quadratic mixing"
    return True, ""


def all_cells():
    from .base import all_arch_names, get_config

    for arch in all_arch_names():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            yield cfg, shape
