"""OLMoE-1B-7B [arXiv:2409.02060; hf]. 64 experts top-8, every layer MoE."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    n_shared_experts=0,
    top_k=8,
    d_ff_expert=1024,
    n_dense_layers=0,
    rope_theta=1e4,
))
