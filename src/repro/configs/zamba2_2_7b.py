"""Zamba2-2.7B [arXiv:2411.15242; hf].

54 Mamba2 layers (d_model=2560, ssm_state=64) with a SHARED attention+MLP
block interleaved every 6 layers (the Zamba2 shared-block pattern; its
parameters are reused at every invocation point).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    d_head=80,
    ssm_state=64,
    ssm_heads=40,          # expand=2 → d_inner=5120, head_dim=128
    ssm_head_dim=128,
    ssm_chunk=256,
    attn_every=6,
    rope_theta=1e4,
))
