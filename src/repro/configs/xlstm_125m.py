"""xLSTM-125M [arXiv:2405.04517; unverified]. Alternating sLSTM + mLSTM
blocks (12L, d_model=768, 4 heads). d_ff=0: xLSTM blocks carry their own
up/down projections instead of a separate FFN."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    d_head=192,
    slstm_every=2,      # even blocks mLSTM, odd blocks sLSTM
    ssm_heads=4,
    ssm_head_dim=192,
    tie_embeddings=True,
))
