"""DeepSeek-V3-671B [arXiv:2412.19437; hf].

61L d_model=7168, MLA attention (128 heads), MoE: 1 shared + 256 routed
top-8 (d_ff_expert=2048), first 3 layers dense (d_ff=18432), MTP head.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,           # MLA: kv "heads" = q heads, latent-compressed
    d_ff=18432,               # dense-layer FFN width
    vocab=129280,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    n_dense_layers=3,
    mtp=True,
    rope_theta=1e4,
))
