"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE, dynamic
resolution.  Modality frontend is a STUB: input_specs() provides precomputed
patch embeddings for a fixed vision prefix; M-RoPE 3-component rotary is
implemented in full.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    d_head=128,
    qkv_bias=True,
    rope_theta=1e6,
    mrope=True,
    vision_prefix=256,
    vision_grid=(16, 16),
))
