"""Serverless model serving with Aquifer cold-start mitigation.

`SkeletonPool` is the MicroVM-pool analogue (§3.5): pre-created server
skeletons with all expensive host resources already provisioned — compiled
step functions and pre-allocated KV-cache/workspace buffers — so an incoming
invocation only needs its weights installed (borrow → flush → pre-install →
resume) instead of paying compile + alloc on the critical path.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Optional

from ..configs.base import ModelConfig
from ..core import Orchestrator
from ..core.clock import Clock, REAL_CLOCK
from ..checkpoint.ckpt import restore_checkpoint
from ..models.model_zoo import Model, build
from .engine import ServerInstance, _decode_jit


@dataclasses.dataclass
class Skeleton:
    """Pre-provisioned host resources for one instance (no weights yet)."""

    cfg: ModelConfig
    model: Model
    caches: Any                 # pre-allocated decode state
    batch: int
    max_len: int
    # stamped from the owning SkeletonPool's injected Clock (monotonic
    # seconds), NOT a wall-clock default factory: skeleton-age accounting
    # must be deterministic under the simulator's VirtualClock
    created_at: float = 0.0


class SkeletonPool:
    """Continuously replenished pool of pre-created skeletons (§3.5)."""

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 target_size: int = 2, background: bool = True,
                 clock: Optional[Clock] = None):
        self.clock = clock or REAL_CLOCK
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.target_size = target_size
        self.model = build(cfg)
        _decode_jit(self.model)     # warm the compile cache once
        self._q: "queue.Queue[Skeleton]" = queue.Queue()
        self.stats = {"claimed": 0, "created_on_demand": 0, "replenished": 0}
        # signaled by claim()/close(); the replenish thread blocks here while
        # the pool is full instead of polling the stop event at 100 Hz
        self._cond = threading.Condition()
        for _ in range(target_size):
            self._q.put(self._make())
        self._bg = background
        self._stop = threading.Event()
        if background:
            self._t = threading.Thread(target=self._replenish_loop, daemon=True)
            self._t.start()

    def _make(self) -> Skeleton:
        caches = self.model.init_caches(None, self.batch, self.max_len)
        return Skeleton(self.cfg, self.model, caches, self.batch, self.max_len,
                        created_at=self.clock.monotonic())

    def _need_work(self) -> bool:
        return self._stop.is_set() or self._q.qsize() < self.target_size

    def _replenish_loop(self):
        while True:
            with self._cond:
                # block until a claim drains the queue or close() asks us to
                # exit — no periodic wakeups while the pool is full.  claim()
                # and close() notify under the same condition, so the check-
                # then-wait here cannot lose a wakeup.
                while not self._need_work():
                    self.clock.cv_wait_for(self._cond, self._need_work, None)
                if self._stop.is_set():
                    return
            # build OUTSIDE the condition: a skeleton build can take seconds
            # and must not block claim()/close() from signaling
            self._q.put(self._make())
            self.stats["replenished"] += 1

    def claim(self) -> Skeleton:
        self.stats["claimed"] += 1
        try:
            sk = self._q.get_nowait()
        except queue.Empty:
            self.stats["created_on_demand"] += 1
            return self._make()
        with self._cond:
            self._cond.notify()
        return sk

    def close(self, timeout_s: float = 10.0):
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self._bg:
            # generous bound: the loop only re-checks _stop between _make()
            # calls, and a skeleton build can take seconds on a loaded box
            self._t.join(timeout=timeout_s)


def restore_server(
    orch: Orchestrator,
    snapshot_name: str,
    skeleton: Skeleton,
    params_template,
) -> Dict[str, Any]:
    """Aquifer warm restore into a claimed skeleton.

    Returns {"instance": ServerInstance, "stats": {...}} with time-to-hot
    (params pre-installed from CXL) vs time-to-full recorded.
    """
    template = ({"params": params_template}
                if "params" not in params_template else params_template)
    state, stats = restore_checkpoint(orch, snapshot_name, template)
    inst = ServerInstance(skeleton.model, state["params"], skeleton.caches, skeleton.max_len)
    return {"instance": inst, "stats": stats}
