"""Serving engine: prefill + decode with KV caches, greedy sampling.

`ServerInstance` is the MicroVM analogue: a model + caches + pre-compiled
step functions.  Prefill uses the full-sequence forward for logits; caches
are filled by a scanned decode pass (compact HLO, works for every family).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model_zoo import Model, build


@dataclasses.dataclass
class ServerInstance:
    """A live serving instance: model, params, and decode caches."""

    model: Model
    params: Any
    caches: Any
    max_len: int
    pos: int = 0

    def prefill(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """Feed prompt tokens (B, S); returns last-position logits (B, V)."""
        logits, self.caches = _prefill_scan(
            self.model, self.params, tokens, self.caches, self.pos
        )
        self.pos += tokens.shape[1]
        return logits

    def decode(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """One step: tokens (B, 1) -> logits (B, V)."""
        logits, self.caches = _decode_jit(self.model)(
            self.params, tokens, self.caches, jnp.asarray(self.pos, jnp.int32)
        )
        self.pos += 1
        return logits[:, 0]

    def generate(self, prompt: jnp.ndarray, n_tokens: int) -> np.ndarray:
        logits = self.prefill(prompt)
        out = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(n_tokens):
            out.append(np.asarray(tok[:, 0]))
            logits = self.decode(tok)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return np.stack(out, axis=1)


_decode_cache: Dict[str, Any] = {}


def _decode_jit(model: Model):
    key = model.cfg.name
    if key not in _decode_cache:
        def step(params, tokens, caches, pos):
            return model.decode_step(params, {"tokens": tokens, "pos": pos}, caches)
        _decode_cache[key] = jax.jit(step)
    return _decode_cache[key]


def _prefill_scan(model: Model, params, tokens, caches, start_pos: int):
    """Sequentially decode the prompt to fill caches; returns final logits."""
    step_fn = _decode_jit(model)
    b, s = tokens.shape
    logits = None
    for t in range(s):
        logits, caches = step_fn(params, tokens[:, t : t + 1], caches,
                                 jnp.asarray(start_pos + t, jnp.int32))
    return logits[:, 0], caches


def new_instance(cfg: ModelConfig, params, batch: int, max_len: int) -> ServerInstance:
    model = build(cfg)
    caches = model.init_caches(params, batch, max_len)
    return ServerInstance(model, params, caches, max_len)
