"""The paper's five restore configurations (§5.1.3), adapted to the same
two-tier pool so differences reflect algorithmic choices, not media:

  firecracker : full image in the RDMA pool; no prefetch; every touched page
                (including zero pages — they are stored in the full image)
                takes a fault → RDMA read → uffd.copy.
  reap        : prefetch the *recorded working set* (incl. its zero pages)
                via RDMA, rest demand-paged.
  faasnap     : prefetch only the non-zero working set via RDMA; zero-page
                faults resolve as minor faults (uffd.zeropage); cold pages
                demand-paged.
  fctiered    : Aquifer snapshot format (hot→CXL, cold→RDMA, zero sentinel)
                but no prefetch — pure demand paging over the tiers.
  aquifer     : hot set pre-installed from CXL before resume; zero faults →
                uffd.zeropage; cold faults → async RDMA (§3.4).

Each strategy executes *real* page movement against the pool (restored bytes
are verified) and returns **modeled** stage times (CPU wall time on this box
says nothing about CXL/RDMA — DESIGN.md §2).  Modeled time uses the cost
constants in core/pool.py plus a userfaultfd trap cost per major fault, with
an optional ``scale`` that linearly extrapolates page counts to the paper's
1.5 GiB instances.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional

import numpy as np

from ..core import (
    HierarchicalPool,
    SnapshotReader,
    StateImage,
    TimeLedger,
)
from ..core.pagestore import PAGE_SIZE, runs_from_pages
from ..core.pool import (
    CLFLUSH_PER_LINE_S,
    UFFD_COPY_PER_PAGE_S,
    UFFD_ZEROPAGE_PER_PAGE_S,
    uffd_copy_batch_cost,
    uffd_zeropage_range_cost,
)
from ..core.serving import Instance, RestoreEngine

# keep the analytic model in lockstep with the measured serving path
HOT_CHUNK_PAGES = RestoreEngine.HOT_CHUNK_PAGES

FAULT_TRAP_S = 10e-6         # userfaultfd trap + handler wakeup + wake ioctl
SNAPSHOT_API_S = 1.5e-3      # Firecracker Snapshot API + uffd handshake
MACHINE_STATE_S = 1.0e-3     # load serialized vCPU/device state
CXL_LAT_S = 400e-9
CXL_BW = 50e9                # emulated CXL = remote NUMA node (§5.1.1)
RDMA_LAT_S = 3e-6
RDMA_BW = 100e9 / 8          # per-host RNIC, shared by co-located restores
CXL_PAGE_READ_S = CXL_LAT_S + PAGE_SIZE / CXL_BW
RDMA_PAGE_READ_S = RDMA_LAT_S + PAGE_SIZE / RDMA_BW
RDMA_INFLIGHT = 64
# Residual stall accounting for the predictive-prefetch A/B (DESIGN.md §17):
# a demand fault on an UNCOVERED cold page pays the trap plus the full
# synchronous RDMA page read plus the install; a fault that lands on a page
# whose prefetch is already in flight ("prefetch hit") pays only trap +
# install — the wire latency is (modeled as fully) hidden by the prefetcher.
DEMAND_FAULT_STALL_S = FAULT_TRAP_S + RDMA_PAGE_READ_S + UFFD_COPY_PER_PAGE_S
PREFETCH_HIT_STALL_S = FAULT_TRAP_S + UFFD_COPY_PER_PAGE_S
# Inter-pod fabric (topology layer, DESIGN.md §16): a read that leaves the
# host's CXL pod rides the RNIC through one extra switch hop.  Octopus-style
# pods are port-limited and sparse, so the fleet is many small pods and the
# inter-pod path is what a host pays when its pod holds no replica (or its
# pod's MHD ports are exhausted).  Bandwidth is the same 100 Gb/s RNIC; the
# hop adds fixed latency per op.
INTER_POD_HOP_S = 1.5e-6
INTER_POD_LAT_S = RDMA_LAT_S + INTER_POD_HOP_S
INTER_POD_BW = RDMA_BW
INTER_POD_INFLIGHT = RDMA_INFLIGHT


@dataclasses.dataclass
class RestoreResult:
    """Timing breakdown of one restore under a named strategy."""

    strategy: str
    setup_s: float               # machine state + snapshot API + prefetch
    prefetch_s: float
    exec_install_s: float        # page-installation time during execution
    compute_s: float
    stats: Dict[str, int]

    @property
    def total_s(self) -> float:
        return self.setup_s + self.exec_install_s + self.compute_s

    def breakdown(self) -> Dict[str, float]:
        return {
            "setup": self.setup_s - self.prefetch_s,
            "prefetch": self.prefetch_s,
            "exec_install": self.exec_install_s,
            "compute": self.compute_s,
            "total": self.total_s,
        }


@dataclasses.dataclass
class WorkloadSpec:
    """Everything a strategy needs about one serverless workload."""

    name: str
    image: StateImage                    # full state image (ground truth)
    working_set: np.ndarray              # profiled WS page indices (§3.2)
    touched: np.ndarray                  # pages touched by THIS invocation
    compute_s: float                     # function execution compute time
    scale: float = 1.0                   # page-count extrapolation factor


def residual_stall_s(n_demand_faults: int, n_prefetch_hits: int = 0) -> float:
    """Modeled guest-visible stall from cold-page faults during one
    invocation: uncovered faults pay the full demand shape, covered ones
    the hit shape.  The quantity the predicted-order prefetch policy is
    scored on (adaptive_bench phase-shift A/B)."""
    return (n_demand_faults * DEMAND_FAULT_STALL_S
            + n_prefetch_hits * PREFETCH_HIT_STALL_S)


def _shared(serial_s: float, nbytes: int, bw: float, conc: int) -> float:
    """Contention model: an instance is limited by its own serial path OR by
    its fair share of the host link, whichever is slower."""
    return max(serial_s, nbytes * conc / bw)


def _bulk_cc(conc: int) -> int:
    """Bulk prefetch happens in a short window right after dispatch; the
    load balancer staggers restores, so prefetch windows only partially
    overlap (~1/4 of co-located restores contend at once)."""
    return max(1, conc // 4)


def _rdma_bulk(n_pages: int, conc: int = 1) -> float:
    """Pipelined one-sided reads (QP depth RDMA_INFLIGHT); `conc` co-located
    restores share the RNIC bandwidth (latency is unaffected)."""
    if n_pages <= 0:
        return 0.0
    serial = -(-n_pages // RDMA_INFLIGHT) * RDMA_LAT_S + n_pages * PAGE_SIZE / RDMA_BW
    return _shared(serial, n_pages * PAGE_SIZE, RDMA_BW, _bulk_cc(conc))


def _rdma_pages_faulted(n_pages: int, conc: int = 1) -> float:
    """Synchronous per-fault reads: latency-serialized, bandwidth-floored."""
    serial = n_pages * (RDMA_LAT_S + PAGE_SIZE / RDMA_BW)
    return _shared(serial, n_pages * PAGE_SIZE, RDMA_BW, conc)


def _cxl_pages(n_pages: int, conc: int = 1) -> float:
    serial = n_pages * (CXL_LAT_S + PAGE_SIZE / CXL_BW)
    return _shared(serial, n_pages * PAGE_SIZE, CXL_BW, _bulk_cc(conc))


def _classify(spec: WorkloadSpec):
    """Vectorized page classification: numpy boolean masks over the zero
    bitmap and a working-set membership mask, instead of Python set lookups
    per touched page.  Outputs are equivalent to the scalar reference: the
    ``t_*`` arrays preserve ``spec.touched`` order (duplicates included),
    the ``ws_*`` arrays are the deduplicated working set in sorted order."""
    zero = spec.image.zero_page_bitmap()
    ws_idx = (np.unique(np.asarray(spec.working_set, dtype=np.int64))
              if len(spec.working_set) else np.zeros(0, dtype=np.int64))
    ws_mask = np.zeros(zero.size, dtype=bool)
    ws_mask[ws_idx] = True
    touched = np.asarray(spec.touched, dtype=np.int64).reshape(-1)
    t_is_zero = zero[touched]
    t_in_ws = ws_mask[touched]
    t_zero = touched[t_is_zero]
    t_hot = touched[~t_is_zero & t_in_ws]
    t_cold = touched[~t_is_zero & ~t_in_ws]
    ws_zero = ws_idx[zero[ws_idx]]
    ws_nonzero = ws_idx[~zero[ws_idx]]
    return zero, t_zero, t_hot, t_cold, ws_zero, ws_nonzero


def _cxl_chunks(n_pages: int, conc: int = 1) -> float:
    """Streamed CXL reads over the *compacted* hot region: one op-latency per
    HOT_CHUNK_PAGES chunk (never worse than one per run); the per-host link
    bandwidth floor is physics and stays."""
    n_ops = -(-n_pages // HOT_CHUNK_PAGES) if n_pages else 0
    serial = n_ops * CXL_LAT_S + n_pages * PAGE_SIZE / CXL_BW
    return _shared(serial, n_pages * PAGE_SIZE, CXL_BW, _bulk_cc(conc))


def run_strategy(strategy: str, spec: WorkloadSpec, concurrency: int = 1,
                 batched: bool = True) -> RestoreResult:
    """`concurrency` co-located restores share the host's CXL link and RNIC
    bandwidth; per-op latencies and CPU-side uffd costs are per-instance.

    ``batched=True`` (default) models run-coalesced installs for the
    prefetch-style strategies: prefetched pages land run-at-a-time (one
    uffd.copy ioctl per contiguous run), and Aquifer's hot pre-install pays
    one CXL op-latency per run instead of per page.  ``batched=False`` keeps
    the strictly page-at-a-time model for comparison."""
    zero, t_zero, t_hot, t_cold, ws_zero, ws_nonzero = _classify(spec)
    sc = spec.scale
    cc = max(1, concurrency)
    ws_runs = len(runs_from_pages(spec.working_set))
    hot_runs = len(runs_from_pages(ws_nonzero))
    t_cold_runs = len(runs_from_pages(t_cold))
    stats = {
        "touched": len(spec.touched), "t_zero": len(t_zero),
        "t_hot": len(t_hot), "t_cold": len(t_cold),
        "ws": len(spec.working_set),
        "ws_runs": ws_runs, "hot_runs": hot_runs,
    }
    setup = SNAPSHOT_API_S + MACHINE_STATE_S
    prefetch = 0.0
    exec_install = 0.0

    n = lambda k: int(k * sc)  # page counts extrapolated to paper-size instances
    # run counts scale with page counts (mean run length is size-invariant)

    def install_cost(n_pages: int, n_runs: int) -> float:
        """uffd.copy install of a prefetched set: batched = one ioctl per
        contiguous run; per-page = one ioctl per page."""
        if batched:
            return uffd_copy_batch_cost(n_pages, max(1, n_runs)) if n_pages else 0.0
        return n_pages * UFFD_COPY_PER_PAGE_S

    if strategy == "firecracker":
        # all touched pages: major fault + sync RDMA read + uffd.copy
        nt = n(len(spec.touched))
        exec_install = (
            nt * (FAULT_TRAP_S + UFFD_COPY_PER_PAGE_S) + _rdma_pages_faulted(nt, cc)
        )
    elif strategy == "reap":
        n_pre = n(len(spec.working_set))
        prefetch = _rdma_bulk(n_pre, cc) + install_cost(n_pre, n(ws_runs))
        nc_ = n(len(t_cold))
        exec_install = nc_ * (FAULT_TRAP_S + UFFD_COPY_PER_PAGE_S) + _rdma_pages_faulted(nc_, cc)
    elif strategy == "faasnap":
        n_pre = n(len(ws_nonzero))
        prefetch = _rdma_bulk(n_pre, cc) + install_cost(n_pre, n(hot_runs))
        nz, nc_ = n(len(t_zero)), n(len(t_cold))
        exec_install = (
            nz * (FAULT_TRAP_S + UFFD_ZEROPAGE_PER_PAGE_S)
            + nc_ * (FAULT_TRAP_S + UFFD_COPY_PER_PAGE_S) + _rdma_pages_faulted(nc_, cc)
        )
    elif strategy == "fctiered":
        # Aquifer format, no prefetch: hot faults serve from CXL
        nh, nz, nc_ = n(len(t_hot)), n(len(t_zero)), n(len(t_cold))
        exec_install = (
            nh * (FAULT_TRAP_S + UFFD_COPY_PER_PAGE_S) + _cxl_pages(nh, cc)
            + nz * (FAULT_TRAP_S + UFFD_ZEROPAGE_PER_PAGE_S)
            + nc_ * (FAULT_TRAP_S + UFFD_COPY_PER_PAGE_S) + _rdma_pages_faulted(nc_, cc)
        )
    elif strategy == "aquifer":
        n_hot, n_hruns = n(len(ws_nonzero)), n(hot_runs)
        # serialized CXL pre-install (§5.2) + clflush of the CXL sections
        flush = (n_hot * PAGE_SIZE / 64) * CLFLUSH_PER_LINE_S
        if batched:
            # run-coalesced: chunked CXL reads over the compact hot region,
            # one uffd.copy ioctl per guest-contiguous run
            prefetch = _cxl_chunks(n_hot, cc) + install_cost(n_hot, n_hruns) + flush
        else:
            prefetch = _cxl_pages(n_hot, cc) + n_hot * UFFD_COPY_PER_PAGE_S + flush
        # cold faults overlap via async RDMA: latency hidden up to QP depth;
        # the completion handler installs extent-at-a-time when batched
        nz, nc_ = n(len(t_zero)), n(len(t_cold))
        async_cold = (_rdma_bulk(nc_, cc) + nc_ * FAULT_TRAP_S
                      + install_cost(nc_, n(t_cold_runs)))
        exec_install = nz * (FAULT_TRAP_S + UFFD_ZEROPAGE_PER_PAGE_S) + async_cold
    else:
        raise ValueError(strategy)

    return RestoreResult(
        strategy=strategy,
        setup_s=setup + prefetch,
        prefetch_s=prefetch,
        exec_install_s=exec_install,
        compute_s=spec.compute_s,
        stats=stats,
    )


STRATEGIES = ("firecracker", "reap", "faasnap", "fctiered", "aquifer")


def hot_preinstall_time(spec: WorkloadSpec, batched: bool = True) -> float:
    """Modeled hot pre-install time (CXL reads + uffd installs) for one
    instance, excluding the borrow-protocol clflush (which the Orchestrator
    pays before pre-install) and link contention.  This is the per-run vs
    per-page comparison the run-coalesced serving design targets."""
    _zero, _tz, _th, _tc, _wsz, hot = _classify(spec)
    n_hot = int(len(hot) * spec.scale)
    if not batched:
        return n_hot * (CXL_LAT_S + PAGE_SIZE / CXL_BW) + n_hot * UFFD_COPY_PER_PAGE_S
    n_runs = int(len(runs_from_pages(hot)) * spec.scale)
    n_chunks = -(-n_hot // HOT_CHUNK_PAGES) if n_hot else 0
    read = n_chunks * CXL_LAT_S + n_hot * PAGE_SIZE / CXL_BW
    return read + uffd_copy_batch_cost(n_hot, max(1, n_runs))


def modeled_concurrent_restore_s(reader, conc: int, max_extent_pages: int = 64,
                                 chunk_pages: Optional[int] = None) -> float:
    """Analytic modeled time of ONE full restore — machine-state + index
    reads, borrow clflush, chunked hot pre-install, zero ranges, and a
    doorbell-batched cold-extent prefetch that covers every cold page (no
    demand faults) — while `conc` independent streams contend for the
    host's CXL link and RNIC.

    Every transfer term is `_shared()` over the same run/extent arithmetic
    the serving path executes, so this is the analytic twin of the executed
    path's per-host ``LinkArbiter`` accounting: the property tests require
    the two to agree within 15% across random concurrency/workload mixes.
    For fan-out groups (k same-snapshot restores through a NodePageServer)
    pass the number of distinct *groups* as `conc` — the link carries each
    group's bytes once regardless of k.
    """
    r = reader.regions
    chunk = chunk_pages or HOT_CHUNK_PAGES
    conc = max(1, conc)
    # machine state + offset array (one HostView read each), cold index if
    # the cold tier is compressed
    t = _shared(CXL_LAT_S + r.ms_size / CXL_BW, r.ms_size, CXL_BW, conc)
    oa_bytes = r.total_pages * 8
    t += _shared(CXL_LAT_S + oa_bytes / CXL_BW, oa_bytes, CXL_BW, conc)
    if r.cold_compressed and r.n_cold:
        ci_bytes = r.n_cold * 4
        t += _shared(CXL_LAT_S + ci_bytes / CXL_BW, ci_bytes, CXL_BW, conc)
    # borrow-protocol clflushopt over the snapshot's CXL sections
    n_lines = -(-(r.ms_size + r.oa_size + max(r.hot_bytes, 0)) // 64)
    t += n_lines * CLFLUSH_PER_LINE_S
    # hot pre-install: one CXL read per extent (contiguous-region chunk, or
    # adjacent-store-offset run for dedup), one uffd.copy ioctl per
    # guest-contiguous run within each extent — the same extent walk the
    # serving path executes (reader.iter_hot_extents)
    n_hot, n_chunks, n_ranges = 0, 0, 0
    for pages, _off, _nbytes in reader.iter_hot_extents(chunk):
        n_chunks += 1
        n_hot += int(pages.size)
        seg = np.sort(pages)
        n_ranges += 1 + int(np.count_nonzero(np.diff(seg) != 1))
    if n_hot:
        t += _shared(n_chunks * CXL_LAT_S + n_hot * PAGE_SIZE / CXL_BW,
                     n_hot * PAGE_SIZE, CXL_BW, conc)
        t += uffd_copy_batch_cost(n_hot, n_ranges)
    # zero pages: one uffd.zeropage ioctl per zero run
    zr = reader.zero_runs()
    if zr.size:
        t += uffd_zeropage_range_cost(int(zr[:, 1].sum()), int(zr.shape[0]))
    # cold prefetch: pipelined extent reads (QP-depth doorbell batching),
    # one uffd.copy ioctl per extent install
    cr = reader.cold_runs()
    n_cold = int(cr[:, 1].sum()) if cr.size else 0
    if n_cold:
        n_ext, cold_bytes = 0, 0
        for _es, _en, _rank0, _off, nbytes in reader.iter_cold_extents(
                max_extent_pages):
            cold_bytes += nbytes
            n_ext += 1
        serial = -(-n_ext // RDMA_INFLIGHT) * RDMA_LAT_S + cold_bytes / RDMA_BW
        t += _shared(serial, cold_bytes, RDMA_BW, conc)
        t += uffd_copy_batch_cost(n_cold, n_ext)
    return t


def modeled_degraded_restore_s(reader, conc: int = 1,
                               max_extent_pages: int = 64) -> float:
    """Analytic modeled time of one restore while the CXL host link is
    browned out (DESIGN.md §15): the breaker is open, so EVERY byte that
    would have crossed the CXL link — machine state, offset array, cold
    index, and the whole hot set — is fetched over the RDMA fabric instead,
    at the RDMA demand shape.  This is the analytic twin of the executed
    degraded path (``SnapshotReader.degraded_cxl_read`` +
    ``RestoreEngine.drain_degraded_hot``): metadata reads become single RDMA
    transfers, hot pages demand-fault one page per transfer (the all-cold
    fault shape of :func:`_rdma_pages_faulted`) with one uffd.copy each, and
    the zero/cold terms are unchanged from
    :func:`modeled_concurrent_restore_s`."""
    r = reader.regions
    conc = max(1, conc)
    # metadata over RDMA: one transfer each, no CXL op latency
    t = _shared(RDMA_LAT_S + r.ms_size / RDMA_BW, r.ms_size, RDMA_BW, conc)
    oa_bytes = r.total_pages * 8
    t += _shared(RDMA_LAT_S + oa_bytes / RDMA_BW, oa_bytes, RDMA_BW, conc)
    if r.cold_compressed and r.n_cold:
        ci_bytes = r.n_cold * 4
        t += _shared(RDMA_LAT_S + ci_bytes / RDMA_BW, ci_bytes, RDMA_BW, conc)
    # the borrow protocol still clflushes the snapshot's CXL sections — the
    # flush is owner-coherence work, not a host-link read
    n_lines = -(-(r.ms_size + r.oa_size + max(r.hot_bytes, 0)) // 64)
    t += n_lines * CLFLUSH_PER_LINE_S
    # hot set: page-granular demand faults over RDMA (the pre-install was
    # skipped), one uffd.copy ioctl per page
    n_hot = int(reader.hot_page_indices().size)
    if n_hot:
        t += _rdma_pages_faulted(n_hot, conc)
        t += uffd_copy_batch_cost(n_hot, n_hot)
    # zero pages: one uffd.zeropage ioctl per zero run (unchanged)
    zr = reader.zero_runs()
    if zr.size:
        t += uffd_zeropage_range_cost(int(zr[:, 1].sum()), int(zr.shape[0]))
    # cold prefetch: identical to the healthy path (it never touched CXL)
    cr = reader.cold_runs()
    n_cold = int(cr[:, 1].sum()) if cr.size else 0
    if n_cold:
        n_ext, cold_bytes = 0, 0
        for _es, _en, _rank0, _off, nbytes in reader.iter_cold_extents(
                max_extent_pages):
            cold_bytes += nbytes
            n_ext += 1
        serial = -(-n_ext // RDMA_INFLIGHT) * RDMA_LAT_S + cold_bytes / RDMA_BW
        t += _shared(serial, cold_bytes, RDMA_BW, conc)
        t += uffd_copy_batch_cost(n_cold, n_ext)
    return t


# -- content-addressed (dedup) publish/restore economics ---------------------
# Hashing throughput of the publish-time content hash.  Hand-set at 20 GB/s
# through PR 5; since the fused publish sweep (kernels/snapshot_fuse,
# DESIGN.md §13) computes the hash in-register while the page streams through
# VMEM, the per-page hash cost is one streaming pass at the sweep's roofline
# bandwidth.  The value is sourced from the committed calibration artifact
# written by ``benchmarks/kernel_bench.py --write-calibration`` — a file read
# at import, never re-measured, so modeled numbers stay deterministic per
# commit; the hand-set defaults below apply only when the artifact is absent.
_CALIBRATION_PATH = (Path(__file__).resolve().parents[3]
                     / "experiments" / "kernel_calibration.json")
_CALIBRATION_DEFAULTS = {
    "checksum_bw_Bps": 20e9,              # pre-calibration hand-set value
    "publish_sweep_page_s": 2 * PAGE_SIZE / 20e9,
    "preinstall_page_s": 2 * PAGE_SIZE / 20e9,
}


def _load_calibration() -> Dict[str, float]:
    try:
        cal = json.loads(_CALIBRATION_PATH.read_text())
        consts = cal.get("constants", {})
    except (OSError, ValueError):
        consts = {}
    return {k: float(consts.get(k, v)) for k, v in _CALIBRATION_DEFAULTS.items()}


CALIBRATION = _load_calibration()
CHECKSUM_BW = CALIBRATION["checksum_bw_Bps"]
CHECKSUM_PER_PAGE_S = PAGE_SIZE / CHECKSUM_BW
# fused data-plane per-page sweep times ("and friends"): publish = one-pass
# zero-scan + checksum + compaction; pre-install = gather + verify + scatter
PUBLISH_SWEEP_PAGE_S = CALIBRATION["publish_sweep_page_s"]
PREINSTALL_PAGE_S = CALIBRATION["preinstall_page_s"]


def dedup_publish_cost_s(n_hot: int, n_cold: int,
                         n_hot_unique: int, n_cold_unique: int) -> float:
    """Modeled owner-side publish cost WITH dedup: every candidate page is
    hashed (and byte-verified on a hash hit — same streaming pass), but only
    the UNIQUE pages cross a link into their tier."""
    hash_s = (n_hot + n_cold) * CHECKSUM_PER_PAGE_S
    return hash_s + _cxl_chunks(n_hot_unique) + _rdma_bulk(n_cold_unique)


def baseline_publish_cost_s(n_hot: int, n_cold: int) -> float:
    """Modeled owner-side publish cost WITHOUT dedup: every page is written."""
    return _cxl_chunks(n_hot) + _rdma_bulk(n_cold)


def dedup_restore_penalty_s(n_extra_hot_extents: int,
                            n_extra_cold_extents: int) -> float:
    """Per-restore cost of dedup's lost contiguity: each extra CXL extent
    pays one more load-to-use latency, each extra RDMA extent one more
    one-sided-read latency (bandwidth terms are unchanged — the same bytes
    move; uffd ranges are guest-side and also unchanged)."""
    return (max(0, n_extra_hot_extents) * CXL_LAT_S
            + max(0, n_extra_cold_extents) * RDMA_LAT_S)


def dedup_economics(n_hot: int, n_cold: int,
                    n_hot_unique: int, n_cold_unique: int,
                    n_extra_hot_extents: int = 0,
                    n_extra_cold_extents: int = 0,
                    expected_restores: int = 64) -> Dict[str, float]:
    """Break-even model for content-addressed publishing of one snapshot.

    Dedup is a CAPACITY play: every shared hot page keeps one page of CXL
    free, which lets another snapshot's hot set stay resident instead of
    degrading to RDMA demand paging.  The benefit side therefore prices each
    saved CXL page at the demand-fault path it spares some co-resident
    restore (trap + synchronous-feeling RDMA read + per-page uffd.copy,
    minus the pre-install path the page rides instead) — the same arithmetic
    :func:`recuration_benefit_s` uses for promotions.  The cost side is the
    publish-time hashing overhead plus the per-restore fragmentation
    penalty, both amortized over ``expected_restores``.
    """
    pages_saved_cxl = max(0, n_hot - n_hot_unique)
    saved_demand = pages_saved_cxl * (FAULT_TRAP_S + RDMA_PAGE_READ_S
                                      + UFFD_COPY_PER_PAGE_S)
    saved_preinstall = (_cxl_chunks(pages_saved_cxl)
                        + uffd_copy_batch_cost(pages_saved_cxl)
                        if pages_saved_cxl else 0.0)
    benefit_s = (saved_demand - saved_preinstall) * expected_restores
    publish_delta_s = (dedup_publish_cost_s(n_hot, n_cold,
                                            n_hot_unique, n_cold_unique)
                       - baseline_publish_cost_s(n_hot, n_cold))
    penalty_s = dedup_restore_penalty_s(n_extra_hot_extents,
                                        n_extra_cold_extents)
    cost_s = max(0.0, publish_delta_s) + penalty_s * expected_restores
    return {
        "pages_saved_cxl": float(pages_saved_cxl),
        "bytes_saved": float((n_hot - n_hot_unique + n_cold - n_cold_unique)
                             * PAGE_SIZE),
        "benefit_s": benefit_s,
        "publish_delta_s": publish_delta_s,
        "restore_penalty_s": penalty_s,
        "cost_s": cost_s,
        "net_s": benefit_s - cost_s,
        "expected_restores": float(expected_restores),
        "worthwhile": bool(benefit_s > cost_s),
    }


# -- keep-warm vs re-restore economics (fleet serving layer) ------------------
# Reactivating a kept-warm instance moves no pages: it is a scheduler wake +
# cgroup unfreeze, modeled as a fixed resume cost.
WARM_RESUME_S = 0.5e-3
# Holding an instance warm pins its resident bytes on the host.  The
# opportunity cost is what the pod could do with those bytes instead: keep
# another snapshot's hot page resident and spare its next restore the
# demand-fault path (trap + synchronous-feeling RDMA read + per-page
# uffd.copy), amortized over a typical inter-restore interval of the
# displaced snapshot.  Same price base as recuration_benefit_s.
KEEPWARM_DISPLACE_INTERVAL_S = 1.0
KEEPWARM_BYTE_S_COST = ((FAULT_TRAP_S + RDMA_PAGE_READ_S + UFFD_COPY_PER_PAGE_S)
                        / (PAGE_SIZE * KEEPWARM_DISPLACE_INTERVAL_S))


def keepwarm_economics(restore_s: float, expected_gap_s: float,
                       resident_bytes: int) -> Dict[str, float]:
    """Break-even model for holding a just-finished instance warm until its
    function's next expected arrival (``expected_gap_s`` away) instead of
    releasing it and paying a cold restore then.

    Benefit: the next invocation skips the restore (pays ``WARM_RESUME_S``).
    Cost: ``resident_bytes`` pinned for the gap, priced at the memory's
    opportunity cost (:data:`KEEPWARM_BYTE_S_COST`).  The fleet driver keeps
    an instance warm exactly when this verdict says so, and holds it for at
    most the expected gap — an instance whose function went quiet is
    reclaimed at expiry, Azure-Functions keep-alive style.
    """
    benefit_s = max(0.0, restore_s - WARM_RESUME_S)
    hold_cost_s = expected_gap_s * resident_bytes * KEEPWARM_BYTE_S_COST
    rate = resident_bytes * KEEPWARM_BYTE_S_COST
    return {
        "benefit_s": benefit_s,
        "hold_cost_s": hold_cost_s,
        "net_s": benefit_s - hold_cost_s,
        "break_even_gap_s": benefit_s / rate if rate > 0 else float("inf"),
        "worthwhile": bool(benefit_s > hold_cost_s),
    }


def recuration_benefit_s(n_promote: int, n_demote: int,
                         expected_restores: int = 64) -> float:
    """Modeled seconds saved over ``expected_restores`` future restores if
    ``n_promote`` hot-faulting cold pages move into the CXL hot region and
    ``n_demote`` never-touched hot pages move out to RDMA.

    Per restore:

    * each promoted page stops paying the demand-fault path
      (trap + synchronous-feeling RDMA read + per-page uffd.copy) and
      instead rides the chunked CXL pre-install (amortized op latency +
      bandwidth + its share of a batched uffd.copy);
    * each demoted page stops being pre-installed at all (it was never
      touched, so it costs nothing after demotion).
    """
    if expected_restores <= 0:
        return 0.0
    promote_now = n_promote * (FAULT_TRAP_S + RDMA_PAGE_READ_S
                               + UFFD_COPY_PER_PAGE_S)
    promote_after = (_cxl_chunks(n_promote) + uffd_copy_batch_cost(n_promote)
                     if n_promote else 0.0)
    demote_saved = ((_cxl_chunks(n_demote) + uffd_copy_batch_cost(n_demote))
                    if n_demote else 0.0)
    per_restore = (promote_now - promote_after) + demote_saved
    return per_restore * expected_restores


def recuration_cost_s(regions) -> float:
    """Modeled cost of one re-curation rebuild: the owner materializes the
    full image (hot region streamed from CXL, cold region bulk-read from
    RDMA), rewrites both data regions, and republishes through the
    ownership protocol (tombstone + drain + catalog writes ~ one RDMA RPC
    budget).  Zero pages are free in both directions."""
    hot_pages = regions.n_hot
    cold_pages = regions.n_cold
    cold_payload = (regions.cold_bytes if regions.cold_compressed
                    else cold_pages * PAGE_SIZE)
    read = _cxl_chunks(hot_pages) + _shared(
        -(-cold_pages // RDMA_INFLIGHT) * RDMA_LAT_S
        + cold_payload / RDMA_BW, cold_payload, RDMA_BW, 1)
    # rewrite: every non-zero page crosses a link once more (hot→CXL write,
    # cold→RDMA write; promoted/demoted pages just swap which link)
    write = _cxl_chunks(hot_pages) + _shared(
        -(-cold_pages // RDMA_INFLIGHT) * RDMA_LAT_S
        + cold_payload / RDMA_BW, cold_payload, RDMA_BW, 1)
    return read + write + SNAPSHOT_API_S


def recuration_economics(regions, plan, expected_restores: int = 64) -> Dict[str, float]:
    """Break-even model gating re-curation (the analytic twin the
    ``PoolMaster.recurate`` pipeline consults): rebuild only when the
    modeled fault-latency savings over the snapshot's expected remaining
    restores exceed the modeled rebuild cost."""
    benefit = recuration_benefit_s(int(plan.promote.size), int(plan.demote.size),
                                   expected_restores)
    cost = recuration_cost_s(regions)
    return {
        "benefit_s": benefit,
        "cost_s": cost,
        "net_s": benefit - cost,
        "expected_restores": float(expected_restores),
        "worthwhile": bool(benefit > cost),
    }


def interpod_bulk_read_s(n_pages: int, conc: int = 1) -> float:
    """Pipelined one-sided reads over the inter-pod fabric (RNIC + one
    switch hop): the chunked hot pre-install repriced for a replica that
    lives in another pod.  ``conc`` distinct streams share the RNIC."""
    if n_pages <= 0:
        return 0.0
    serial = (-(-n_pages // INTER_POD_INFLIGHT) * INTER_POD_LAT_S
              + n_pages * PAGE_SIZE / INTER_POD_BW)
    return _shared(serial, n_pages * PAGE_SIZE, INTER_POD_BW, conc)


def interpod_hot_penalty_s(n_hot_pages: int, conc: int = 1) -> float:
    """Extra modeled seconds a restore pays when its hot set must cross the
    inter-pod fabric instead of the local pod's CXL link — the surcharge the
    pod-aware placement score applies to hosts whose pod holds no replica
    (replica distance 1) or whose MHD ports are exhausted (attach
    fallthrough).  Never negative: CXL is the faster path by construction."""
    if n_hot_pages <= 0:
        return 0.0
    return max(0.0, interpod_bulk_read_s(n_hot_pages, conc)
               - _cxl_chunks(n_hot_pages, conc))


def migration_economics(hot_bytes: int, cold_bytes: int,
                        expected_reads: int, conc: int = 1) -> Dict[str, float]:
    """Break-even model gating snapshot replication/migration toward demand
    (the analytic twin ``topology.MigrationManager`` consults).

    Benefit: each of the next ``expected_reads`` restores from the demanding
    pod stops paying the inter-pod hot penalty and reads intra-pod CXL.
    Cost: the snapshot's payload crosses the inter-pod fabric once (hot +
    cold), is rewritten into the target pod's tiers, and republishes through
    the ownership protocol (~ one snapshot-API budget) — the same shape as
    :func:`recuration_cost_s` with the read side repriced inter-pod."""
    n_hot = int(hot_bytes) // PAGE_SIZE
    n_cold = int(cold_bytes) // PAGE_SIZE
    per_read = interpod_hot_penalty_s(n_hot, conc)
    benefit = per_read * max(0, int(expected_reads))
    copy_read = interpod_bulk_read_s(n_hot + n_cold)
    copy_write = _cxl_chunks(n_hot) + _rdma_bulk(n_cold)
    cost = copy_read + copy_write + SNAPSHOT_API_S
    return {
        "benefit_s": benefit,
        "cost_s": cost,
        "net_s": benefit - cost,
        "per_read_saving_s": per_read,
        "break_even_reads": (cost / per_read if per_read > 0
                             else float("inf")),
        "worthwhile": bool(benefit > cost),
    }


def verify_restore_correctness(pool: HierarchicalPool, reader: SnapshotReader,
                               spec: WorkloadSpec) -> bool:
    """Real-data check: a full Aquifer restore reproduces the image bits."""
    inst = Instance(StateImage.empty_like(spec.image.manifest))
    eng = RestoreEngine(reader, inst, rdma_engine=None)
    eng.pre_install_hot()
    eng.install_all_sync()
    return bool(np.array_equal(inst.image.buf, spec.image.buf))
