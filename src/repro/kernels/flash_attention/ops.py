"""jit'd attention entry point: Pallas flash kernel on TPU, oracle elsewhere.

The model layer calls `attention(...)`; on this CPU container it resolves to
the jnp oracle (identical numerics modulo fp reassociation), on TPU to the
Pallas kernel.  `use_pallas=True, interpret=True` forces kernel-in-Python
validation (tests).
"""
import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref, chunked_attention_ref

# Above this KV length the non-Pallas path uses the chunked online-softmax
# formulation so compile-time memory/cost analysis matches the TPU kernel.
CHUNKED_THRESHOLD = 2048


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    block_q: int = 512, block_k: int = 512,
                    use_pallas: bool | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        if k.shape[2] > CHUNKED_THRESHOLD:
            return chunked_attention_ref(q, k, v, causal=causal, scale=scale,
                                         block_k=block_k)
        return attention_ref(q, k, v, causal=causal, scale=scale)
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
