"""Pallas TPU kernel: blocked online-softmax (Flash) attention with GQA.

Training/prefill hot spot for the full-attention architectures.  Standard
TPU formulation (cf. jax.experimental.pallas.ops.tpu.flash_attention):

  grid = (batch, q_heads, Sq/bq, Skv/bk), kv axis innermost & "arbitrary"
  scratch: f32 acc (bq, Dv), running max m and sum l stored replicated as
  (bq, 128) tiles (TPU VREG lane width).

Causal handling is two-level: whole kv-blocks strictly above the diagonal
are skipped via pl.when (no FLOPs, no DMA wait), the diagonal block applies
an element mask.  GQA is free: the K/V BlockSpec index_map maps q-head h to
kv-head h // group, so K/V tiles for a group are fetched once per q-head
(the pipeline caches the revisit).

Block sizes default to (bq, bk) = (512, 512): VMEM ≈ bq*Dk(q) + bk*(Dk+Dv)
+ bq*Dv f32 acc ≈ 1.6 MiB at D=128 — comfortably inside 16 MiB VMEM with
double buffering, and MXU-aligned (multiples of 128).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, causal: bool, scale: float, bq: int, bk: int,
                  nk: int, kv_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv block strictly above the diagonal contributes nothing.
    # q row global pos = iq*bq + r + kv_offset ; kv col global pos = ik*bk + c
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, Dk)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, Dk)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                         # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + kv_offset
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_scr[:, 0]                              # (bq,)
        m_cur = s.max(axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                   # rescale of old acc
        p = jnp.exp(s - m_new[:, None])                   # (bq, bk)
        l_new = alpha * l_scr[:, 0] + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    if causal:
        block_relevant = ik * bk <= iq * bq + (bq - 1) + kv_offset
        pl.when(block_relevant)(_compute)
        last_ik = jnp.minimum(nk - 1, (iq * bq + (bq - 1) + kv_offset) // bk)
    else:
        _compute()
        last_ik = nk - 1

    @pl.when(ik == last_ik)
    def _finalize():
        l = l_scr[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q, k, v, *, causal: bool = True, scale: float | None = None,
    block_q: int = 512, block_k: int = 512, interpret: bool = False,
):
    """q: (B, Hq, Sq, Dk); k/v: (B, Hkv, Skv, Dk/Dv) -> (B, Hq, Sq, Dv)."""
    b, hq, sq, dk = q.shape
    hkv, skv, dv = k.shape[1], k.shape[2], v.shape[3]
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = dk ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nq, nk = sq // bq, skv // bk
    kv_offset = skv - sq  # suffix-aligned causal (supports chunked prefill)

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=float(scale),
        bq=bq, bk=bk, nk=nk, kv_offset=kv_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dk), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, dk), lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, dv), lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
