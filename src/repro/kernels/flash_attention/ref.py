"""Pure-jnp oracles: naive GQA attention (small-shape test oracle) and a
chunked online-softmax formulation (the CPU/compile path for long sequences —
same FLOPs and working-set structure as the Pallas kernel, so dry-run
cost/memory analysis reflects the TPU kernel rather than a naive S×S blowup).
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, Sq, Dk); k: (B, Hkv, Skv, Dk); v: (B, Hkv, Skv, Dv).

    Hq must be a multiple of Hkv (grouped-query attention).
    Returns (B, Hq, Sq, Dv) in q.dtype; softmax in f32.
    """
    b, hq, sq, dk = q.shape
    hkv, skv, dv = k.shape[1], k.shape[2], v.shape[3]
    group = hq // hkv
    if scale is None:
        scale = dk ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if causal:
        # query i attends to kv positions <= i + (skv - sq)  (suffix alignment)
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention_ref(q, k, v, *, causal: bool = True,
                          scale: float | None = None, block_k: int = 512):
    """Online-softmax attention scanning KV in blocks (flash-style, pure jnp).

    q: (B, Hq, Sq, Dk); k/v: (B, Hkv, Skv, Dk/Dv) -> (B, Hq, Sq, Dv).
    Peak intermediate is (B, Hq, Sq, block_k) instead of (B, Hq, Sq, Skv).
    """
    b, hq, sq, dk = q.shape
    hkv, skv, dv = k.shape[1], k.shape[2], v.shape[3]
    group = hq // hkv
    if scale is None:
        scale = dk ** -0.5
    pad = (-skv) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k.shape[2] // block_k
    kb = k.reshape(b, hkv, nk, block_k, dk).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nk, block_k, dv).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32) * scale
    qpos = jnp.arange(sq) + (skv - sq)

    def step(carry, inp):
        m, l, acc = carry
        ik, kblk, vblk = inp
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)
        kk = jnp.repeat(kf, group, axis=1)
        vv = jnp.repeat(vf, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kk)
        kpos = ik * block_k + jnp.arange(block_k)
        invalid = kpos[None, :] >= skv  # padding
        if causal:
            invalid = invalid | (kpos[None, :] > qpos[:, None])
        s = jnp.where(invalid[None, None], -1e30, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = alpha * l + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vv)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    a0 = jnp.zeros((b, hq, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
