"""jit'd wrapper: pad-to-block, dispatch Pallas on TPU / interpret elsewhere."""
import jax
import jax.numpy as jnp
import numpy as np

from .kernel import zero_detect_pallas
from .ref import zero_detect_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def zero_detect(pages, *, block_pages: int = 256, use_pallas: bool | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """int32[n_pages] zero-page bitmap; pads ragged tails with a nonzero
    sentinel so padding never reports zero."""
    pages = jnp.asarray(pages)
    n = pages.shape[0]
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return zero_detect_ref(pages)
    if interpret is None:
        interpret = not _on_tpu()
    pad = (-n) % block_pages
    if pad:
        filler = jnp.ones((pad, pages.shape[1]), dtype=pages.dtype)
        pages = jnp.concatenate([pages, filler], axis=0)
    out = zero_detect_pallas(pages, block_pages=block_pages, interpret=interpret)
    return out[:n]


def zero_bitmap_numpy(buf: np.ndarray, page_bytes: int = 4096) -> np.ndarray:
    """Host-side fast path used by core/ when no accelerator is attached."""
    mat = buf.reshape(-1, page_bytes)
    return ~mat.any(axis=1)
