"""Pure-jnp oracle for zero-page detection."""
import jax.numpy as jnp


def zero_detect_ref(pages: jnp.ndarray) -> jnp.ndarray:
    """pages: (n_pages, page_elems) any dtype -> int32[n_pages], 1 where the
    page is entirely zero (bitwise: we compare values to 0, which matches the
    paper's byte-walk because state buffers are IEEE arrays where +0.0 is the
    all-zero pattern; -0.0 is treated as zero content by design)."""
    return (pages == 0).all(axis=1).astype(jnp.int32)
