"""Pallas TPU kernel: block-wise zero-page detection.

The snapshot walk (§3.2 "first walk all page contents to identify zero
pages") over ~10-100 GB of sharded state is a pure HBM-bandwidth job; on TPU
we tile it so each grid step streams a (block_pages, page_elems) tile
HBM→VMEM and reduces it on the VPU.

Tiling: page_elems is 1024 (f32) / 2048 (bf16) / 4096 (int8) — all multiples
of the 128-lane VREG; block_pages rows of 8 keep the (8, 128) sublane×lane
tile shape aligned.  Default block: (256, page_elems) ≈ 1 MiB f32 in VMEM.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _zero_detect_block(pages_ref, out_ref):
    tile = pages_ref[...]
    nz = (tile != 0).any(axis=1)
    out_ref[...] = jnp.where(nz, 0, 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_pages", "interpret"))
def zero_detect_pallas(pages: jnp.ndarray, *, block_pages: int = 256, interpret: bool = False):
    """pages: (n_pages, page_elems) -> int32[n_pages] (1 = all-zero page).

    n_pages must be a multiple of block_pages (ops.py pads).
    """
    n_pages, page_elems = pages.shape
    assert n_pages % block_pages == 0, (n_pages, block_pages)
    grid = (n_pages // block_pages,)
    return pl.pallas_call(
        _zero_detect_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_pages, page_elems), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_pages,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pages,), jnp.int32),
        interpret=interpret,
    )(pages)
