"""Pallas TPU kernel: per-page polynomial checksum (dedup layer, §3.6).

Streams (block_pages, n_lanes) uint32 tiles HBM→VMEM, multiplies by the
precomputed power-of-P weight vector and row-reduces with wraparound uint32
arithmetic.  Bandwidth-bound like zero_detect; the two walks are fused at the
ops level when dedup is enabled (one HBM pass computes both).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _checksum_block(pages_ref, w_ref, out_ref):
    tile = pages_ref[...]
    w = w_ref[...]
    out_ref[...] = (tile * w[None, :]).sum(axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_pages", "interpret"))
def page_checksum_pallas(pages_u32: jnp.ndarray, weights: jnp.ndarray,
                         *, block_pages: int = 256, interpret: bool = False):
    n_pages, n_lanes = pages_u32.shape
    assert n_pages % block_pages == 0
    grid = (n_pages // block_pages,)
    return pl.pallas_call(
        _checksum_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_pages, n_lanes), lambda i: (i, 0)),
            pl.BlockSpec((n_lanes,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_pages,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pages,), jnp.uint32),
        interpret=interpret,
    )(pages_u32, weights)
