"""Pure-jnp oracle for the per-page polynomial checksum.

TPU-native hash choice (hardware adaptation, DESIGN.md §2): FNV-1a is an
inherently sequential byte fold and TPUs have no 64-bit vector lanes, so the
device kernel uses a *polynomial rolling hash* over uint32 lanes instead:

    h(page) = sum_i lane_i * P^(E-1-i)   (mod 2^32),  P = 0x01000193

which is a single vector multiply + reduction — VPU-shaped.  Same collision
structure as Rabin-Karp; the host-side dedup path (core/dedup.py) keeps
FNV-1a-64 and both are accepted by DedupStore.
"""
import jax.numpy as jnp
import numpy as np

POLY_P = np.uint32(0x01000193)  # FNV prime reused as the polynomial base


def poly_weights(n_lanes: int) -> jnp.ndarray:
    """uint32[ n_lanes ] = [P^(n-1), ..., P, 1] mod 2^32."""
    w = np.empty(n_lanes, dtype=np.uint32)
    acc = np.uint32(1)
    with np.errstate(over="ignore"):
        for i in range(n_lanes - 1, -1, -1):
            w[i] = acc
            acc = acc * POLY_P
    return jnp.asarray(w)


def page_checksum_ref(pages_u32: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """pages_u32: (n_pages, n_lanes) uint32 -> uint32[n_pages]."""
    return (pages_u32 * weights[None, :]).sum(axis=1, dtype=jnp.uint32)
