"""jit'd wrapper for page checksums with CPU fallback."""
import jax
import jax.numpy as jnp
import numpy as np

from .kernel import page_checksum_pallas
from .ref import page_checksum_ref, poly_weights


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def page_checksum(pages_bytes, *, block_pages: int = 256,
                  use_pallas: bool | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """pages_bytes: (n_pages, page_bytes) uint8 -> uint32[n_pages]."""
    arr = np.ascontiguousarray(pages_bytes)
    pages_u32 = jnp.asarray(arr.view(np.uint32).reshape(arr.shape[0], -1))
    w = poly_weights(pages_u32.shape[1])
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return page_checksum_ref(pages_u32, w)
    if interpret is None:
        interpret = not _on_tpu()
    n = pages_u32.shape[0]
    pad = (-n) % block_pages
    if pad:
        pages_u32 = jnp.concatenate(
            [pages_u32, jnp.zeros((pad, pages_u32.shape[1]), jnp.uint32)], axis=0
        )
    out = page_checksum_pallas(pages_u32, w, block_pages=block_pages, interpret=interpret)
    return out[:n]
