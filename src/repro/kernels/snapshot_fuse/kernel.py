"""Pallas TPU kernels: the fused snapshot data plane (DESIGN.md §13).

Two ops replace the piecemeal kernel sequences on Aquifer's byte-moving hot
paths, turning three (publish) / three (restore) HBM sweeps into one each:

``fused_publish_pallas`` — publish sweep.  One blocked pass over the page
matrix emits, per page: the zero bitmap (``zero_detect``), the polynomial
checksum / dedup hash (``page_checksum``), and a compacted gather of the
non-zero pages split hot/cold by the working-set mask (``page_gather`` twice)
— 4 passes' worth of outputs for ONE read of the matrix.  Compaction under
static shapes works because the TPU grid is sequential: running hot/cold
counters live in SMEM scratch and survive across grid steps.  Each block is
locally compacted into VMEM staging rows, then DMA'd to the ANY-space output
at the carried row offset (``pltpu.make_async_copy``); the output is
oversized by one block and garbage tail rows are overwritten by the next
block's copy, so the host slices ``[:count]`` using the SMEM counts output.

``fused_restore_pallas`` — restore pre-install.  Per compact row the kernel
gathers from the streamed CXL chunk (scalar-prefetched ``src_idx`` drives the
input index map), computes the verify checksum from the row already in VMEM
(a free byproduct — the verify pass costs zero extra HBM traffic), and
scatters into the guest frame (``dst_idx`` drives the output index map, dest
donated via ``input_output_aliases`` so untouched rows keep their contents,
mirroring uffd.copy).  Double buffering comes from Pallas's revolving input
buffers over the sequential grid: the HBM→VMEM stream of chunk row *k+1*
overlaps the checksum+scatter of row *k*, so CXL streaming and guest-frame
installs pipeline exactly as §3.4 wants.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _publish_kernel(pages_ref, ws_ref, w_ref, zero_ref, csum_ref, hot_ref,
                    cold_ref, counts_ref, carry, stage_hot, stage_cold, sems):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        carry[0] = 0
        carry[1] = 0

    tile = pages_ref[...]
    nz = (tile != 0).any(axis=1)
    zero_ref[...] = jnp.where(nz, 0, 1).astype(jnp.int32)
    csum_ref[...] = (tile * w_ref[...][None, :]).sum(axis=1, dtype=jnp.uint32)

    ws = ws_ref[...] != 0
    hot_sel = nz & ws
    cold_sel = nz & ~ws
    block = tile.shape[0]

    def body(r, hc):
        h, c = hc
        row = pages_ref[pl.ds(r, 1), :]

        @pl.when(hot_sel[r])
        def _():
            stage_hot[pl.ds(h, 1), :] = row

        @pl.when(cold_sel[r])
        def _():
            stage_cold[pl.ds(c, 1), :] = row

        return (h + hot_sel[r].astype(jnp.int32),
                c + cold_sel[r].astype(jnp.int32))

    k_hot, k_cold = jax.lax.fori_loop(
        0, block, body, (jnp.int32(0), jnp.int32(0)))

    # Copy the FULL staging block to the carried offset: rows past the local
    # count are garbage, but the next block's copy lands on top of them, so
    # only the final tail (sliced away by the host) ever holds stale rows.
    hot_base, cold_base = carry[0], carry[1]
    cp_h = pltpu.make_async_copy(
        stage_hot, hot_ref.at[pl.ds(hot_base, block), :], sems.at[0])
    cp_c = pltpu.make_async_copy(
        stage_cold, cold_ref.at[pl.ds(cold_base, block), :], sems.at[1])
    cp_h.start()
    cp_c.start()
    cp_h.wait()
    cp_c.wait()
    carry[0] = hot_base + k_hot
    carry[1] = cold_base + k_cold
    counts_ref[0] = carry[0]
    counts_ref[1] = carry[1]


@functools.partial(jax.jit, static_argnames=("block_pages", "interpret"))
def fused_publish_pallas(pages_u32: jnp.ndarray, ws_mask: jnp.ndarray,
                         weights: jnp.ndarray, *, block_pages: int = 256,
                         interpret: bool = False):
    """One sweep over ``pages_u32 (N, E)`` (N % block_pages == 0).

    Returns ``(zero int32[N], csum uint32[N], hot (N+block, E),
    cold (N+block, E), counts int32[2])``; the caller slices the compacted
    outputs to ``[:counts[0]]`` / ``[:counts[1]]``.
    """
    n, e = pages_u32.shape
    grid = (n // block_pages,)
    return pl.pallas_call(
        _publish_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_pages, e), lambda i: (i, 0)),
            pl.BlockSpec((block_pages,), lambda i: (i,)),
            pl.BlockSpec((e,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_pages,), lambda i: (i,)),
            pl.BlockSpec((block_pages,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n + block_pages, e), jnp.uint32),
            jax.ShapeDtypeStruct((n + block_pages, e), jnp.uint32),
            jax.ShapeDtypeStruct((2,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((2,), jnp.int32),
            pltpu.VMEM((block_pages, e), jnp.uint32),
            pltpu.VMEM((block_pages, e), jnp.uint32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(pages_u32, ws_mask, weights)


def _restore_kernel(src_ref, dst_ref, chunk_ref, w_ref, dest_ref,
                    out_ref, csum_ref):
    del src_ref, dst_ref, dest_ref  # index maps consumed them; dest aliased
    row = chunk_ref[...]
    out_ref[...] = row
    csum_ref[...] = (row * w_ref[...][None, :]).sum(axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def fused_restore_pallas(dest: jnp.ndarray, chunk: jnp.ndarray,
                         src_idx: jnp.ndarray, dst_idx: jnp.ndarray,
                         weights: jnp.ndarray, *, interpret: bool = False):
    """gather(chunk[src_idx[i]]) → checksum → scatter(dest[dst_idx[i]]).

    dest: (N, E) donated; chunk: (C, E); src_idx/dst_idx: int32[M].
    Returns ``(dest', csum uint32[M])`` with csum in compact (i) order.
    """
    n, e = dest.shape
    m = src_idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, src, dst: (src[i], 0)),
            pl.BlockSpec((e,), lambda i, src, dst: (0,)),
            pl.BlockSpec((1, e), lambda i, src, dst: (dst[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, e), lambda i, src, dst: (dst[i], 0)),
            pl.BlockSpec((1,), lambda i, src, dst: (i,)),
        ],
    )
    return pl.pallas_call(
        _restore_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, e), dest.dtype),
            jax.ShapeDtypeStruct((m,), jnp.uint32),
        ],
        input_output_aliases={4: 0},  # dest (input incl. scalar prefetch) -> out
        interpret=interpret,
    )(src_idx, dst_idx, chunk, weights, dest)
