"""Fused snapshot data-plane kernels (single-sweep publish, verified restore)."""
from .ops import (
    FusedPublishResult,
    FusedScatter,
    fused_publish,
    fused_restore,
    make_fused_publish_fn,
)

__all__ = [
    "FusedPublishResult",
    "FusedScatter",
    "fused_publish",
    "fused_restore",
    "make_fused_publish_fn",
]
