"""Dispatch wrappers for the fused snapshot data plane.

``fused_publish``   — one sweep: zero bitmap + poly checksum/dedup hash +
                      hot/cold compaction.  Plugs into ``build_snapshot``
                      via the ``publish_fn`` seam (``make_fused_publish_fn``).
``fused_restore``   — one kernel: gather-from-chunk → checksum-verify →
                      scatter-into-guest-frame.
``FusedScatter``    — ``fused_restore`` adapted to the serving layer's
                      ``ScatterFn`` signature ``(dest, compact, indices) ->
                      dest``; optionally bound to a snapshot's publish-time
                      checksum table, in which case every installed page is
                      verified in the same kernel invocation that installs it.

CPU fallback is the numpy oracle (in-place, zero-copy for the serving path);
``use_pallas=True`` with ``interpret=True`` runs the real kernels off-TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..page_checksum.ref import poly_weights
from .kernel import fused_publish_pallas, fused_restore_pallas
from .ref import fused_publish_ref, fused_restore_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class ChecksumMismatchError(RuntimeError):
    """A restored page's checksum disagreed with the publish-time record.

    ``bad_pages`` is the structured payload — a 1-D int64 array of the
    failing GUEST page indices — which the serving layer's checksum-repair
    path consumes (``RestoreEngine._install_verified``).  The message stays
    human-readable and truncated no matter how many pages failed.
    """

    MAX_SHOWN = 8

    def __init__(self, pages: np.ndarray):
        self.bad_pages = np.atleast_1d(
            np.asarray(pages, dtype=np.int64)).reshape(-1)
        shown = self.bad_pages[: self.MAX_SHOWN].tolist()
        extra = self.bad_pages.size - len(shown)
        super().__init__(
            f"checksum mismatch on {self.bad_pages.size} restored page(s): "
            f"{shown}{f' (+{extra} more)' if extra > 0 else ''}")

    @property
    def pages(self) -> np.ndarray:
        """Back-compat alias for :attr:`bad_pages`."""
        return self.bad_pages


@dataclasses.dataclass
class FusedPublishResult:
    """One publish sweep's outputs, guest-page order throughout."""

    zero_bitmap: np.ndarray   # bool[N]
    checksums: np.ndarray     # uint32[N] poly hash (== pallas_hash_fn output)
    hot: np.ndarray           # uint8[n_hot, page_bytes], ascending page order
    cold: np.ndarray          # uint8[n_cold, page_bytes], ascending page order


def _as_u32(pages_bytes: np.ndarray) -> np.ndarray:
    arr = np.ascontiguousarray(pages_bytes)
    if arr.dtype != np.uint8:
        arr = arr.view(np.uint8)
    lanes = arr.shape[1] // 4 if arr.ndim == 2 else 0
    return arr.view(np.uint32).reshape(arr.shape[0], lanes)


def fused_publish(pages_bytes: np.ndarray, ws_mask: np.ndarray, *,
                  block_pages: int = 256, use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None) -> FusedPublishResult:
    """pages_bytes: (N, page_bytes) uint8; ws_mask: bool[N] working set."""
    u32 = _as_u32(pages_bytes)
    n, e = u32.shape
    page_bytes = e * 4
    ws = np.asarray(ws_mask, dtype=bool)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if n == 0 or not use_pallas:
        zero, csum, hot, cold = fused_publish_ref(u32, ws)
        return FusedPublishResult(zero, csum,
                                  hot.view(np.uint8), cold.view(np.uint8))
    if interpret is None:
        interpret = not _on_tpu()
    pad = (-n) % block_pages
    u32_p, ws_p = u32, ws
    if pad:
        # zero filler: padded rows read as zero pages, so they are excluded
        # from both compactions; the bitmap/checksum tails are sliced off
        u32_p = np.concatenate([u32, np.zeros((pad, e), np.uint32)], axis=0)
        ws_p = np.concatenate([ws, np.zeros(pad, bool)])
    zero_i32, csum, hot, cold, counts = fused_publish_pallas(
        jnp.asarray(u32_p), jnp.asarray(ws_p.astype(np.int32)),
        poly_weights(e), block_pages=block_pages, interpret=interpret)
    counts = np.asarray(counts)
    n_hot, n_cold = int(counts[0]), int(counts[1])
    result = FusedPublishResult(
        np.asarray(zero_i32[:n]) != 0,
        np.asarray(csum[:n]),
        np.asarray(hot[:n_hot]).view(np.uint8).reshape(n_hot, page_bytes),
        np.asarray(cold[:n_cold]).view(np.uint8).reshape(n_cold, page_bytes),
    )
    nz = ~result.zero_bitmap
    assert n_hot == int(np.count_nonzero(nz & ws)), "hot count drifted"
    assert n_cold == int(np.count_nonzero(nz & ~ws)), "cold count drifted"
    return result


# build_snapshot's publish_fn seam: (pages_matrix uint8[N, PAGE_SIZE],
# ws bool[N]) -> FusedPublishResult
PublishFn = Callable[[np.ndarray, np.ndarray], FusedPublishResult]


def make_fused_publish_fn(*, block_pages: int = 256,
                          use_pallas: Optional[bool] = None,
                          interpret: Optional[bool] = None) -> PublishFn:
    def publish_fn(pages_matrix: np.ndarray, ws: np.ndarray) -> FusedPublishResult:
        return fused_publish(pages_matrix, ws, block_pages=block_pages,
                             use_pallas=use_pallas, interpret=interpret)

    return publish_fn


def fused_restore(dest: np.ndarray, compact: np.ndarray, indices: np.ndarray,
                  *, src_indices: Optional[np.ndarray] = None,
                  expected_csums: Optional[np.ndarray] = None,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None):
    """Install ``compact[src_indices[i]]`` at ``dest[indices[i]]`` and return
    ``(dest', csums uint32[M])``; raises :class:`ChecksumMismatchError` when
    ``expected_csums`` (aligned with ``indices``) disagree.  The CPU path
    updates ``dest`` in place and returns the same object."""
    indices = np.asarray(indices, dtype=np.int32)
    m = indices.shape[0]
    if src_indices is None:
        src_indices = np.arange(m, dtype=np.int32)
    else:
        src_indices = np.asarray(src_indices, dtype=np.int32)
    if m == 0:
        return dest, np.zeros(0, np.uint32)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        dest_u32 = _as_u32(dest) if isinstance(dest, np.ndarray) else _as_u32(np.asarray(dest))
        out_u32, csums = fused_restore_ref(dest_u32, _as_u32(compact),
                                           src_indices, indices)
        out = dest if isinstance(dest, np.ndarray) else out_u32.view(np.uint8)
    else:
        if interpret is None:
            interpret = not _on_tpu()
        e = _as_u32(compact).shape[1]
        out_u32, csums = fused_restore_pallas(
            jnp.asarray(_as_u32(np.asarray(dest))), jnp.asarray(_as_u32(compact)),
            jnp.asarray(src_indices), jnp.asarray(indices),
            poly_weights(e), interpret=interpret)
        out = np.asarray(out_u32).view(np.uint8).reshape(np.asarray(dest).shape)
        csums = np.asarray(csums)
    if expected_csums is not None:
        bad = np.asarray(csums) != np.asarray(expected_csums, dtype=np.uint32)
        if bad.any():
            raise ChecksumMismatchError(indices[bad])
    return out, np.asarray(csums)


class FusedScatter:
    """``ScatterFn``-shaped adapter over :func:`fused_restore`.

    Drop-in for the serving layer's scatter seam (``Instance``,
    ``RestoreEngine``, ``NodePageServer.attach``, ``Orchestrator``): the
    call signature stays ``(dest, compact, indices) -> dest``.  When bound
    to a snapshot's guest-indexed publish-time checksum table
    (:meth:`bind_checksums` — ``RestoreEngine.__init__`` does this when the
    reader's regions carry one), every batch is verified against
    ``table[indices]`` inside the same fused invocation that installs it.
    Bound copies share the template's ``stats`` dict so fan-out totals stay
    observable in one place.
    """

    def __init__(self, *, use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 expected: Optional[np.ndarray] = None,
                 stats: Optional[dict] = None):
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.expected = None if expected is None else np.asarray(expected, np.uint32)
        self.stats = stats if stats is not None else {
            "batches": 0, "pages": 0, "pages_verified": 0}

    def bind_checksums(self, table: np.ndarray) -> "FusedScatter":
        return FusedScatter(use_pallas=self.use_pallas, interpret=self.interpret,
                            expected=table, stats=self.stats)

    def __call__(self, dest: np.ndarray, compact: np.ndarray,
                 indices: np.ndarray) -> np.ndarray:
        idx = np.asarray(indices)
        exp = self.expected[idx] if self.expected is not None else None
        out, _csums = fused_restore(dest, compact, idx, expected_csums=exp,
                                    use_pallas=self.use_pallas,
                                    interpret=self.interpret)
        self.stats["batches"] += 1
        self.stats["pages"] += int(idx.size)
        if exp is not None:
            self.stats["pages_verified"] += int(idx.size)
        return out
