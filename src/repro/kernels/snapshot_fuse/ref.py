"""Numpy oracles for the fused snapshot data plane.

These are the A/B references the fused kernels must match bit-for-bit: the
publish oracle is literally the piecemeal pipeline (zero scan → poly
checksum → two fancy-index gathers), the restore oracle the piecemeal
gather → checksum → scatter.  The checksum is the same polynomial rolling
hash as ``kernels/page_checksum`` (shared weights), so a fused publish's
checksum column doubles as the dedup hash behind ``DedupStore``'s
``hash_fn`` seam.
"""

import numpy as np

from ..page_checksum.ref import poly_weights


def checksum_u32_ref(pages_u32: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """uint32[N] polynomial checksum over u32 lanes (numpy, wrap-around)."""
    with np.errstate(over="ignore"):
        acc = np.zeros(pages_u32.shape[0], dtype=np.uint32)
        w = np.asarray(weights, dtype=np.uint32)
        for j in range(pages_u32.shape[1]):
            acc += pages_u32[:, j] * w[j]
    return acc


def fused_publish_ref(pages_u32: np.ndarray, ws_mask: np.ndarray):
    """The piecemeal sequence, as one function: returns
    ``(zero_bitmap bool[N], csum uint32[N], hot (H, E), cold (C, E))``
    with hot/cold compacted in ascending page order."""
    pages_u32 = np.asarray(pages_u32)
    nz = pages_u32.any(axis=1)
    csum = checksum_u32_ref(pages_u32, np.asarray(poly_weights(pages_u32.shape[1])))
    ws = np.asarray(ws_mask, dtype=bool)
    hot_idx = np.nonzero(nz & ws)[0]
    cold_idx = np.nonzero(nz & ~ws)[0]
    return ~nz, csum, pages_u32[hot_idx], pages_u32[cold_idx]


def fused_restore_ref(dest_u32: np.ndarray, chunk_u32: np.ndarray,
                      src_idx: np.ndarray, dst_idx: np.ndarray):
    """In-place gather → checksum → scatter; returns ``(dest, csum[M])``."""
    dest_u32 = np.asarray(dest_u32)
    rows = np.asarray(chunk_u32)[np.asarray(src_idx, dtype=np.int64)]
    csum = checksum_u32_ref(rows, np.asarray(poly_weights(rows.shape[1])))
    dest_u32[np.asarray(dst_idx, dtype=np.int64)] = rows
    return dest_u32, csum
