"""jit'd wrapper for page scatter with CPU fallback."""
import jax
import jax.numpy as jnp

from .kernel import page_scatter_pallas
from .ref import page_scatter_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def page_scatter(dest, compact, indices, *, use_pallas: bool | None = None,
                 interpret: bool | None = None) -> jnp.ndarray:
    dest = jnp.asarray(dest)
    compact = jnp.asarray(compact)
    indices = jnp.asarray(indices, dtype=jnp.int32)
    if indices.shape[0] == 0:
        return dest
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return page_scatter_ref(dest, compact, indices)
    if interpret is None:
        interpret = not _on_tpu()
    return page_scatter_pallas(dest, compact, indices, interpret=interpret)
