"""Pure-jnp oracle for page scatter (restore pre-install)."""
import jax.numpy as jnp


def page_scatter_ref(dest: jnp.ndarray, compact: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """dest: (N, E); compact: (M, E); indices: int32[M] -> dest with
    dest[indices[i]] = compact[i] (indices unique)."""
    return dest.at[indices].set(compact)
