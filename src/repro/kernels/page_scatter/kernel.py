"""Pallas TPU kernel: scatter compact pages into the instance image (§3.4).

The device-side bulk analogue of hot-set pre-installation: M compacted pages
stream VMEM→HBM into their guest page slots.  The destination image is
donated (input_output_aliases) so unwritten pages keep their prior contents —
the kernel only touches the scattered rows, mirroring uffd.copy semantics
(private copy, pool source untouched).

Scalar-prefetched indices drive the *output* BlockSpec's index_map.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(idx_ref, compact_ref, dest_ref, out_ref):
    del idx_ref, dest_ref  # dest is aliased to out; untouched rows persist
    out_ref[...] = compact_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def page_scatter_pallas(dest: jnp.ndarray, compact: jnp.ndarray, indices: jnp.ndarray,
                        *, interpret: bool = False):
    """dest: (N, E) donated; compact: (M, E); indices: int32[M] -> updated dest."""
    n, e = dest.shape
    m = compact.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, idx_ref: (i, 0)),          # compact row i
            pl.BlockSpec((1, e), lambda i, idx_ref: (idx_ref[i], 0)),  # dest row idx[i]
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, e), dest.dtype),
        input_output_aliases={2: 0},  # alias dest (input incl. scalar prefetch) -> output
        interpret=interpret,
    )(indices, compact, dest)
