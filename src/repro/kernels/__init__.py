"""Pallas TPU kernels for Aquifer-JAX's compute hot-spots.

Snapshot pipeline (the paper's data-plane, rethought as device-side
bandwidth-bound walks over sharded state — DESIGN.md §7):
  - zero_detect    : zero-page bitmap (snapshot build walk, §3.2)
  - page_gather    : compact hot/cold regions by offset array (§3.2)
  - page_scatter   : bulk pre-install into the instance image (§3.4)
  - page_checksum  : per-page polynomial hash for dedup (§3.6)

Model hot-spot:
  - flash_attention: blocked online-softmax GQA attention

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with CPU fallback), ref.py (pure-jnp oracle).  All kernels are
validated in interpret mode against their oracle over shape/dtype sweeps
(tests/test_kernels.py).
"""
from .zero_detect.ops import zero_detect
from .page_gather.ops import page_gather
from .page_scatter.ops import page_scatter
from .page_checksum.ops import page_checksum
from .flash_attention.ops import flash_attention

__all__ = ["zero_detect", "page_gather", "page_scatter", "page_checksum", "flash_attention"]
