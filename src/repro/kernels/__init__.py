"""Pallas TPU kernels for Aquifer-JAX's compute hot-spots.

Snapshot pipeline (the paper's data-plane, rethought as device-side
bandwidth-bound walks over sharded state — DESIGN.md §7):
  - zero_detect    : zero-page bitmap (snapshot build walk, §3.2)
  - page_gather    : compact hot/cold regions by offset array (§3.2)
  - page_scatter   : bulk pre-install into the instance image (§3.4)
  - page_checksum  : per-page polynomial hash for dedup (§3.6)

Fused data plane (DESIGN.md §13) — the piecemeal sweeps above, one pass each:
  - fused_publish  : zero bitmap + checksum/dedup hash + hot/cold compaction
  - fused_restore  : gather-from-chunk → checksum-verify → scatter (FusedScatter
                     adapts it to the serving layer's ScatterFn seam)

Model hot-spot:
  - flash_attention: blocked online-softmax GQA attention

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with CPU fallback), ref.py (pure-jnp oracle).  All kernels are
validated in interpret mode against their oracle over shape/dtype sweeps
(tests/test_kernels.py).
"""
from .zero_detect.ops import zero_detect
from .page_gather.ops import page_gather
from .page_scatter.ops import page_scatter
from .page_checksum.ops import page_checksum
from .flash_attention.ops import flash_attention
from .snapshot_fuse.ops import (
    FusedPublishResult,
    FusedScatter,
    fused_publish,
    fused_restore,
    make_fused_publish_fn,
)

__all__ = [
    "zero_detect", "page_gather", "page_scatter", "page_checksum",
    "flash_attention", "fused_publish", "fused_restore", "FusedScatter",
    "FusedPublishResult", "make_fused_publish_fn",
]
