"""Pure-jnp oracle for page gather (snapshot compaction)."""
import jax.numpy as jnp


def page_gather_ref(pages: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """pages: (N, E); indices: int32[M] -> (M, E) compacted pages."""
    return jnp.take(pages, indices, axis=0)
