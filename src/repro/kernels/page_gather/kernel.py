"""Pallas TPU kernel: gather pages by index (snapshot compaction, §3.2).

Building the compact hot/cold data regions is a gather of M pages out of an
N-page sharded state image.  The page index list is **scalar-prefetched**
(PrefetchScalarGridSpec) so the pipeline can issue the HBM→VMEM DMA for page
``idx[i+1]`` while page ``idx[i]`` is being written back — random-access
reads become overlapped streaming.

One grid step moves `rows_per_step` index-contiguous output rows; the input
BlockSpec picks the source page per step via the prefetched index ref.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, pages_ref, out_ref):
    del idx_ref
    out_ref[...] = pages_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather_pallas(pages: jnp.ndarray, indices: jnp.ndarray, *, interpret: bool = False):
    """pages: (N, E); indices: int32[M] -> (M, E)."""
    n, e = pages.shape
    (m,) = indices.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, e), pages.dtype),
        interpret=interpret,
    )(indices, pages)
