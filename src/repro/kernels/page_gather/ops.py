"""jit'd wrapper for page gather with CPU fallback."""
import jax
import jax.numpy as jnp

from .kernel import page_gather_pallas
from .ref import page_gather_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def page_gather(pages, indices, *, use_pallas: bool | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    pages = jnp.asarray(pages)
    indices = jnp.asarray(indices, dtype=jnp.int32)
    if indices.shape[0] == 0:
        return jnp.zeros((0, pages.shape[1]), pages.dtype)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return page_gather_ref(pages, indices)
    if interpret is None:
        interpret = not _on_tpu()
    return page_gather_pallas(pages, indices, interpret=interpret)
