"""Discrete-event fleet-serving driver: traffic in, cold-start tail out.

``FleetDriver`` replays an arrival :class:`~repro.fleet.arrivals.Trace`
against a pod of :class:`~repro.fleet.placement.HostState` hosts on a
single event heap (the batched-serving loop idiom: pop the next completion
or arrival, update state, push the consequences).  Time is modeled — every
duration comes from a :class:`~repro.fleet.model.RestoreProfile` priced
under the host's conditions at dispatch — and the injected
:class:`~repro.sim.clock.VirtualClock` is advanced to each event so any
clock-reading component observes a consistent timeline.

Per invocation the driver resolves, in order:

1. **warm hit** — a kept-warm instance of the same function on any alive
   host with a free slot resumes in ``WARM_RESUME_S``;
2. **placement** — the :class:`PlacementScheduler` picks a host; with a
   free slot the restore starts, otherwise the invocation queues FIFO;
3. **restore pricing** — joining an in-flight same-snapshot fan-out group
   costs install-only and finishes with the group; a fresh restore pays
   ``profile.cold_start_s(conc, overlap)`` where ``conc`` counts the
   host's distinct active groups and ``overlap`` its chunk-cache coverage;
4. **keep-warm** — on completion, ``strategies.keepwarm_economics`` prices
   holding the instance for its expected inter-arrival gap against
   re-restoring; worthwhile instances stay resident until a warm hit or
   expiry.

Host crashes (``crash_at``) kill a host mid-trace: its queued and
in-flight invocations are re-placed on the survivors and restored from
scratch (pool state is durable; only the host's private mappings die).
An optional :class:`~repro.fleet.autoscale.QueueAutoscaler` grows/shrinks
the pod on backlog.  Everything is deterministic per (trace, seed).
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.clock import Clock, REAL_CLOCK
from ..serve.strategies import WARM_RESUME_S, keepwarm_economics
from .arrivals import FunctionType, Trace
from .autoscale import QueueAutoscaler
from .model import RestoreProfile
from .placement import HostState, PlacementScheduler

# event kinds, ordered so same-timestamp events resolve deterministically:
# finish work before expiring warm instances before admitting new arrivals
EV_RESTORE_DONE = 0
EV_COMPUTE_DONE = 1
EV_WARM_EXPIRE = 2
EV_CRASH = 3
EV_ARRIVAL = 4

MODE_COLD = 0      # paid a full (possibly overlap-discounted) restore
MODE_JOIN = 1      # joined an in-flight fan-out group, install-only
MODE_WARM = 2      # resumed a kept-warm instance


@dataclasses.dataclass
class FleetResult:
    """Per-invocation outcome arrays plus run-level counters."""

    arrival_s: np.ndarray        # trace arrival time
    ready_s: np.ndarray          # instance ready to execute (NaN if lost)
    done_s: np.ndarray           # execution finished (NaN if lost)
    host: np.ndarray             # final host id (-1 if never placed)
    mode: np.ndarray             # MODE_* of the attempt that succeeded
    restarts: np.ndarray         # crash-induced re-placements
    fn: np.ndarray
    counters: Dict[str, int]
    host_peak: int
    inflight_peak: int

    def cold_start(self) -> np.ndarray:
        """ready - arrival per completed invocation (queue wait included)."""
        ok = ~np.isnan(self.ready_s)
        return (self.ready_s - self.arrival_s)[ok]

    def summary(self) -> Dict[str, float]:
        cs = self.cold_start()
        done = ~np.isnan(self.done_s)
        span = float(self.done_s[done].max() - self.arrival_s.min()) \
            if done.any() else 0.0
        out = {
            "invocations": int(self.arrival_s.size),
            "completed": int(done.sum()),
            "throughput_rps": float(done.sum() / span) if span > 0 else 0.0,
            "p50_cold_start_s": float(np.percentile(cs, 50)) if cs.size else 0.0,
            "p99_cold_start_s": float(np.percentile(cs, 99)) if cs.size else 0.0,
            "mean_cold_start_s": float(cs.mean()) if cs.size else 0.0,
            "warm_frac": float((self.mode == MODE_WARM).mean()) if cs.size else 0.0,
            "join_frac": float((self.mode == MODE_JOIN).mean()) if cs.size else 0.0,
            "host_peak": int(self.host_peak),
            "inflight_peak": int(self.inflight_peak),
        }
        out.update({k: int(v) for k, v in self.counters.items()})
        return out


class FleetDriver:
    """Single-heap discrete-event loop serving a trace against the hosts."""

    def __init__(self, fleet: List[FunctionType],
                 profiles: Dict[int, RestoreProfile],
                 policy: str = "locality", seed: int = 0,
                 n_hosts: int = 8, slots_per_host: int = 64,
                 clock: Optional[Clock] = None,
                 autoscaler: Optional[QueueAutoscaler] = None,
                 keep_warm: bool = True,
                 crash_at: Optional[List[Tuple[float, int]]] = None):
        self.fleet = {f.fn_id: f for f in fleet}
        self.profiles = profiles
        self.scheduler = PlacementScheduler(policy, seed=seed)
        self.clock = clock or REAL_CLOCK
        self.autoscaler = autoscaler
        self.keep_warm = keep_warm
        self.slots_per_host = slots_per_host
        self.hosts: List[HostState] = [
            HostState(i, slots=slots_per_host) for i in range(n_hosts)]
        self._crash_at = list(crash_at or [])
        self._events: List[Tuple[float, int, int, tuple]] = []
        self._seq = 0
        # fn_id -> host ids holding a warm instance (scan-free warm hits)
        self._warm_hosts: Dict[int, set] = {}
        # fn_id -> (worthwhile, gap): the keep-warm verdict depends only on
        # the fn's uncontended restore cost, rate, and resident bytes
        self._keepwarm: Dict[int, Tuple[bool, float]] = {}
        self._total_queued = 0
        self._n_alive = len(self.hosts)
        self.counters = {
            "cold_restores": 0, "joins": 0, "warm_hits": 0,
            "keepwarm_held": 0, "keepwarm_expired": 0,
            "crashes": 0, "crash_requeued": 0,
            "scale_ups": 0, "scale_downs": 0,
        }

    # -- event plumbing ----------------------------------------------------
    def _push(self, t: float, kind: int, *data) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, kind, self._seq, data))

    def _alive(self) -> List[HostState]:
        return [h for h in self.hosts if h.alive]

    # -- the run -----------------------------------------------------------
    def run(self, trace: Trace) -> FleetResult:
        n = len(trace)
        self._arr = trace.t
        self._fn = trace.fn
        self._comp = trace.compute_s
        self._ready = np.full(n, np.nan)
        self._done = np.full(n, np.nan)
        self._host = np.full(n, -1, np.int32)
        self._mode = np.full(n, -1, np.int8)
        self._restarts = np.zeros(n, np.int32)
        self._inflight = 0
        self._inflight_peak = 0
        self._host_peak = len(self.hosts)
        for i in range(n):
            self._push(float(trace.t[i]), EV_ARRIVAL, i)
        for t, host_id in self._crash_at:
            self._push(float(t), EV_CRASH, host_id)
        while self._events:
            t, kind, _seq, data = heapq.heappop(self._events)
            if hasattr(self.clock, "advance_to"):
                self.clock.advance_to(t)
            if kind == EV_ARRIVAL:
                self._on_arrival(t, data[0])
            elif kind == EV_RESTORE_DONE:
                self._on_restore_done(t, *data)
            elif kind == EV_COMPUTE_DONE:
                self._on_compute_done(t, *data)
            elif kind == EV_WARM_EXPIRE:
                self._on_warm_expire(t, *data)
            elif kind == EV_CRASH:
                self._on_crash(t, data[0])
        return FleetResult(
            arrival_s=self._arr, ready_s=self._ready, done_s=self._done,
            host=self._host, mode=self._mode, restarts=self._restarts,
            fn=self._fn, counters=dict(self.counters),
            host_peak=self._host_peak, inflight_peak=self._inflight_peak)

    # -- handlers ----------------------------------------------------------
    def _on_arrival(self, t: float, i: int) -> None:
        self._inflight += 1
        self._inflight_peak = max(self._inflight_peak, self._inflight)
        self._autoscale(t)
        fn = self.fleet[int(self._fn[i])]
        # 1) warm hit: lowest host id with a warm instance AND a free slot
        if self.keep_warm:
            for hid in sorted(self._warm_hosts.get(fn.fn_id, ())):
                h = self.hosts[hid]
                if not h.alive or h.free_slots() <= 0:
                    continue
                dq = h.warm[fn.fn_id]
                dq.popleft()            # consume the oldest warm instance
                if not dq:
                    del h.warm[fn.fn_id]
                    self._warm_unindex(fn.fn_id, hid)
                self.counters["warm_hits"] += 1
                h.busy += 1
                ready = t + WARM_RESUME_S
                self._ready[i] = ready
                self._host[i] = h.host_id
                self._mode[i] = MODE_WARM
                self._push(ready + float(self._comp[i]), EV_COMPUTE_DONE,
                           h.host_id, i)
                return
        self._place(t, i)

    def _warm_unindex(self, fn_id: int, host_id: int) -> None:
        s = self._warm_hosts.get(fn_id)
        if s is not None:
            s.discard(host_id)
            if not s:
                del self._warm_hosts[fn_id]

    def _place(self, t: float, i: int) -> None:
        fn = self.fleet[int(self._fn[i])]
        h = self.scheduler.choose(self.hosts, fn, self.profiles[fn.fn_id])
        if h is None:       # no alive hosts: autoscaler will revive the pod
            self._grow(max(1, self.autoscaler.min_hosts
                           if self.autoscaler else 1))
            h = self.scheduler.choose(self.hosts, fn, self.profiles[fn.fn_id])
        if h.free_slots() > 0:
            self._start_restore(t, h, i)
        else:
            h.queue.append(i)
            self._total_queued += 1

    def _start_restore(self, t: float, h: HostState, i: int) -> None:
        fn = self.fleet[int(self._fn[i])]
        profile = self.profiles[fn.fn_id]
        h.busy += 1
        self._host[i] = h.host_id
        group_finish = h.active_restores.get(fn.name)
        if group_finish is not None:
            # join the in-flight fan-out group: shared reads already in
            # motion, this member pays only its CPU-side installs
            finish = max(group_finish,
                         t + self.scheduler.priced(fn, profile, 1, 0.0,
                                                   joined=True))
            self.counters["joins"] += 1
            self._mode[i] = MODE_JOIN
        else:
            conc = len(h.active_restores) + 1
            finish = (t + self.scheduler.priced(fn, profile, conc,
                                                h.overlap_frac(fn, profile))
                      + self.scheduler.topology_penalty(h, fn, profile, conc))
            if self.scheduler.topology is not None:
                self.scheduler.topology.note_placement(h.host_id, fn.fn_id)
            h.active_restores[fn.name] = finish
            self.counters["cold_restores"] += 1
            self._mode[i] = MODE_COLD
        self._push(finish, EV_RESTORE_DONE, h.host_id, i, fn.name)

    def _on_restore_done(self, t: float, host_id: int, i: int,
                         name: str) -> None:
        h = self.hosts[host_id]
        if not h.alive:
            return              # crash handler already re-placed this one
        # once the group's shared reads are complete there is nothing left
        # to join: late joiners only run their installs past this point
        gf = h.active_restores.get(name)
        if gf is not None and t >= gf:
            h.active_restores.pop(name, None)
        fn = self.fleet[int(self._fn[i])]
        h.add_resident(fn.base_group)
        self._ready[i] = t
        self._push(t + float(self._comp[i]), EV_COMPUTE_DONE, host_id, i)

    def _on_compute_done(self, t: float, host_id: int, i: int) -> None:
        h = self.hosts[host_id]
        if not h.alive:
            return
        self._done[i] = t
        self._inflight -= 1
        h.busy -= 1
        fn = self.fleet[int(self._fn[i])]
        profile = self.profiles[fn.fn_id]
        # the completing instance holds exactly one residency count: cold
        # and join restores added it at restore-done, a warm resume
        # inherited it from the held instance it consumed
        held = False
        if self.keep_warm:
            cached = self._keepwarm.get(fn.fn_id)
            if cached is None:
                gap = 1.0 / max(fn.rate_rps, 1e-9)
                econ = keepwarm_economics(
                    restore_s=profile.cold_start_s(1),
                    expected_gap_s=gap,
                    resident_bytes=profile.hot_bytes + profile.cold_bytes)
                cached = (bool(econ["worthwhile"]), gap)
                self._keepwarm[fn.fn_id] = cached
            worthwhile, gap = cached
            if worthwhile:
                h.warm.setdefault(fn.fn_id, deque()).append(t + gap)
                self._warm_hosts.setdefault(fn.fn_id, set()).add(host_id)
                self.counters["keepwarm_held"] += 1
                self._push(t + gap, EV_WARM_EXPIRE, host_id, fn.fn_id)
                held = True
        if not held:
            h.drop_resident(fn.base_group)
        self._drain_queue(t, h)

    def _on_warm_expire(self, t: float, host_id: int, fn_id: int) -> None:
        h = self.hosts[host_id]
        if not h.alive:
            return
        dq = h.warm.get(fn_id)
        # the warm hit path pops from the left, so expiries and hits stay
        # matched FIFO; an empty deque means every held instance was used
        if dq and dq[0] <= t:
            dq.popleft()
            if not dq:
                del h.warm[fn_id]
                self._warm_unindex(fn_id, host_id)
            self.counters["keepwarm_expired"] += 1
            h.drop_resident(self.fleet[fn_id].base_group)

    def _on_crash(self, t: float, host_id: int) -> None:
        if host_id >= len(self.hosts) or not self.hosts[host_id].alive:
            return
        h = self.hosts[host_id]
        h.alive = False
        self._n_alive -= 1
        self.counters["crashes"] += 1
        for fn_id in h.warm:
            self._warm_unindex(fn_id, host_id)
        # every invocation bound to this host that has not completed is
        # re-placed on the survivors and restored from scratch
        victims = [i for i in range(self._arr.size)
                   if self._host[i] == host_id and np.isnan(self._done[i])]
        victims.extend(h.queue)
        self._total_queued -= len(h.queue)
        h.queue.clear()
        h.active_restores.clear()
        h.warm.clear()
        h.resident_groups.clear()
        h.busy = 0
        for i in sorted(set(victims)):
            self._host[i] = -1
            self._mode[i] = -1
            self._ready[i] = np.nan
            self._restarts[i] += 1
            self.counters["crash_requeued"] += 1
            self._place(t, i)

    # -- pod sizing --------------------------------------------------------
    def _autoscale(self, t: float) -> None:
        if self.autoscaler is None:
            return
        delta = self.autoscaler.decide(t, self._total_queued, self._n_alive)
        if delta > 0:
            self._grow(delta)
            self.counters["scale_ups"] += 1
        elif delta < 0:
            removed = 0
            for h in reversed(self.hosts):
                if removed >= -delta:
                    break
                if h.alive and h.busy == 0 and not h.queue and not h.warm:
                    h.alive = False
                    self._n_alive -= 1
                    removed += 1
            if removed:
                self.counters["scale_downs"] += 1

    def _grow(self, k: int) -> None:
        for _ in range(k):
            self.hosts.append(HostState(len(self.hosts),
                                        slots=self.slots_per_host))
        self._n_alive += k
        self._host_peak = max(self._host_peak, self._n_alive)

    def _drain_queue(self, t: float, h: HostState) -> None:
        while h.queue and h.free_slots() > 0:
            self._total_queued -= 1
            self._start_restore(t, h, h.queue.popleft())
