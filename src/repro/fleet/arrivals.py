"""Seeded request-arrival generators for the fleet-serving layer.

Serverless traces (the Azure Functions characterization, PAPERS.md) are
heavy-tailed and bursty: a few function types dominate traffic, most are
invoked rarely, and per-function arrivals mix steady Poisson, diurnal
cycles, and ON/OFF bursts.  This module synthesizes such a fleet
deterministically from a seed:

* :func:`poisson_arrivals` — homogeneous Poisson over a window;
* :func:`diurnal_arrivals` — inhomogeneous Poisson with a sinusoidal rate
  (thinning over the peak rate), the day/night cycle shrunk to simulated
  seconds;
* :func:`onoff_arrivals` — a two-state Markov-modulated process: bursts at
  a high ON rate separated by exponential OFF silences;
* :func:`synthesize_fleet` — N function types with Zipf-weighted rates,
  patterns assigned round-robin, each mapped to a snapshot variant of one
  of ``n_bases`` base images (the dedup-overlap structure placement
  exploits);
* :func:`generate_trace` — the merged, time-sorted invocation trace with a
  per-invocation compute time, as flat numpy arrays.

Everything is vectorized numpy on simulated seconds (the fleet driver runs
it on a :class:`~repro.sim.clock.VirtualClock` timeline); per-function
streams draw from ``SeedSequence(seed, fn_id)`` so a trace is bit-identical
for a seed regardless of generation order.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

PATTERNS = ("poisson", "diurnal", "onoff")


@dataclasses.dataclass(frozen=True)
class FunctionType:
    """One serverless function type: its snapshot and its traffic shape."""

    fn_id: int
    name: str                   # snapshot name this function restores
    base_group: int             # which base image its snapshot derives from
    rate_rps: float             # long-run mean arrival rate
    pattern: str                # one of PATTERNS
    compute_mean_s: float       # mean modeled execution time per invocation


@dataclasses.dataclass
class Trace:
    """Merged invocation trace (time-sorted, deterministic per seed)."""

    t: np.ndarray               # float64 arrival seconds, non-decreasing
    fn: np.ndarray              # int32 FunctionType.fn_id per invocation
    compute_s: np.ndarray       # float64 modeled execution time per invocation

    def __len__(self) -> int:
        return int(self.t.size)


def poisson_arrivals(rng: np.random.Generator, rate_rps: float,
                     t_end: float, t_start: float = 0.0) -> np.ndarray:
    """Homogeneous Poisson: N ~ Poisson(rate * window), times uniform."""
    window = max(0.0, t_end - t_start)
    n = int(rng.poisson(rate_rps * window))
    if n == 0:
        return np.zeros(0, np.float64)
    return np.sort(rng.uniform(t_start, t_end, n))


def diurnal_arrivals(rng: np.random.Generator, rate_rps: float, t_end: float,
                     period_s: float = 60.0, depth: float = 0.8,
                     t_start: float = 0.0) -> np.ndarray:
    """Inhomogeneous Poisson with rate(t) = rate * (1 + depth sin(2πt/T)),
    sampled by thinning against the peak rate — the day/night cycle of the
    Azure traces shrunk to ``period_s`` simulated seconds."""
    depth = float(np.clip(depth, 0.0, 1.0))
    peak = rate_rps * (1.0 + depth)
    ts = poisson_arrivals(rng, peak, t_end, t_start)
    if ts.size == 0:
        return ts
    lam = rate_rps * (1.0 + depth * np.sin(2.0 * np.pi * ts / period_s))
    keep = rng.uniform(0.0, peak, ts.size) < lam
    return ts[keep]


def onoff_arrivals(rng: np.random.Generator, rate_rps: float, t_end: float,
                   mean_on_s: float = 2.0, mean_off_s: float = 8.0,
                   t_start: float = 0.0) -> np.ndarray:
    """Markov-modulated ON/OFF bursts: exponential ON windows at an elevated
    rate separated by exponential OFF silences.  The ON rate is scaled so
    the long-run mean stays ``rate_rps`` — burstiness changes the shape of
    the arrival process, not the offered load."""
    duty = mean_on_s / (mean_on_s + mean_off_s)
    on_rate = rate_rps / max(duty, 1e-9)
    window = max(0.0, t_end - t_start)
    # enough alternating periods to cover the window with margin
    n_pairs = max(4, int(window / (mean_on_s + mean_off_s) * 3) + 4)
    on_len = rng.exponential(mean_on_s, n_pairs)
    off_len = rng.exponential(mean_off_s, n_pairs)
    # phase: start OFF or ON with duty-cycle probability
    start_on = bool(rng.uniform() < duty)
    durations = np.empty(2 * n_pairs)
    durations[0::2], durations[1::2] = (on_len, off_len) if start_on else (off_len, on_len)
    edges = t_start + np.concatenate(([0.0], np.cumsum(durations)))
    out: List[np.ndarray] = []
    on_slots = range(0, 2 * n_pairs, 2) if start_on else range(1, 2 * n_pairs, 2)
    for i in on_slots:
        a, b = edges[i], min(edges[i + 1], t_end)
        if a >= t_end:
            break
        if b > a:
            out.append(poisson_arrivals(rng, on_rate, b, a))
    if not out:
        return np.zeros(0, np.float64)
    return np.sort(np.concatenate(out))


def zipf_rates(n_types: int, total_rps: float, alpha: float = 1.1) -> np.ndarray:
    """Heavy-tailed per-function rates: rate_i ∝ 1/(i+1)^alpha, normalized
    to ``total_rps`` offered load (the Azure-style skew: a handful of hot
    functions carry most traffic)."""
    w = 1.0 / np.power(np.arange(1, n_types + 1, dtype=np.float64), alpha)
    return total_rps * w / w.sum()


def synthesize_fleet(n_types: int, n_bases: int, total_rps: float,
                     seed: int = 0, alpha: float = 1.1,
                     compute_mean_s: float = 0.25) -> List[FunctionType]:
    """N function types with Zipf rates; type i restores snapshot ``fn{i}``
    derived from base group ``i % n_bases``; patterns round-robin so every
    shape appears at every rate tier.  Compute time scales mildly with rank
    (hot functions tend to be short in the traces)."""
    rates = zipf_rates(n_types, total_rps, alpha)
    rng = np.random.default_rng(np.random.SeedSequence((seed, 0xF1EE7)))
    jitter = rng.uniform(0.6, 1.4, n_types)
    return [
        FunctionType(
            fn_id=i,
            name=f"fn{i}",
            base_group=i % n_bases,
            rate_rps=float(rates[i]),
            pattern=PATTERNS[i % len(PATTERNS)],
            compute_mean_s=float(compute_mean_s * jitter[i]),
        )
        for i in range(n_types)
    ]


def generate_trace(fleet: Sequence[FunctionType], t_end: float, seed: int = 0,
                   burst_mean_on_s: float = 2.0, burst_mean_off_s: float = 8.0,
                   diurnal_period_s: float = 60.0,
                   max_invocations: Optional[int] = None) -> Trace:
    """The merged fleet trace.  Each function's stream (and its compute
    times) draws from ``SeedSequence(seed, fn_id)``, so the trace is
    bit-identical per seed and independent of fleet iteration order; the
    merge sort is stable with fn_id as tiebreak, so simultaneous arrivals
    order deterministically too."""
    ts: List[np.ndarray] = []
    fns: List[np.ndarray] = []
    comps: List[np.ndarray] = []
    for f in fleet:
        rng = np.random.default_rng(np.random.SeedSequence((seed, f.fn_id)))
        if f.pattern == "poisson":
            a = poisson_arrivals(rng, f.rate_rps, t_end)
        elif f.pattern == "diurnal":
            a = diurnal_arrivals(rng, f.rate_rps, t_end,
                                 period_s=diurnal_period_s)
        elif f.pattern == "onoff":
            a = onoff_arrivals(rng, f.rate_rps, t_end,
                               mean_on_s=burst_mean_on_s,
                               mean_off_s=burst_mean_off_s)
        else:
            raise ValueError(f.pattern)
        if a.size == 0:
            continue
        ts.append(a)
        fns.append(np.full(a.size, f.fn_id, np.int32))
        # lognormal around the function's mean (sigma=0.5 → mild tail)
        comps.append(f.compute_mean_s
                     * rng.lognormal(-0.125, 0.5, a.size))
    if not ts:
        return Trace(np.zeros(0), np.zeros(0, np.int32), np.zeros(0))
    t = np.concatenate(ts)
    fn = np.concatenate(fns)
    comp = np.concatenate(comps)
    order = np.lexsort((fn, t))
    t, fn, comp = t[order], fn[order], comp[order]
    if max_invocations is not None and t.size > max_invocations:
        t, fn, comp = t[:max_invocations], fn[:max_invocations], comp[:max_invocations]
    return Trace(t, fn, comp)
