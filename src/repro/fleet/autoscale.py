"""Queue-depth host autoscaling for the fleet driver.

The pod adds restore hosts when the backlog per alive host crosses
``up_queue_per_host`` and retires *empty* hosts (no running work, no queue,
no warm instances) when it falls below ``down_queue_per_host``.  Decisions
are hysteretic — the two thresholds are separated and every action starts a
cooldown window — so a bursty arrival process (the ON/OFF traces) does not
thrash host count.  Purely deterministic: state is (last action time, host
count), inputs are the modeled clock and queue depth.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class QueueAutoscaler:
    """Queue-depth host autoscaling policy (grow on backlog, shrink idle)."""

    min_hosts: int = 4
    max_hosts: int = 256
    up_queue_per_host: float = 8.0     # backlog/host that triggers scale-up
    down_queue_per_host: float = 1.0   # backlog/host that allows scale-down
    step_frac: float = 0.25            # grow/shrink by this fraction of pod
    cooldown_s: float = 2.0
    _last_action_t: float = dataclasses.field(default=-1e18, init=False)

    def decide(self, now: float, queued: int, n_alive: int) -> int:
        """Return the host-count delta (+k grow, -k shrink candidates, 0
        hold).  The driver only retires hosts that are actually empty, so a
        negative return is a ceiling, not a command."""
        if n_alive <= 0:
            self._last_action_t = now
            return max(1, self.min_hosts)
        if now - self._last_action_t < self.cooldown_s:
            return 0
        per_host = queued / n_alive
        step = max(1, int(n_alive * self.step_frac))
        if per_host > self.up_queue_per_host and n_alive < self.max_hosts:
            self._last_action_t = now
            return min(step, self.max_hosts - n_alive)
        if per_host < self.down_queue_per_host and n_alive > self.min_hosts:
            self._last_action_t = now
            return -min(step, n_alive - self.min_hosts)
        return 0
