"""Locality-aware invocation placement over a pod of restore hosts.

The scheduler maps each cold invocation to a host.  The ``locality`` policy
scores hosts on the three effects the serving stack actually implements:

* **fan-out affinity** — a host already restoring the same ``(name,
  version)`` snapshot lets the newcomer join the ``NodePageServer`` fan-out
  group (PR 3): tier reads are shared, the joiner pays install-only cost;
* **dedup overlap** — a host holding resident instances of the same *base
  group* has the shared base chunks in its content-keyed ``HotChunkCache``
  (PR 5), so the variant's CXL read shrinks by its shared-byte fraction
  (``DedupStore.probe_new_bytes`` / ``exclusive_cxl_bytes`` ground these
  fractions in the store's real offset tables — see fleet_bench);
* **link contention** — every distinct active group on a host fair-shares
  its CXL link and RNIC (`strategies._shared`), so piling unrelated groups
  onto one host slows them all.

``random`` and ``round_robin`` are the A/B baselines.  All three are
deterministic for a seed: random draws from a dedicated generator consumed
in event order, ties break on lowest host id.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from ..core.pagestore import PAGE_SIZE
from ..serve.strategies import _rdma_pages_faulted
from .arrivals import FunctionType
from .model import RestoreProfile

POLICIES = ("locality", "random", "round_robin")


@dataclasses.dataclass
class HostState:
    """Mutable per-host serving state the driver and scheduler share."""

    host_id: int
    slots: int = 64
    busy: int = 0                                    # occupied compute slots
    alive: bool = True
    # host CXL-link health, fed from the serving tier's circuit breaker
    # (``core.faults.TierHealth``): while False, restores placed here run
    # the degraded RDMA-only path, so the scheduler de-scores the host
    cxl_healthy: bool = True
    # snapshot name -> finish time of the in-flight fan-out group's shared
    # reads; while present, same-name restores join at install-only cost
    active_restores: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # base_group -> resident instance count (running, queued-warm, or warm)
    resident_groups: Dict[int, int] = dataclasses.field(default_factory=dict)
    # fn_id -> warm-instance expiry times (driver pops oldest first)
    warm: Dict[int, Deque[float]] = dataclasses.field(default_factory=dict)
    queue: Deque[int] = dataclasses.field(default_factory=deque)

    def free_slots(self) -> int:
        return max(0, self.slots - self.busy)

    def load(self) -> float:
        return (self.busy + len(self.queue)) / max(1, self.slots)

    def add_resident(self, group: int) -> None:
        self.resident_groups[group] = self.resident_groups.get(group, 0) + 1

    def drop_resident(self, group: int) -> None:
        n = self.resident_groups.get(group, 0) - 1
        if n <= 0:
            self.resident_groups.pop(group, None)
        else:
            self.resident_groups[group] = n

    def note_health(self, cxl_health) -> None:
        """Feed a ``core.faults.TierHealth`` breaker (or None) into the
        placement state; call whenever the host's breaker changes state."""
        self.cxl_healthy = cxl_health is None or not cxl_health.degraded

    def overlap_frac(self, fn: FunctionType, profile: RestoreProfile) -> float:
        """Fraction of the hot read the host's chunk cache absorbs: the
        snapshot's shared-base bytes, if any same-group instance is (or was
        kept) resident here."""
        if profile.hot_bytes <= 0:
            return 0.0
        if self.resident_groups.get(fn.base_group, 0) <= 0:
            return 0.0
        return min(1.0, profile.shared_base_bytes / profile.hot_bytes)


class PlacementScheduler:
    """Chooses a host for each cold invocation under one of POLICIES."""

    def __init__(self, policy: str, seed: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; want one of {POLICIES}")
        self.policy = policy
        # optional FleetTopology (fleet/topology.py): when set, hosts whose
        # pod lacks a replica (or that hold no MHD port) are surcharged the
        # inter-pod hot-read penalty in score() AND in the driver's charge
        self.topology = None
        self._rng = np.random.default_rng(np.random.SeedSequence((seed, 0x91ACE)))
        self._rr = 0
        self.stats = {"placed": 0, "join_hits": 0, "overlap_hits": 0}
        # restore pricing depends only on (fn, conc, overlap-or-not, join):
        # a function's overlap fraction is a constant of its snapshot, so
        # the priced cost is memoizable on a tiny key
        self._cost: Dict[tuple, float] = {}

    def priced(self, fn: FunctionType, profile: RestoreProfile,
               conc: int, ov: float, joined: bool = False) -> float:
        key = (fn.fn_id, conc, ov > 0.0, joined)
        v = self._cost.get(key)
        if v is None:
            v = profile.cold_start_s(conc, ov, joined)
            self._cost[key] = v
        return v

    def topology_penalty(self, h: HostState, fn: FunctionType,
                         profile: RestoreProfile, conc: int) -> float:
        """Fabric surcharge for a NON-join restore of ``fn`` on ``h``
        (a joiner shares the group's already-moving reads, so it never
        pays the fabric again); 0 when no topology is configured."""
        topo = self.topology
        if topo is None or profile.hot_bytes <= 0:
            return 0.0
        return topo.penalty_s(h.host_id, fn.fn_id,
                              int(profile.hot_bytes // PAGE_SIZE), conc)

    def score(self, h: HostState, fn: FunctionType,
              profile: RestoreProfile) -> float:
        """Negative modeled time-to-ready on this host, priced with the
        same RestoreProfile arithmetic the driver charges: fan-out join
        collapses to install-only, dedup overlap trims the hot read,
        distinct active groups contend for the links, and a full host
        adds a crude FIFO queue-wait.  Affinity only counts when a slot
        is free — a queued invocation starts after the group's shared
        reads (and likely the chunk residency) are gone."""
        free = h.free_slots() > 0
        if free and fn.name in h.active_restores:
            base = self.priced(fn, profile, 1, 0.0, joined=True)
        else:
            conc = len(h.active_restores) + 1
            ov = h.overlap_frac(fn, profile) if free else 0.0
            base = self.priced(fn, profile, conc, ov)
            base += self.topology_penalty(h, fn, profile, conc)
        if not h.cxl_healthy and profile.hot_bytes > 0:
            # browned-out CXL link (DESIGN.md §15): the hot set arrives
            # page-at-a-time over the RNIC instead of the chunked CXL
            # pre-install — surcharge by the repriced difference
            n_hot = int(profile.hot_bytes // PAGE_SIZE)
            base += max(0.0,
                        _rdma_pages_faulted(n_hot, 1) - profile.hot_serial_s)
        wait = 0.0 if free else (len(h.queue) + 1) * base
        return -(wait + base)

    def choose(self, hosts: List[HostState], fn: FunctionType,
               profile: RestoreProfile) -> Optional[HostState]:
        alive = [h for h in hosts if h.alive]
        if not alive:
            return None
        self.stats["placed"] += 1
        if self.policy == "random":
            pick = alive[int(self._rng.integers(len(alive)))]
        elif self.policy == "round_robin":
            pick = alive[self._rr % len(alive)]
            self._rr += 1
        else:
            best, best_score = alive[0], self.score(alive[0], fn, profile)
            for h in alive[1:]:
                s = self.score(h, fn, profile)
                if s > best_score:       # strict: ties keep lowest host_id
                    best, best_score = h, s
            pick = best
        if fn.name in pick.active_restores:
            self.stats["join_hits"] += 1
        if pick.overlap_frac(fn, profile) > 0.0:
            self.stats["overlap_hits"] += 1
        return pick
