"""Decomposed per-snapshot restore-cost profiles for the fleet driver.

A fleet simulation at 10k+ concurrent invocations cannot walk a
``SnapshotReader`` per invocation, so each function type's snapshot is
profiled ONCE into a :class:`RestoreProfile`: the same term-by-term
arithmetic as :func:`repro.serve.strategies.modeled_concurrent_restore_s`
(metadata reads, borrow clflush, chunked hot pre-install, zero ranges,
doorbell-batched cold prefetch), but with the link-bound and CPU-bound
terms kept separate so the driver can re-price a restore under the host's
*current* conditions:

* **contention** — ``conc`` distinct fan-out groups actively restoring on
  the host share its CXL link and RNIC (`strategies._shared`, the same
  fair-share model the executed ``LinkArbiter`` path matches to ≤0.8%);
* **fan-out join** — a restore of a ``(name, version)`` already restoring
  on the host rides the existing group's tier reads (``HotChunkCache`` +
  shared cold extents, PR 3) and pays only its own CPU-side installs;
* **dedup overlap** — hot chunks whose content is already resident on the
  host (a variant sharing base pages restored there before) hit the
  content-keyed chunk cache (PR 5 ``cross_group_hits``), removing that
  fraction of the CXL read.

``profile_reader`` is exact: at ``conc`` streams, no overlap and no join,
``RestoreProfile.cold_start_s`` reproduces ``modeled_concurrent_restore_s``
bit-for-bit (asserted in tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from ..core.pagestore import PAGE_SIZE
from ..core.pool import (
    CLFLUSH_PER_LINE_S,
    uffd_copy_batch_cost,
    uffd_zeropage_range_cost,
)
from ..serve.strategies import (
    CXL_BW,
    CXL_LAT_S,
    RDMA_BW,
    RDMA_INFLIGHT,
    RDMA_LAT_S,
    HOT_CHUNK_PAGES,
    _shared,
)


@dataclasses.dataclass(frozen=True)
class RestoreProfile:
    """Link-bound vs CPU-bound restore terms for one published snapshot."""

    name: str
    version: int
    total_pages: int
    hot_bytes: int               # hot payload crossing the CXL link
    cold_bytes: int              # cold payload crossing the RNIC
    # ms / oa / cold-index CXL reads as (serial_s, nbytes) terms — kept
    # separate because _shared is a max, not additive across regions
    meta_terms: Tuple[Tuple[float, int], ...]
    flush_s: float               # borrow-protocol clflushopt (CPU-side)
    hot_serial_s: float          # chunked CXL read, uncontended
    hot_chunks: int
    hot_install_s: float         # batched uffd.copy of the hot set
    zero_install_s: float        # uffd.zeropage ranges
    cold_serial_s: float         # doorbell-batched RDMA extent reads
    cold_install_s: float        # batched uffd.copy of the cold extents
    # dedup-overlap structure (placement scoring)
    shared_base_bytes: int = 0   # hot bytes shared with the base group
    exclusive_bytes: int = 0     # hot bytes exclusively ours (store truth)

    def cold_start_s(self, conc: int = 1, overlap_frac: float = 0.0,
                     joined: bool = False) -> float:
        """Modeled seconds for one full restore on a host where ``conc``
        distinct fan-out groups (including this one) contend for the links,
        ``overlap_frac`` of the hot bytes are already chunk-cache resident,
        and ``joined`` means an active same-snapshot group's reads are
        shared (this instance pays CPU-side installs only)."""
        conc = max(1, int(conc))
        # term order matches modeled_concurrent_restore_s exactly so that at
        # (conc, overlap=0, joined=False) the two are bit-identical
        t = 0.0
        for serial_s, nbytes in self.meta_terms:
            t += _shared(serial_s, nbytes, CXL_BW, conc)
        t += self.flush_s
        if not joined:
            f = float(np.clip(overlap_frac, 0.0, 1.0))
            eff_hot = int(round(self.hot_bytes * (1.0 - f))) if f > 0.0 \
                else self.hot_bytes
            if eff_hot > 0:
                serial = self.hot_serial_s if f == 0.0 else (
                    self.hot_chunks * (1.0 - f) * CXL_LAT_S
                    + eff_hot / CXL_BW)
                t += _shared(serial, eff_hot, CXL_BW, conc)
        if self.hot_bytes > 0:
            t += self.hot_install_s
        t += self.zero_install_s
        if not joined and self.cold_bytes > 0:
            t += _shared(self.cold_serial_s, self.cold_bytes, RDMA_BW, conc)
        if self.cold_bytes > 0:
            t += self.cold_install_s
        return t

    def install_only_s(self) -> float:
        """The fan-out joiner's cost (kept for reporting symmetry)."""
        return self.cold_start_s(1, joined=True)

    def scaled(self, k: float) -> "RestoreProfile":
        """Extrapolate to a k-x larger image (the ``Workload.scale`` idiom):
        every byte count, serial transfer, and install term grows by k, so
        the contention/overlap shape of the profiled layout is preserved
        while the bench models production-sized snapshots from small real
        pods."""
        if k == 1.0:
            return self
        mt = tuple((s * k, int(round(b * k))) for s, b in self.meta_terms)
        return dataclasses.replace(
            self,
            total_pages=int(round(self.total_pages * k)),
            hot_bytes=int(round(self.hot_bytes * k)),
            cold_bytes=int(round(self.cold_bytes * k)),
            meta_terms=mt,
            flush_s=self.flush_s * k,
            hot_serial_s=self.hot_serial_s * k,
            hot_chunks=max(1, int(round(self.hot_chunks * k)))
            if self.hot_chunks else 0,
            hot_install_s=self.hot_install_s * k,
            zero_install_s=self.zero_install_s * k,
            cold_serial_s=self.cold_serial_s * k,
            cold_install_s=self.cold_install_s * k,
            shared_base_bytes=int(round(self.shared_base_bytes * k)),
            exclusive_bytes=int(round(self.exclusive_bytes * k)),
        )


def profile_reader(reader, max_extent_pages: int = 64,
                   chunk_pages: int = HOT_CHUNK_PAGES,
                   shared_base_bytes: int = 0,
                   exclusive_bytes: int = 0) -> RestoreProfile:
    """Build a profile from a live ``SnapshotReader`` with exactly the term
    arithmetic of ``strategies.modeled_concurrent_restore_s`` — the two must
    agree bit-for-bit at (conc, overlap=0, joined=False)."""
    r = reader.regions
    oa_bytes = r.total_pages * 8
    meta_terms = [(CXL_LAT_S + r.ms_size / CXL_BW, r.ms_size),
                  (CXL_LAT_S + oa_bytes / CXL_BW, oa_bytes)]
    if r.cold_compressed and r.n_cold:
        ci_bytes = r.n_cold * 4
        meta_terms.append((CXL_LAT_S + ci_bytes / CXL_BW, ci_bytes))
    n_lines = -(-(r.ms_size + r.oa_size + max(r.hot_bytes, 0)) // 64)
    flush_s = n_lines * CLFLUSH_PER_LINE_S
    n_hot, n_chunks, n_ranges = 0, 0, 0
    for pages, _off, _nbytes in reader.iter_hot_extents(chunk_pages):
        n_chunks += 1
        n_hot += int(pages.size)
        seg = np.sort(pages)
        n_ranges += 1 + int(np.count_nonzero(np.diff(seg) != 1))
    hot_serial = (n_chunks * CXL_LAT_S + n_hot * PAGE_SIZE / CXL_BW
                  if n_hot else 0.0)
    hot_install = uffd_copy_batch_cost(n_hot, n_ranges) if n_hot else 0.0
    zr = reader.zero_runs()
    zero_install = (uffd_zeropage_range_cost(int(zr[:, 1].sum()),
                                             int(zr.shape[0]))
                    if zr.size else 0.0)
    cr = reader.cold_runs()
    n_cold = int(cr[:, 1].sum()) if cr.size else 0
    cold_serial, cold_bytes, cold_install = 0.0, 0, 0.0
    if n_cold:
        n_ext = 0
        for _es, _en, _rank0, _off, nbytes in reader.iter_cold_extents(
                max_extent_pages):
            cold_bytes += nbytes
            n_ext += 1
        cold_serial = (-(-n_ext // RDMA_INFLIGHT) * RDMA_LAT_S
                       + cold_bytes / RDMA_BW)
        cold_install = uffd_copy_batch_cost(n_cold, n_ext)
    return RestoreProfile(
        name=getattr(r, "name", ""), version=r.version,
        total_pages=r.total_pages,
        hot_bytes=n_hot * PAGE_SIZE, cold_bytes=cold_bytes,
        meta_terms=tuple(meta_terms), flush_s=flush_s,
        hot_serial_s=hot_serial, hot_chunks=n_chunks,
        hot_install_s=hot_install, zero_install_s=zero_install,
        cold_serial_s=cold_serial, cold_install_s=cold_install,
        shared_base_bytes=int(shared_base_bytes),
        exclusive_bytes=int(exclusive_bytes),
    )
