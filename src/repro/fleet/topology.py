"""Pod-awareness for fleet placement: sparse CXL attach + replica maps.

Octopus (PAPERS.md) builds real fleets from many small MHD pods: each pod's
device exposes a fixed number of head ports, so only ``ports_per_pod``
hosts per pod are CXL-attached — everyone else reaches pool memory over
the RDMA fabric.  :class:`FleetTopology` captures the static shape the
placement layer needs:

* ``pod_of(host)`` — hosts stripe across pods (``host_id % n_pods``);
* ``attached(host)`` — the first ``ports_per_pod`` hosts of each pod hold
  a head port (``host_id // n_pods < ports_per_pod``); autoscaled
  late-comers are fabric-only, like burst capacity racked outside the pod;
* ``replicas`` — which pods hold each function's snapshot, produced by the
  planners below.

A restore is **local** (no surcharge) only when the host is attached AND
its pod holds a replica; otherwise the hot set crosses the inter-pod
fabric and the placement score/driver charge add
``strategies.interpod_hot_penalty_s`` — the same constants the topology
package executes against, so the fleet model and the data plane agree.

Planners (the multi-pod fleet_bench tiers):

* :func:`plan_single` — everything in pod 0 (the single-big-pod baseline);
* :func:`plan_balanced` — one replica per snapshot, byte-balanced across
  pods (multi-pod, no replication);
* :func:`plan_replicated` — balanced plus second replicas for hot
  functions, gated by ``strategies.migration_economics`` and a per-pod
  CXL budget: replication spends the SAME total budget, just on copies of
  what demand actually reads.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..core.pagestore import PAGE_SIZE
from ..serve.strategies import interpod_hot_penalty_s, migration_economics
from .arrivals import FunctionType
from .model import RestoreProfile


class FleetTopology:
    """Static pod shape + replica map the scheduler and driver consult."""

    def __init__(self, n_pods: int, ports_per_pod: int,
                 replicas: Optional[Dict[int, Set[int]]] = None):
        self.n_pods = int(n_pods)
        self.ports_per_pod = int(ports_per_pod)
        self.replicas: Dict[int, Set[int]] = {
            k: set(v) for k, v in (replicas or {}).items()}
        self._penalty: Dict[Tuple[int, int], float] = {}
        self.stats = {"local_placements": 0, "remote_placements": 0,
                      "unattached_placements": 0}

    def pod_of(self, host_id: int) -> int:
        return host_id % self.n_pods

    def attached(self, host_id: int) -> bool:
        return (host_id // self.n_pods) < self.ports_per_pod

    def is_local(self, host_id: int, fn_id: int) -> bool:
        """True when this host serves ``fn_id``'s hot set over its own
        pod's CXL: port-attached and the pod holds a replica."""
        return (self.attached(host_id)
                and self.pod_of(host_id) in self.replicas.get(fn_id, ()))

    def penalty_s(self, host_id: int, fn_id: int, n_hot_pages: int,
                  conc: int) -> float:
        """Extra modeled seconds for the hot read when it must cross the
        inter-pod fabric (memoized per (fn, conc) — the penalty depends
        only on the hot-set size and the host's concurrent groups)."""
        if n_hot_pages <= 0 or self.is_local(host_id, fn_id):
            return 0.0
        key = (fn_id, conc)
        v = self._penalty.get(key)
        if v is None:
            v = self._penalty[key] = interpod_hot_penalty_s(n_hot_pages, conc)
        return v

    def note_placement(self, host_id: int, fn_id: int) -> None:
        """Tally where a restore actually landed (driver calls this once
        per non-join restore, never per candidate scored)."""
        if self.is_local(host_id, fn_id):
            self.stats["local_placements"] += 1
        elif not self.attached(host_id):
            self.stats["unattached_placements"] += 1
        else:
            self.stats["remote_placements"] += 1


# ---------------------------------------------------------------------------
# replica planners (the fleet_bench multi-pod tiers)
# ---------------------------------------------------------------------------

def plan_single(fleet: Iterable[FunctionType]) -> Dict[int, Set[int]]:
    """Single-big-pod baseline: every snapshot lives in pod 0."""
    return {f.fn_id: {0} for f in fleet}


def plan_balanced(fleet: Iterable[FunctionType],
                  profiles: Dict[int, RestoreProfile],
                  n_pods: int) -> Tuple[Dict[int, Set[int]], List[int]]:
    """One replica per snapshot, byte-balanced: heaviest hot sets first
    onto the lightest pod (deterministic: ties break on fn then pod id).
    Returns (replica map, per-pod CXL bytes)."""
    loads = [0] * n_pods
    out: Dict[int, Set[int]] = {}
    order = sorted(fleet, key=lambda f: (-profiles[f.fn_id].hot_bytes, f.fn_id))
    for f in order:
        pid = min(range(n_pods), key=lambda p: (loads[p], p))
        out[f.fn_id] = {pid}
        loads[pid] += int(profiles[f.fn_id].hot_bytes)
    return out, loads


def plan_replicated(fleet: Iterable[FunctionType],
                    profiles: Dict[int, RestoreProfile],
                    n_pods: int, budget_bytes: int,
                    expected_reads: Dict[int, float]
                    ) -> Tuple[Dict[int, Set[int]], Dict[str, int]]:
    """Balanced placement plus economics-gated second replicas.

    Hottest functions first (by expected reads over the trace), a second
    replica is added only when ``migration_economics`` says the one-time
    copy amortizes — and only onto a pod with budget headroom, where the
    per-pod budget is ``budget_bytes / n_pods`` (equal TOTAL budget to the
    single-pod baseline; replication spends headroom, never new capacity).
    Returns (replica map, planner stats) — the stats prove the gate
    actually filtered."""
    out, loads = plan_balanced(fleet, profiles, n_pods)
    per_pod = budget_bytes // n_pods
    stats = {"replicas_added": 0, "skipped_uneconomic": 0,
             "skipped_no_budget": 0}
    order = sorted(fleet,
                   key=lambda f: (-expected_reads.get(f.fn_id, 0.0), f.fn_id))
    for f in order:
        prof = profiles[f.fn_id]
        econ = migration_economics(int(prof.hot_bytes), int(prof.cold_bytes),
                                   expected_reads.get(f.fn_id, 0.0))
        if not econ["worthwhile"]:
            stats["skipped_uneconomic"] += 1
            continue
        have = out[f.fn_id]
        cands = [p for p in range(n_pods)
                 if p not in have and loads[p] + prof.hot_bytes <= per_pod]
        if not cands:
            stats["skipped_no_budget"] += 1
            continue
        pid = min(cands, key=lambda p: (loads[p], p))
        have.add(pid)
        loads[pid] += int(prof.hot_bytes)
        stats["replicas_added"] += 1
    return out, stats


def hot_pages_of(profile: RestoreProfile) -> int:
    """The hot-set page count the fabric penalty is priced on."""
    return int(profile.hot_bytes // PAGE_SIZE)
