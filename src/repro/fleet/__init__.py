"""Traffic-driven fleet serving: seeded arrival synthesis, locality-aware
placement over the CXL pod, keep-warm economics, and queue-depth host
autoscaling — the serving layer that turns single-restore machinery
(PoolMaster publish, NodePageServer fan-out, dedup overlap) into modeled
fleet-scale cold-start numbers."""
from .arrivals import (
    FunctionType,
    Trace,
    diurnal_arrivals,
    generate_trace,
    onoff_arrivals,
    poisson_arrivals,
    synthesize_fleet,
    zipf_rates,
)
from .autoscale import QueueAutoscaler
from .driver import (
    MODE_COLD,
    MODE_JOIN,
    MODE_WARM,
    FleetDriver,
    FleetResult,
)
from .model import RestoreProfile, profile_reader
from .placement import POLICIES, HostState, PlacementScheduler
from .topology import (
    FleetTopology,
    plan_balanced,
    plan_replicated,
    plan_single,
)

__all__ = [
    "FunctionType", "Trace", "poisson_arrivals", "diurnal_arrivals",
    "onoff_arrivals", "zipf_rates", "synthesize_fleet", "generate_trace",
    "RestoreProfile", "profile_reader",
    "HostState", "PlacementScheduler", "POLICIES",
    "QueueAutoscaler",
    "FleetDriver", "FleetResult", "MODE_COLD", "MODE_JOIN", "MODE_WARM",
    "FleetTopology", "plan_single", "plan_balanced", "plan_replicated",
]
