"""Sharding rules: param/optimizer/cache/batch PartitionSpecs for the
production meshes.

Scheme (baseline — §Perf hillclimbs start from here):
  * 2-D param sharding: FSDP over the ``data`` axis × tensor parallelism
    over the ``model`` axis.  Column-parallel in-projections, row-parallel
    out-projections, vocab-parallel embeddings.
  * MoE experts: expert-parallel over ``model`` (E % 16 == 0 for both MoE
    archs), expert weights additionally FSDP over ``data``.
  * Multi-pod: batch data-parallel over (pod, data); params/optimizer are
    replicated across pods (gradient all-reduce rides the DCN), sharded
    within a pod.
  * KV caches: batch-sharded where the batch covers the axis; KV heads over
    ``model`` when divisible, else head_dim; long-context batch=1 cells
    shard the sequence axis of the cache over ``data``.

Rules are path-keyed (substring match on '/'-joined param paths) with the
trailing dims of the rule aligned to the trailing dims of the leaf — any
leading scan-stack dims (layer, group) are replicated automatically.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

# (pattern, trailing-dims spec). First match wins; patterns are substrings
# of the '/'-joined path. None spec entry = replicated dim.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings
    ("embed/table", ("model", "data")),        # vocab-parallel
    ("embed/head", ("data", "model")),
    # MoE experts (E, D, F) / (E, F, D) — EP over model, FSDP over data
    ("moe/wi", ("model", "data", None)),
    ("moe/wg", ("model", "data", None)),
    ("moe/wo", ("model", None, "data")),
    ("moe/router", ("data", None)),
    ("moe/shared/wi", ("data", "model")),
    ("moe/shared/wg", ("data", "model")),
    ("moe/shared/wo", ("model", "data")),
    # attention projections
    ("attn/wq", ("data", "model")),
    ("attn/wk", ("data", "model")),
    ("attn/wv", ("data", "model")),
    ("attn/wo", ("model", "data")),
    ("attn/wq_a", ("data", None)),
    ("attn/wq_b", (None, "model")),
    ("attn/wkv_a", ("data", None)),
    ("attn/wkv_b", (None, "model")),
    ("self/w", ("data", "model")),
    ("self/wo", ("model", "data")),
    ("cross/wo", ("model", "data")),
    ("cross/w", ("data", "model")),
    # MLPs
    ("mlp/wi", ("data", "model")),
    ("mlp/wg", ("data", "model")),
    ("mlp/wo", ("model", "data")),
    ("mtp/proj", ("data", None)),
    # SSM / xLSTM
    # fused (z|x|B|C|dt) out dim is not TP-divisible → FSDP only
    ("mamba/in_proj", ("data", None)),
    ("mamba/out_proj", ("model", "data")),
    ("mlstm/wq", ("data", "model")),
    ("mlstm/wk", ("data", "model")),
    ("mlstm/wv", ("data", "model")),
    ("mlstm/wog", ("data", "model")),
    ("mlstm/wo", ("model", "data")),
    ("slstm/wx", ("data", "model")),
    ("slstm/wo", ("model", "data")),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_for(path: str, ndim: int) -> P:
    for pat, trailing in _PARAM_RULES:
        if pat in path:
            if len(trailing) > ndim:
                return P()
            lead = (None,) * (ndim - len(trailing))
            return P(*lead, *trailing)
    return P()  # norms, biases, scalars: replicated


def param_specs(params) -> Any:
    """PartitionSpec pytree mirroring `params` (axis names: data/model)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), np.ndim(leaf)), params
    )


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------

def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh else ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def batch_specs(batch_tree, mesh: Mesh) -> Any:
    """Shard the leading (batch) dim over the DP axes where it divides."""
    dp = dp_axes(mesh)
    dpn = _axis_size(mesh, dp)

    def spec(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        if shape[0] % dpn == 0 and shape[0] > 0:
            return P(dp, *(None,) * (len(shape) - 1))
        # small batch: try data-only
        if "data" in mesh.axis_names and shape[0] % mesh.shape["data"] == 0:
            return P("data", *(None,) * (len(shape) - 1))
        return P(*(None,) * len(shape))

    return jax.tree.map(spec, batch_tree)


def cache_specs(cache_tree, cfg: ModelConfig, mesh: Mesh, batch: int) -> Any:
    """Decode-state sharding (see module docstring)."""
    dp = dp_axes(mesh)
    dpn = _axis_size(mesh, dp)
    tp = mesh.shape.get("model", 1)
    batch_shardable = batch % dpn == 0

    def spec(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        ax: list = [None] * nd
        # locate the batch dim: first dim equal to `batch`
        try:
            bdim = next(i for i, s in enumerate(shape) if s == batch and i <= 2)
        except StopIteration:
            bdim = None
        if bdim is not None and batch_shardable:
            ax[bdim] = dp
        if ("latent" in p) or re.search(r"(^|/)(k|v|cross|self)($|/)", p) or "attn" in p:
            # attention caches: (..., B, H, S, dh) or latent (..., B, S, r)
            if "latent" in p:
                sdim = nd - 2
                if cfg.flash_decoding and shape[sdim] % tp == 0:
                    # flash-decoding layout: sequence over the TP axis
                    # (partial softmax combines with tiny (B,h) collectives)
                    ax[sdim] = "model"
                elif ((bdim is None or not batch_shardable)
                      and shape[sdim] % mesh.shape.get("data", 1) == 0):
                    ax[sdim] = "data"
            else:
                hdim, sdim, ddim = nd - 3, nd - 2, nd - 1
                if shape[hdim] % tp == 0:
                    ax[hdim] = "model"
                elif shape[ddim] % tp == 0:
                    ax[ddim] = "model"
                if ((bdim is None or not batch_shardable)
                        and shape[sdim] % mesh.shape.get("data", 1) == 0):
                    ax[sdim] = "data"
        elif any(k in p for k in ("ssm", "conv", "/C", "/n", "/m", "/h", "/c")):
            pass  # recurrent states: batch dim (handled above) or replicated
        return P(*[tuple(a) if isinstance(a, tuple) else a for a in ax])

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, *axes):
    """Best-effort with_sharding_constraint: axes not present on the current
    mesh degrade to replicated; no-op when no mesh is active (CPU tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = getattr(mesh, "axis_names", ()) or ()
        spec = []
        for a in axes:
            if a is None:
                spec.append(None)
            elif isinstance(a, tuple):
                ok = tuple(ax for ax in a if ax in names)
                spec.append(ok if ok else None)
            else:
                spec.append(a if a in names else None)
        if not any(s for s in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # no mesh context
        return x


def cache_constrain(x, seq_shard: bool = False):
    """In-loop counterpart of cache_specs for a single layer's cache slice:
    batch over DP; for (B,H,S,dh) KV caches, heads over 'model' when
    divisible else head_dim. Pinning the carry prevents XLA from re-sharding
    the stacked cache mid-loop (observed: f32 all-gather of the whole stack
    over the latent dim)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = getattr(mesh, "axis_names", ()) or ()
        if not names:
            return x
        dp = tuple(a for a in ("pod", "data") if a in names)
        dpn = 1
        for a in dp:
            dpn *= mesh.shape[a]
        tp = mesh.shape.get("model", 1) if "model" in names else 1
        nd = x.ndim
        spec = [None] * nd
        if dp and x.shape[0] % dpn == 0:
            spec[0] = dp
        if nd == 4 and "model" in names:
            if x.shape[1] % tp == 0:
                spec[1] = "model"
            elif x.shape[3] % tp == 0:
                spec[3] = "model"
        elif nd == 3 and seq_shard and "model" in names and x.shape[1] % tp == 0:
            spec[1] = "model"   # latent cache: sequence over TP (flash-decoding)
        if not any(spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
