"""Distributed-optimization helpers: compressed gradient all-reduce with
error feedback (int8), built from scratch.

At 1000+-node scale the cross-pod gradient all-reduce rides the DCN; int8
quantization with per-leaf scales cuts those bytes 4x (f32) / 2x (bf16).
Error feedback keeps the quantization noise unbiased over steps (Karimireddy
et al., 2019 — EF-SGD).  The transform plugs into the train step as
``grad_transform`` and is exercised by tests for convergence parity.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads) -> Any:
    """Simulate the int8 wire format: quantize+dequantize each leaf."""
    def f(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        return dequantize_int8(q, s).astype(g.dtype)
    return jax.tree.map(f, grads)


class ErrorFeedback:
    """Stateful EF wrapper: g' = Q(g + e); e = (g + e) - g'."""

    def __init__(self, params_like):
        self.residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params_like)

    def __call__(self, grads):
        corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, self.residual)
        sent = compress_tree(corrected)
        self.residual = jax.tree.map(lambda c, s: c - s.astype(jnp.float32), corrected, sent)
        return sent


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """shard_map-side compressed all-reduce: agree on a shared scale (pmax of
    local scales — one scalar on the wire), quantize to int8, ring-reduce in
    int32 (exact), dequantize once.  Wire bytes: 1B/element + 4B scale."""
    local_scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return acc.astype(jnp.float32) * scale
