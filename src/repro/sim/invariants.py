"""Coherence/pool invariants, checked after every simulator step.

The checker reads the *actual* shared state (catalog entries, lease words,
tier free lists) and compares it against the cluster's independent
accounting of what every host program has done.  Violations raise
:class:`InvariantViolation` tagged with the scenario seed and step number,
so any failure reproduces exactly by re-running the scenario with that seed.

Invariant list (DESIGN.md §9):

  I1  refcount accounting — every entry's refcount equals the number of
      live (successful, unreleased) borrows plus in-flight increments of
      borrows paused between their refcount++ and state CAS.  Orphans from
      crashed hosts stay counted: a crash may leak a refcount, but the
      shared word must never drift from the sum of causes.
  I2  single master per term — a lease term is never observed with two
      different holders, and at most one node is ``is_master`` at any step.
  I3  pool conservation — per tier: bytes_in_use + free bytes == capacity,
      with a sorted, non-overlapping, in-bounds free list.
  I4  borrow pinning — a live successful borrow's entry still points at the
      regions/version observed at borrow time (owner updates must drain
      first); borrowers therefore never observe TOMBSTONE'd data bytes.
  I5  catalog sanity — PUBLISHED entries have regions; refcounts are
      non-negative; states are in the valid set.
  I6  dedup refcount conservation — for each content store (CXL and RDMA),
      every stored page's refcount equals the number of live offset-array
      slots pointing at it, counted over catalog entries PLUS in-flight /
      leaked builds the cluster tracks (``pending_regions``): a crashed
      owner may leak references, but the store's words must never drift
      from the sum of causes — and a page must never be freed while any
      snapshot still points at it.
  I7  replica coherence (multi-pod, DESIGN.md §16) — all PUBLISHED
      replicas of a group-managed name carry the same version and
      bit-identical reconstructed content; a group update/delete drains
      every replica, so no step ever observes PUBLISHED replicas at two
      different versions.
  I8  single writer across pods — at most one in-flight group write per
      name, and no pod-local owner mutation of a group-managed name
      happens outside the group writer lock (a busy per-pod owner for a
      managed name without the lock is a protocol bypass).

I1/I3/I5/I6 are checked per pod; a single-pod cluster degenerates to the
original checks and I7/I8 are skipped when no :class:`ReplicaManager`
exists.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..core.coherence import STATE_FREE, STATE_PUBLISHED, STATE_TOMBSTONE
from ..core.failover import NO_MASTER
from ..core.pool import TIER_CXL, TIER_RDMA
from ..core.snapshot import decode_dedup_offsets, reconstruct_image


class InvariantViolation(AssertionError):
    """A checked coherence/pool invariant failed at a specific (seed, step)."""


class InvariantChecker:
    """Checks I1–I8 against a SimCluster after every scheduler step."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.term_history: Dict[int, int] = {}   # lease term -> holder node id
        # I7 bit-compare cache: name -> sorted (pod, version) signature at
        # the last full reconstruct, so identical steady states skip the
        # O(bytes) comparison
        self._replica_sigs: Dict[str, Tuple[Tuple[int, int], ...]] = {}
        self.checks_run = 0

    def _fail(self, invariant: str, msg: str) -> None:
        c = self.cluster
        raise InvariantViolation(
            f"[seed={c.seed} step={c.step_no}] {invariant} violated: {msg}\n"
            f"  reproduce with SimCluster(seed={c.seed}) and the same scenario"
        )

    # -- I1 -------------------------------------------------------------------
    def check_refcounts(self) -> None:
        c = self.cluster
        for pod in c.pods:
            for entry in pod.catalog.entries:
                key = (pod.pod_id, entry.index)
                expected = c.live.get(key, 0) + c.midflight.get(key, 0)
                actual = entry.refcount.load()
                if actual != expected:
                    self._fail(
                        "I1 refcount==live_borrows+midflight",
                        f"pod {pod.pod_id} entry {entry.index} "
                        f"({entry.name!r}): refcount={actual}, "
                        f"live={c.live.get(key, 0)}, "
                        f"midflight={c.midflight.get(key, 0)}")
                if actual < 0:
                    self._fail("I5 refcount>=0",
                               f"pod {pod.pod_id} entry {entry.index}: {actual}")

    # -- I2 -------------------------------------------------------------------
    def check_single_master(self) -> None:
        c = self.cluster
        if c.lease is None:
            return
        term = c.lease.term.load()
        holder = c.lease.holder.load()
        if holder != NO_MASTER and term > 0:
            prev = self.term_history.setdefault(term, holder)
            if prev != holder:
                self._fail("I2 one master per lease term",
                           f"term {term} held by both node {prev} and node {holder}")
        masters = [n.node_id for n in c.nodes.values() if n.is_master]
        if len(masters) > 1:
            self._fail("I2 at most one active master",
                       f"simultaneous masters: {masters}")

    # -- I3 -------------------------------------------------------------------
    def check_pool_conservation(self) -> None:
        for pod in self.cluster.pods:
            for tier in (pod.pool.cxl, pod.pool.rdma):
                free = sorted(tier._free)
                free_bytes = sum(size for _off, size in free)
                if free_bytes + tier.bytes_in_use != tier.capacity:
                    self._fail("I3 pool byte conservation",
                               f"pod {pod.pod_id} tier {tier.name}: "
                               f"free={free_bytes} + in_use={tier.bytes_in_use}"
                               f" != capacity={tier.capacity}")
                prev_end = 0
                for off, size in free:
                    if off < 0 or size <= 0 or off + size > tier.capacity:
                        self._fail("I3 free segment in bounds",
                                   f"pod {pod.pod_id} tier {tier.name}: "
                                   f"segment ({off}, {size})")
                    if off < prev_end:
                        self._fail("I3 free segments disjoint",
                                   f"pod {pod.pod_id} tier {tier.name}: "
                                   f"segment ({off}, {size}) overlaps "
                                   f"previous ending at {prev_end}")
                    prev_end = off + size

    # -- I4 -------------------------------------------------------------------
    def check_borrow_pins(self) -> None:
        for rec in self.cluster.borrow_records:
            entry = rec.borrow.entry
            if entry.regions is not rec.regions:
                self._fail("I4 borrowed regions pinned",
                           f"{rec.host}'s borrow of {rec.name!r} v{rec.version}: "
                           f"entry regions were rewritten while borrowed")
            if entry.version != rec.version:
                self._fail("I4 borrowed version pinned",
                           f"{rec.host}'s borrow of {rec.name!r}: version "
                           f"{rec.version} -> {entry.version} while borrowed")

    # -- I5 -------------------------------------------------------------------
    def check_catalog_sanity(self) -> None:
        valid = (STATE_FREE, STATE_PUBLISHED, STATE_TOMBSTONE)
        for pod in self.cluster.pods:
            for entry in pod.catalog.entries:
                state = entry.state.load()
                if state not in valid:
                    self._fail("I5 valid entry state",
                               f"pod {pod.pod_id} entry {entry.index}: {state}")
                if state == STATE_PUBLISHED and entry.regions is None:
                    self._fail("I5 PUBLISHED implies regions",
                               f"pod {pod.pod_id} entry {entry.index} "
                               f"({entry.name!r}) has no regions")

    # -- I6 -------------------------------------------------------------------
    def check_dedup_refcounts(self) -> None:
        c = self.cluster
        pending_by_pod = getattr(c, "pending_by_pod", None) or {}
        for pod in c.pods:
            pool = pod.pool
            regions = [e.regions for e in pod.catalog.entries
                       if e.regions is not None and e.regions.dedup]
            regions += [r for r in pending_by_pod.get(pod.pod_id, [])
                        if r is not None and r.dedup]
            for store, tag, tier in ((pool.dedup_cxl, TIER_CXL, "cxl"),
                                     (pool.dedup_rdma, TIER_RDMA, "rdma")):
                actual = store.refcounts()
                if not actual and not regions:
                    continue
                expected: Dict[int, int] = {}
                for r in regions:
                    offs = decode_dedup_offsets(pool, r, tag)
                    uniq, counts = np.unique(offs, return_counts=True)
                    for off, k in zip(uniq, counts):
                        expected[int(off)] = expected.get(int(off), 0) + int(k)
                if expected != actual:
                    only_store = {o: rc for o, rc in actual.items()
                                  if expected.get(o) != rc}
                    only_cat = {o: rc for o, rc in expected.items()
                                if actual.get(o) != rc}
                    self._fail(
                        "I6 dedup refcount conservation",
                        f"pod {pod.pod_id} {tier} store refcounts drifted "
                        f"from live catalog offsets: store-side mismatches "
                        f"{only_store}, catalog-side mismatches {only_cat}")

    # -- I7 -------------------------------------------------------------------
    def check_replica_coherence(self) -> None:
        c = self.cluster
        mgr = getattr(c, "replicas", None)
        if mgr is None:
            return
        for name in mgr.names():
            published = []   # (pod_id, entry) observed PUBLISHED right now
            for pid in mgr.replica_pods(name):
                pod = c.pods[pid]
                if not pod.alive:
                    continue
                entry = pod.catalog.find(name)
                if entry is not None and entry.state.load() == STATE_PUBLISHED:
                    published.append((pid, entry))
            versions = {e.version for _pid, e in published}
            if len(versions) > 1:
                self._fail(
                    "I7 replica version coherence",
                    f"{name!r} PUBLISHED at mixed versions "
                    f"{sorted((pid, e.version) for pid, e in published)} — "
                    f"a group write republished before every replica drained")
            if len(published) < 2:
                self._replica_sigs.pop(name, None)
                continue
            sig = tuple(sorted((pid, e.version) for pid, e in published))
            if self._replica_sigs.get(name) == sig:
                continue   # same steady state already bit-verified
            images = [(pid, reconstruct_image(c.pods[pid].pool, e.regions))
                      for pid, e in published]
            ref_pid, ref = images[0]
            ref_pages = ref.pages_matrix()
            for pid, img in images[1:]:
                if not np.array_equal(img.pages_matrix(), ref_pages):
                    self._fail(
                        "I7 replica bit identity",
                        f"{name!r} v{sig[0][1]}: pod {pid} replica bytes "
                        f"differ from pod {ref_pid}")
            self._replica_sigs[name] = sig

    # -- I8 -------------------------------------------------------------------
    def check_single_writer(self) -> None:
        c = self.cluster
        mgr = getattr(c, "replicas", None)
        if mgr is None:
            return
        managed = mgr.names()
        for pod in c.pods:
            for name in getattr(pod.master, "_busy_names", ()):
                if name in managed and not mgr.holds_writer(name):
                    self._fail(
                        "I8 single writer across pods",
                        f"pod {pod.pod_id} owner is mutating group-managed "
                        f"{name!r} without the group writer lock — a "
                        f"pod-local write bypassed the replication protocol")

    def check_all(self) -> None:
        self.check_refcounts()
        self.check_single_master()
        self.check_pool_conservation()
        self.check_borrow_pins()
        self.check_catalog_sanity()
        self.check_dedup_refcounts()
        self.check_replica_coherence()
        self.check_single_writer()
        self.checks_run += 1
