"""Coherence/pool invariants, checked after every simulator step.

The checker reads the *actual* shared state (catalog entries, lease words,
tier free lists) and compares it against the cluster's independent
accounting of what every host program has done.  Violations raise
:class:`InvariantViolation` tagged with the scenario seed and step number,
so any failure reproduces exactly by re-running the scenario with that seed.

Invariant list (DESIGN.md §9):

  I1  refcount accounting — every entry's refcount equals the number of
      live (successful, unreleased) borrows plus in-flight increments of
      borrows paused between their refcount++ and state CAS.  Orphans from
      crashed hosts stay counted: a crash may leak a refcount, but the
      shared word must never drift from the sum of causes.
  I2  single master per term — a lease term is never observed with two
      different holders, and at most one node is ``is_master`` at any step.
  I3  pool conservation — per tier: bytes_in_use + free bytes == capacity,
      with a sorted, non-overlapping, in-bounds free list.
  I4  borrow pinning — a live successful borrow's entry still points at the
      regions/version observed at borrow time (owner updates must drain
      first); borrowers therefore never observe TOMBSTONE'd data bytes.
  I5  catalog sanity — PUBLISHED entries have regions; refcounts are
      non-negative; states are in the valid set.
  I6  dedup refcount conservation — for each content store (CXL and RDMA),
      every stored page's refcount equals the number of live offset-array
      slots pointing at it, counted over catalog entries PLUS in-flight /
      leaked builds the cluster tracks (``pending_regions``): a crashed
      owner may leak references, but the store's words must never drift
      from the sum of causes — and a page must never be freed while any
      snapshot still points at it.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core.coherence import STATE_FREE, STATE_PUBLISHED, STATE_TOMBSTONE
from ..core.failover import NO_MASTER
from ..core.pool import TIER_CXL, TIER_RDMA
from ..core.snapshot import decode_dedup_offsets


class InvariantViolation(AssertionError):
    """A checked coherence/pool invariant failed at a specific (seed, step)."""


class InvariantChecker:
    def __init__(self, cluster):
        self.cluster = cluster
        self.term_history: Dict[int, int] = {}   # lease term -> holder node id
        self.checks_run = 0

    def _fail(self, invariant: str, msg: str) -> None:
        c = self.cluster
        raise InvariantViolation(
            f"[seed={c.seed} step={c.step_no}] {invariant} violated: {msg}\n"
            f"  reproduce with SimCluster(seed={c.seed}) and the same scenario"
        )

    # -- I1 -------------------------------------------------------------------
    def check_refcounts(self) -> None:
        c = self.cluster
        for entry in c.catalog.entries:
            expected = c.live.get(entry.index, 0) + c.midflight.get(entry.index, 0)
            actual = entry.refcount.load()
            if actual != expected:
                self._fail(
                    "I1 refcount==live_borrows+midflight",
                    f"entry {entry.index} ({entry.name!r}): refcount={actual}, "
                    f"live={c.live.get(entry.index, 0)}, "
                    f"midflight={c.midflight.get(entry.index, 0)}")
            if actual < 0:
                self._fail("I5 refcount>=0", f"entry {entry.index}: {actual}")

    # -- I2 -------------------------------------------------------------------
    def check_single_master(self) -> None:
        c = self.cluster
        if c.lease is None:
            return
        term = c.lease.term.load()
        holder = c.lease.holder.load()
        if holder != NO_MASTER and term > 0:
            prev = self.term_history.setdefault(term, holder)
            if prev != holder:
                self._fail("I2 one master per lease term",
                           f"term {term} held by both node {prev} and node {holder}")
        masters = [n.node_id for n in c.nodes.values() if n.is_master]
        if len(masters) > 1:
            self._fail("I2 at most one active master",
                       f"simultaneous masters: {masters}")

    # -- I3 -------------------------------------------------------------------
    def check_pool_conservation(self) -> None:
        for tier in (self.cluster.pool.cxl, self.cluster.pool.rdma):
            free = sorted(tier._free)
            free_bytes = sum(size for _off, size in free)
            if free_bytes + tier.bytes_in_use != tier.capacity:
                self._fail("I3 pool byte conservation",
                           f"tier {tier.name}: free={free_bytes} + "
                           f"in_use={tier.bytes_in_use} != capacity={tier.capacity}")
            prev_end = 0
            for off, size in free:
                if off < 0 or size <= 0 or off + size > tier.capacity:
                    self._fail("I3 free segment in bounds",
                               f"tier {tier.name}: segment ({off}, {size})")
                if off < prev_end:
                    self._fail("I3 free segments disjoint",
                               f"tier {tier.name}: segment ({off}, {size}) "
                               f"overlaps previous ending at {prev_end}")
                prev_end = off + size

    # -- I4 -------------------------------------------------------------------
    def check_borrow_pins(self) -> None:
        for rec in self.cluster.borrow_records:
            entry = rec.borrow.entry
            if entry.regions is not rec.regions:
                self._fail("I4 borrowed regions pinned",
                           f"{rec.host}'s borrow of {rec.name!r} v{rec.version}: "
                           f"entry regions were rewritten while borrowed")
            if entry.version != rec.version:
                self._fail("I4 borrowed version pinned",
                           f"{rec.host}'s borrow of {rec.name!r}: version "
                           f"{rec.version} -> {entry.version} while borrowed")

    # -- I5 -------------------------------------------------------------------
    def check_catalog_sanity(self) -> None:
        valid = (STATE_FREE, STATE_PUBLISHED, STATE_TOMBSTONE)
        for entry in self.cluster.catalog.entries:
            state = entry.state.load()
            if state not in valid:
                self._fail("I5 valid entry state", f"entry {entry.index}: {state}")
            if state == STATE_PUBLISHED and entry.regions is None:
                self._fail("I5 PUBLISHED implies regions",
                           f"entry {entry.index} ({entry.name!r}) has no regions")

    # -- I6 -------------------------------------------------------------------
    def check_dedup_refcounts(self) -> None:
        c = self.cluster
        pool = c.pool
        regions = [e.regions for e in c.catalog.entries
                   if e.regions is not None and e.regions.dedup]
        regions += [r for r in getattr(c, "pending_regions", [])
                    if r is not None and r.dedup]
        for store, tag, tier in ((pool.dedup_cxl, TIER_CXL, "cxl"),
                                 (pool.dedup_rdma, TIER_RDMA, "rdma")):
            actual = store.refcounts()
            if not actual and not regions:
                continue
            expected: Dict[int, int] = {}
            for r in regions:
                offs = decode_dedup_offsets(pool, r, tag)
                uniq, counts = np.unique(offs, return_counts=True)
                for off, k in zip(uniq, counts):
                    expected[int(off)] = expected.get(int(off), 0) + int(k)
            if expected != actual:
                only_store = {o: rc for o, rc in actual.items()
                              if expected.get(o) != rc}
                only_cat = {o: rc for o, rc in expected.items()
                            if actual.get(o) != rc}
                self._fail(
                    "I6 dedup refcount conservation",
                    f"{tier} store refcounts drifted from live catalog "
                    f"offsets: store-side mismatches {only_store}, "
                    f"catalog-side mismatches {only_cat}")

    def check_all(self) -> None:
        self.check_refcounts()
        self.check_single_master()
        self.check_pool_conservation()
        self.check_borrow_pins()
        self.check_catalog_sanity()
        self.check_dedup_refcounts()
        self.checks_run += 1
