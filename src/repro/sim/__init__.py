"""Deterministic multi-host cluster simulator (test infrastructure).

Drives the *real* production objects — ``Catalog``/``LeaseFallback``,
``PoolMaster``, ``FailoverNode``, ``HierarchicalPool``, ``RestoreSession`` —
across N simulated hosts sharing one MHD catalog, under:

* a :class:`VirtualClock` injected through :mod:`repro.core.clock`, so
  timeouts / lease expiries / drain waits are simulated time, not wall time;
* a seeded interleaving scheduler (:class:`SimCluster`) that serializes host
  "steps", so any failure replays exactly from its seed;
* a fault-injection layer (:mod:`repro.sim.faults`): host crash mid-borrow,
  owner crash between tombstone and republish, lease expiry during GC,
  RDMA extent timeout/retry;
* an invariant checker (:mod:`repro.sim.invariants`) run after every step.

See DESIGN.md §9 for the architecture and the invariant list.
"""
from .clock import VirtualClock
from .faults import FaultPlan, FlakyTier, SimTimeout
from .invariants import InvariantChecker, InvariantViolation
from .cluster import BorrowRecord, SimCluster

__all__ = [
    "BorrowRecord",
    "FaultPlan",
    "FlakyTier",
    "InvariantChecker",
    "InvariantViolation",
    "SimCluster",
    "SimTimeout",
    "VirtualClock",
]
