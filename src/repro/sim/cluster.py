"""Deterministic discrete-event cluster simulator.

``SimCluster`` owns one shared pod (``HierarchicalPool`` + ``Catalog`` +
``MasterLease`` under a single :class:`VirtualClock`) and N simulated hosts.
Host behaviour is expressed as **programs**: Python generators that yield a
label after every atomic step (``yield "label"``) or a simulated delay
(``yield ("sleep", seconds)``).  A seeded scheduler picks which runnable
program advances next, so:

  same seed  ⇒  same interleaving  ⇒  same trace  ⇒  same result.

Programs call the *real* production code — ``Catalog.borrow_steps``,
``PoolMaster.publish_steps``, ``FailoverNode.tick``, ``SnapshotReader``,
``Instance``/``RestoreSession`` — decomposed at protocol phase boundaries,
which is exactly where multi-host interleavings (and crashes) matter.

After every step the :class:`InvariantChecker` validates the shared state
against the cluster's independent accounting of all borrows in flight.
"""
from __future__ import annotations

import dataclasses
import random
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core.coherence import Borrow, Catalog
from ..core.failover import FailoverNode, MasterLease
from ..core.faults import TierFaultError
from ..core.master import PoolMaster
from ..core.pagestore import StateImage
from ..core.pool import HierarchicalPool
from ..core.profiler import AccessRecorder, TouchEvent
from ..core.serving import Instance, RestoreSession
from ..core.snapshot import SnapshotReader
from ..topology import (
    InterPodRouter,
    MigrationManager,
    Pod,
    PodGroup,
    PodLinkDown,
    PortLimiter,
    ReplicaManager,
    split_pod_label,
)
from .clock import VirtualClock
from .faults import FaultPlan, SimTimeout
from .invariants import InvariantChecker, InvariantViolation


@dataclasses.dataclass
class BorrowRecord:
    """Cluster-side accounting for one successful borrow."""

    host: str
    name: str
    borrow: Borrow
    regions: object
    version: int
    pod: int = 0


@dataclasses.dataclass
class _Program:
    name: str
    gen: Iterator
    wake_at: float = 0.0
    done: bool = False
    killed: bool = False
    steps: int = 0
    last_label: str = ""


class SimCluster:
    """N-host pod over one shared catalog, driven step-by-step from a seed."""

    def __init__(
        self,
        n_hosts: int = 2,
        seed: int = 0,
        cxl_capacity: int = 64 << 20,
        rdma_capacity: int = 128 << 20,
        catalog_capacity: int = 16,
        lease_timeout_s: float = 0.2,
        beat_interval_s: float = 0.05,
        schedule: str = "random",
        step_quantum_s: float = 1e-6,
        cxl_budget: Optional[int] = None,
        n_pods: int = 1,
        ports_per_pod: Optional[int] = None,
    ):
        assert schedule in ("random", "round_robin")
        self.seed = seed
        self.rng = random.Random(seed)
        self.schedule = schedule
        # every step costs a small time quantum, so sleeping programs always
        # wake even while non-sleeping programs stay runnable (no starvation)
        self.step_quantum_s = step_quantum_s
        self.clock = VirtualClock()
        # topology: ``n_pods > 1`` builds a PodGroup of per-pod pool/
        # catalog/master triples plus the replication/routing layer; pod 0
        # doubles as the legacy single-pod view (self.pool/catalog/master
        # alias it) so every existing scenario runs unchanged
        if n_pods > 1:
            self.group: Optional[PodGroup] = PodGroup(
                n_pods, cxl_capacity, rdma_capacity,
                catalog_capacity=catalog_capacity,
                ports_per_pod=ports_per_pod, cxl_budget=cxl_budget,
                clock=self.clock)
            self.pods: List[Pod] = self.group.pods
            self.pool = self.pods[0].pool
            self.catalog = self.pods[0].catalog
            self.master = self.pods[0].master
            self.router: Optional[InterPodRouter] = InterPodRouter(self.group)
            self.replicas: Optional[ReplicaManager] = ReplicaManager(
                self.group, self.router)
            self.migrator: Optional[MigrationManager] = MigrationManager(
                self.replicas)
        else:
            self.group = None
            self.router = None
            self.replicas = None
            self.migrator = None
            self.pool = HierarchicalPool(cxl_capacity, rdma_capacity,
                                         clock=self.clock)
            self.catalog = Catalog(catalog_capacity, clock=self.clock)
            # the pod's initial pool master (outside the failover group);
            # cxl_budget arms the capacity manager for eviction scenarios
            self.master = PoolMaster(self.pool, self.catalog,
                                     cxl_budget=cxl_budget)
            self.pods = [Pod(0, self.pool, self.catalog, self.master,
                             PortLimiter())]
        self.lease = MasterLease(lease_timeout_s, clock=self.clock)
        # failover-capable nodes, one per host (ids 1..N; 0 is NO_MASTER)
        self.nodes: Dict[int, FailoverNode] = {
            i: FailoverNode(i, self.pool, self.catalog, self.lease,
                            beat_interval_s=beat_interval_s, clock=self.clock)
            for i in range(1, n_hosts + 1)
        }
        self._programs: Dict[str, _Program] = {}
        self._order: List[str] = []        # insertion order (round_robin)
        self._rr_next = 0
        self.step_no = 0
        self.trace: List[Tuple[int, str, str]] = []
        self.events: List[str] = []
        # borrow accounting ((pod id, entry index) -> counts); orphans from
        # crashed programs stay counted — the refcount they leaked is real.
        self.live: Dict[Tuple[int, int], int] = {}
        self.midflight: Dict[Tuple[int, int], int] = {}
        self.borrow_records: List[BorrowRecord] = []
        self.orphaned_records: List[BorrowRecord] = []
        # dedup (I6) accounting: regions built by an in-flight publish that
        # the catalog does not point at yet.  A crashed owner leaves its
        # record here forever — the references it leaked are still real.
        # ``pending_regions`` is pod 0's list (single-pod back-compat);
        # ``pending_by_pod`` holds every pod's, keyed by pod id.
        self.pending_regions: List[object] = []
        self.pending_by_pod: Dict[int, List[object]] = {0: self.pending_regions}
        for _p in self.pods[1:]:
            self.pending_by_pod[_p.pod_id] = []
        # canonical content per (name, version): the published StateImage
        self.content: Dict[str, Dict[int, StateImage]] = {}
        self.restored: List[dict] = []
        self.fault_plan = FaultPlan()
        self.checker = InvariantChecker(self)

    # ------------------------------------------------------------------
    # snapshot helpers
    # ------------------------------------------------------------------
    def make_image(self, value: float, hot_pages: int = 2, cold_pages: int = 2,
                   zero_pages: int = 1,
                   distinct_hot: bool = False) -> Tuple[StateImage, np.ndarray]:
        """A small image with hot / cold / zero page classes; 'hot' pages are
        filled with ``value`` so borrowers can verify which version they see.

        ``distinct_hot`` makes every hot page's content distinct (a function
        of ``value`` and the page rank), so two snapshots published with the
        same value share page-for-page under dedup while each snapshot's own
        pages stay unique — the fine-tuned-variant shape the dedup scenarios
        need."""
        hot = np.full(hot_pages * 1024, np.float32(value), np.float32)
        if distinct_hot:
            ranks = np.repeat(np.arange(hot_pages, dtype=np.float32), 1024)
            hot = hot + ranks * np.float32(0.125)
        arrays = {
            "hot": hot,
            "cold": np.arange(cold_pages * 1024, dtype=np.float32) + np.float32(value),
            "zeros": np.zeros(max(1, zero_pages) * 1024, np.float32),
        }
        img = StateImage.build(arrays)
        rec = AccessRecorder(img.manifest)
        rec.touch_array("hot")
        return img, rec.working_set()

    def publish(self, name: str, value: float, master: Optional[PoolMaster] = None,
                dedup: Optional[bool] = None, publish_fn=None,
                **image_kw) -> object:
        """Immediate (setup-time) publish through the production path.
        ``publish_fn`` passes through to ``PoolMaster.publish`` — the chaos
        scenarios use the fused publish so snapshots carry checksum tables."""
        master = master or self.master
        img, ws = self.make_image(value, **image_kw)
        regions = master.publish(name, img, ws, dedup=dedup,
                                 publish_fn=publish_fn)
        self.content.setdefault(name, {})[regions.version] = img
        self.events.append(f"published:{name}:v{regions.version}")
        return regions

    # ------------------------------------------------------------------
    # program management + the scheduler
    # ------------------------------------------------------------------
    def add_program(self, name: str, gen: Iterator) -> None:
        assert name not in self._programs, f"duplicate program {name!r}"
        self._programs[name] = _Program(name, gen)
        self._order.append(name)

    def add_heartbeat(self, node_id: int, name: Optional[str] = None) -> None:
        self.add_program(name or f"hb{node_id}",
                         self.heartbeat_program(self.nodes[node_id]))

    def kill_program(self, name: str) -> None:
        """Simulated host crash: the program never runs again.  Its live
        borrows and in-flight refcount increments leak (stay counted)."""
        prog = self._programs[name]
        if prog.done:
            return
        prog.done = prog.killed = True
        prog.gen.close()
        mine = [r for r in self.borrow_records if r.host == name]
        for r in mine:
            self.borrow_records.remove(r)
            self.orphaned_records.append(r)
            # keep self.live[...] counted: the refcount is still held
        self.events.append(f"crashed:{name}")

    def crash_node(self, node_id: int) -> None:
        """Crash a failover node: its heartbeat program dies with it."""
        hb = f"hb{node_id}"
        if hb in self._programs:
            self.kill_program(hb)
        self.nodes[node_id].crash()
        self.events.append(f"node_crashed:{node_id}")

    def _runnable(self) -> List[str]:
        now = self.clock.monotonic()
        return [n for n in self._order
                if not self._programs[n].done and self._programs[n].wake_at <= now]

    def _pick(self) -> Optional[str]:
        runnable = self._runnable()
        if not runnable:
            pending = [self._programs[n].wake_at for n in self._order
                       if not self._programs[n].done]
            if not pending:
                return None
            # discrete-event jump: advance virtual time to the next wakeup
            self.clock.advance_to(min(pending))
            runnable = self._runnable()
            assert runnable
        if self.schedule == "round_robin":
            for _ in range(len(self._order)):
                name = self._order[self._rr_next % len(self._order)]
                self._rr_next += 1
                if name in runnable:
                    return name
            return runnable[0]
        return self.rng.choice(runnable)

    def step(self) -> bool:
        """Advance one program by one step; False when nothing is left."""
        self.fault_plan.run_step_hooks(self.step_no, self)
        self.clock.advance(self.step_quantum_s)
        name = self._pick()
        if name is None:
            return False
        prog = self._programs[name]
        try:
            label = next(prog.gen)
        except StopIteration:
            prog.done = True
            label = "exit"
        if isinstance(label, tuple) and label and label[0] == "sleep":
            prog.wake_at = self.clock.monotonic() + float(label[1])
            label = f"sleep:{label[1]:g}"
        label = str(label)
        prog.steps += 1
        prog.last_label = label
        self.trace.append((self.step_no, name, label))
        if not prog.done and self.fault_plan.should_kill(name, label):
            self.kill_program(name)
        self.step_no += 1
        self.checker.check_all()
        return True

    def run(self, max_steps: int = 20000, until=None) -> List[Tuple[int, str, str]]:
        """Run until all programs finish, ``until(cluster)`` turns true, or
        the step budget is exhausted.  Returns the trace."""
        while self.step_no < max_steps:
            if until is not None and until(self):
                break
            if not self.step():
                break
        return self.trace

    # ------------------------------------------------------------------
    # tracked borrow/release (keeps the invariant accounting honest)
    # ------------------------------------------------------------------
    def borrow_program_steps(self, host: str, name: str, precheck: bool = True,
                             pod: int = 0):
        """``yield from`` this inside a host program: advances the real
        ``Catalog.borrow_steps`` one protocol phase per scheduler turn and
        maintains the cluster's refcount accounting (keyed by ``(pod,
        entry index)``).  Returns a :class:`BorrowRecord` (or None ⇒ cold
        start) via StopIteration."""
        result: Optional[BorrowRecord] = None
        catalog = self.pods[pod].catalog
        for label, val in catalog.borrow_steps(name, state_precheck=precheck):
            if label == "refcount_incremented":
                key = (pod, val.index)
                self.midflight[key] = self.midflight.get(key, 0) + 1
            elif label == "doomed":
                key = (pod, val.index)
                self.midflight[key] = self.midflight.get(key, 0) - 1
            elif label == "done" and val is not None:
                key = (pod, val.entry.index)
                self.midflight[key] = self.midflight.get(key, 0) - 1
                self.live[key] = self.live.get(key, 0) + 1
                result = BorrowRecord(host, name, val, val.regions,
                                      val.version, pod=pod)
                self.borrow_records.append(result)
            yield f"borrow:{label}"
        return result

    def release(self, rec: BorrowRecord) -> None:
        rec.borrow.release()
        self.live[(rec.pod, rec.borrow.entry.index)] -= 1
        self.borrow_records.remove(rec)

    def track_borrow(self, host: str, name: str, borrow: Optional[Borrow],
                     pod: int = 0) -> Optional[BorrowRecord]:
        """Account for a borrow acquired outside ``borrow_program_steps``
        (e.g. through ``LeaseFallback.acquire``, which is one atomic RPC)."""
        if borrow is None:
            return None
        key = (pod, borrow.entry.index)
        self.live[key] = self.live.get(key, 0) + 1
        rec = BorrowRecord(host, name, borrow, borrow.regions, borrow.version,
                           pod=pod)
        self.borrow_records.append(rec)
        return rec

    # ------------------------------------------------------------------
    # host program library
    # ------------------------------------------------------------------
    @staticmethod
    def delayed(delay_s: float, gen: Iterator):
        """Start ``gen`` only after ``delay_s`` of simulated time (scenario
        scripting: e.g. let a borrow land before the owner tombstones)."""
        yield ("sleep", delay_s)
        yield from gen

    def elected_master(self) -> Optional[PoolMaster]:
        """The PoolMaster of whichever failover node currently holds the
        lease, if any."""
        for node in self.nodes.values():
            if node.is_master:
                return node.master
        return None

    def heartbeat_program(self, node: FailoverNode):
        """The failover heartbeat loop as a schedulable program: exactly the
        body of ``FailoverNode._loop`` under the virtual clock."""
        while True:
            node.tick()
            yield "tick"
            yield ("sleep", node.beat_interval_s)

    def _drain_poll(self, name: str, gen, label: str, polls: int,
                    drain_limit: Optional[int], drain_sleep: float):
        """Shared drain/livelock guard for the publish and recurate
        programs: counts ``draining``/``owner_busy`` polls and aborts the
        protocol generator with a ``drain_timeout:<name>`` event once
        ``drain_limit`` is exhausted (the TimeoutError analogue).  Used via
        ``yield from``; returns ``(polls, aborted)``."""
        if label not in ("draining", "owner_busy"):
            return polls, False
        polls += 1
        if drain_limit is not None and polls >= drain_limit:
            self.events.append(f"drain_timeout:{name}")
            gen.close()
            return polls, True
        yield ("sleep", drain_sleep)
        return polls, False

    def publish_program(self, name: str, value: float,
                        master: Optional[PoolMaster] = None,
                        drain_limit: Optional[int] = None,
                        drain_sleep: float = 1e-5,
                        dedup: Optional[bool] = None, **image_kw):
        """Owner update through ``PoolMaster.publish_steps``, one protocol
        phase per scheduler turn.  ``drain_limit`` bounds the drain polls
        (TimeoutError analogue): on exhaustion the program records
        ``drain_timeout:<name>`` and aborts — the livelock detector.

        Built-but-unpublished regions are tracked in ``pending_regions`` for
        the I6 checker: between the build and the catalog republish (or
        forever, if the owner crashes in that window) their dedup page
        references are real but no catalog entry points at them."""
        master = master or self.master
        img, ws = self.make_image(value, **image_kw)
        polls = 0
        built = None
        gen = master.publish_steps(name, img, ws, dedup=dedup)
        for label, val in gen:
            if label in ("built_new", "rebuilt"):
                built = val
                self.pending_regions.append(val)
            elif label == "done":
                # record canonical content BEFORE yielding: the republish has
                # already made this version borrowable, so a borrower
                # scheduled next turn must find it in the content table
                if built is not None:
                    self.pending_regions.remove(built)
                    built = None
                self.content.setdefault(name, {})[val.version] = img
                self.events.append(f"published:{name}:v{val.version}")
            yield f"publish:{label}"
            polls, aborted = yield from self._drain_poll(
                name, gen, label, polls, drain_limit, drain_sleep)
            if aborted:
                return

    def delete_program(self, name: str, master: Optional[PoolMaster] = None,
                       gc_polls: int = 8, gc_sleep: float = 1e-4):
        """Owner delete: tombstone + deferred reclaim, polling gc() so the
        scheduler can interleave releases (and lease expiry) mid-GC."""
        master = master or self.master
        if not master.delete(name, gc_now=False):
            yield "delete:missing"
            return
        yield "delete:tombstoned"
        for _ in range(gc_polls):
            if master.gc() or not master._pending_reclaim:
                yield "delete:gc_done"
                return
            yield "delete:gc_pending"
            yield ("sleep", gc_sleep)
        self.events.append(f"gc_incomplete:{name}")
        yield "delete:gc_gave_up"

    def borrower_program(self, host: str, name: str, attempts: int = 4,
                         read_pages: int = 2, precheck: bool = True,
                         pause_s: float = 1e-4):
        """Borrow → clflush → read hot pages → verify against the canonical
        image for the borrowed version → release, ``attempts`` times.  A torn
        or stale read raises InvariantViolation (the I4 data-level check)."""
        successes = 0
        for i in range(attempts):
            rec = yield from self.borrow_program_steps(host, name, precheck)
            if rec is None:
                self.events.append(f"cold_start:{host}")
                yield ("sleep", pause_s)
                continue
            view = self.pool.host_view(f"{host}:a{i}")
            reader = SnapshotReader(rec.borrow.regions, view, self.pool.rdma)
            reader.invalidate_cxl()
            yield "borrower:flushed"
            canonical = self.content[name][rec.version].pages_matrix()
            for p in reader.hot_page_indices()[:read_pages]:
                got = reader.read_page(int(p))
                if not np.array_equal(got, canonical[int(p)]):
                    raise InvariantViolation(
                        f"[seed={self.seed} step={self.step_no}] {host} observed "
                        f"torn/stale bytes of {name!r} v{rec.version} page {int(p)}")
                yield "borrower:read"
            self.release(rec)
            successes += 1
            yield "borrower:released"
            yield ("sleep", pause_s)
        self.events.append(f"borrower_done:{host}:{successes}/{attempts}")

    def tight_borrower_program(self, host: str, name: str, precheck: bool = True):
        """Infinite tight retry loop, one borrow attempt per scheduler turn:
        each turn finishes the previous attempt (CAS → release/back-out) and
        immediately starts the next, pausing *between* the refcount increment
        and the CAS.  Without the PR-1 state pre-check this keeps the shared
        refcount permanently elevated at every owner drain poll — the
        doomed-borrow livelock."""
        pending = None
        while True:
            if pending is not None:
                rec = None
                try:
                    while True:
                        next(pending)
                except StopIteration as stop:
                    rec = stop.value
                if rec is not None:
                    self.release(rec)
            pending = self.borrow_program_steps(host, name, precheck=precheck)
            label = next(pending, None)     # pause mid-borrow if the path allows
            yield label if label is not None else "borrow:noop"

    def drift_borrower_program(self, host: str, name: str, heat_registry,
                               attempts: int = 3, cold_reads: int = 2,
                               pause_s: float = 1e-4):
        """Borrower whose working set has DRIFTED off the snapshot's frozen
        hot set: each attempt borrows, touches one hot page (keep-hot
        signal) and demand-reads ``cold_reads`` cold pages, recording both
        into the pod's :class:`~repro.core.profiler.HeatRegistry` keyed by
        the borrowed version — the online-feedback signal the re-curation
        pipeline consumes.  Every cold read is verified against the
        canonical image (torn/stale bytes raise, the I4 data-level check).
        """
        for i in range(attempts):
            rec = yield from self.borrow_program_steps(host, name)
            if rec is None:
                self.events.append(f"cold_start:{host}")
                yield ("sleep", pause_s)
                continue
            view = self.pool.host_view(f"{host}:d{i}")
            reader = SnapshotReader(rec.borrow.regions, view, self.pool.rdma)
            reader.invalidate_cxl()
            yield "borrower:flushed"
            hm = heat_registry.map_for(name, rec.version,
                                       rec.borrow.regions.total_pages)
            hm.note_restore()
            # one sequence stream per restore attempt (deterministic id:
            # crc of host+attempt) — the cold reads below feed first-touch
            # transitions in demand order, not just decayed heat
            stream = zlib.crc32(f"{host}:{i}".encode())
            canonical = self.content[name][rec.version].pages_matrix()
            hot = reader.hot_page_indices()
            if hot.size:
                hm.record(TouchEvent(pages=hot[:1], kind="touch",
                                     stream=stream))
            for p in reader.cold_page_indices()[:cold_reads]:
                got = reader.read_page(int(p))
                if not np.array_equal(got, canonical[int(p)]):
                    raise InvariantViolation(
                        f"[seed={self.seed} step={self.step_no}] {host} observed "
                        f"torn/stale cold bytes of {name!r} v{rec.version} "
                        f"page {int(p)}")
                hm.record(TouchEvent(pages=[int(p)], kind="demand_fault",
                                     stream=stream))
                yield "borrower:cold_read"
            self.release(rec)
            yield "borrower:released"
            yield ("sleep", pause_s)
        self.events.append(f"drift_done:{host}")

    def recurate_program(self, name: str, heat_registry,
                         master: Optional[PoolMaster] = None,
                         expected_restores: int = 64, min_restores: int = 1,
                         force: bool = False,
                         drain_limit: Optional[int] = None,
                         drain_sleep: float = 1e-5):
        """Heat-feedback re-curation through ``PoolMaster.recurate_steps``,
        one protocol phase per scheduler turn.  The rebuilt image is
        recorded as the canonical content of the new version the moment the
        republish lands, so borrowers scheduled next turn verify against
        it (re-curated restores must stay bit-identical)."""
        master = master or self.master
        entry = self.catalog.find(name)
        heat = None
        if entry is not None and entry.regions is not None:
            heat = heat_registry.find(name, entry.regions.version)
        polls = 0
        reconstructed = None
        built = None
        gen = master.recurate_steps(name, heat=heat,
                                    expected_restores=expected_restores,
                                    min_restores=min_restores, force=force)
        for label, val in gen:
            if label == "reconstructed":
                reconstructed = val
            elif label in ("built_new", "rebuilt"):
                built = val
                self.pending_regions.append(val)
            elif label == "skipped":
                self.events.append(f"recuration_skipped:{name}")
            elif label == "stale":
                self.events.append(f"recuration_stale:{name}")
            elif label == "done":
                assert reconstructed is not None
                if built is not None:
                    self.pending_regions.remove(built)
                    built = None
                self.content.setdefault(name, {})[val.version] = reconstructed
                self.events.append(f"recurated:{name}:v{val.version}")
            yield f"recurate:{label}"
            polls, aborted = yield from self._drain_poll(
                name, gen, label, polls, drain_limit, drain_sleep)
            if aborted:
                return

    def restore_program(self, host: str, name: str, rdma=None,
                        use_batch: bool = True, max_retries: int = 6,
                        retry_backoff_s: float = 1e-4, precheck: bool = True,
                        scatter_fn=None):
        """Full warm restore via the production ``RestoreSession`` pieces
        (zeropage ranges, run-coalesced hot pre-install, cold extent reads),
        one run per scheduler turn, with SimTimeout retry/backoff on the
        (possibly flaky) RDMA tier.  Verifies the restored image is
        bit-identical to the canonical one for the borrowed version.

        ``scatter_fn`` (e.g. a ``FusedScatter``) turns on checksum
        verification against the snapshot's publish-time table, so injected
        page poison is detected at install time and repaired through the
        session's budgeted re-read path.  A CXL brownout degrades the
        restore to the RDMA-only path (``drain_degraded_hot``) instead of
        failing it; either way the bit-identity check below still runs."""
        rec = yield from self.borrow_program_steps(host, name, precheck)
        if rec is None:
            self.events.append(f"cold_start:{host}")
            return
        rdma = rdma if rdma is not None else self.pool.rdma
        view = self.pool.host_view(host)
        reader = SnapshotReader(rec.borrow.regions, view, rdma)
        reader.invalidate_cxl()
        manifest, _meta = reader.machine_state()
        inst = Instance(StateImage.empty_like(manifest), clock=self.clock)
        session = RestoreSession(reader, inst, None, scatter_fn=scatter_fn,
                                 clock=self.clock)
        yield "restore:setup"
        for start, n in reader.zero_runs():
            inst.uffd_zeropage_range(int(start), int(n))
        yield "restore:zeros"
        session.pre_install_hot(use_batch=use_batch)
        yield "restore:hot"
        retries = 0
        # the extent walk handles every layout: whole guest runs for the
        # private format, dual-contiguous sub-extents for dedup snapshots
        for es, en, rank0, pool_off, nbytes in reader.iter_cold_extents(
                max_extent_pages=1 << 20):
            while True:
                try:
                    payload = rdma.read(pool_off, nbytes)
                    break
                # TierFaultError covers both seams: FlakyTier's SimTimeout
                # subclasses it, and an attached core FaultInjector raises
                # it from MemoryTier.read directly
                except TierFaultError:
                    retries += 1
                    if retries > max_retries:
                        self.release(rec)
                        raise
                    yield ("sleep", retry_backoff_s * (2 ** retries))
                    yield "restore:rdma_retry"
            session._install_verified(np.arange(es, es + en),
                                      reader.split_cold_extent(rank0, en, payload))
            yield "restore:cold_run"
        if session.degraded_cxl:
            # CXL brownout tripped the breaker during pre-install: the hot
            # set arrives over the RDMA fabric via the demand path — the
            # restore degrades, it does not fail
            session.drain_degraded_hot()
            self.events.append(f"degraded_restore:{host}:{name}")
            yield "restore:degraded"
        canonical = self.content[name][rec.version]
        if not inst.all_present() or not np.array_equal(inst.image.buf, canonical.buf):
            raise InvariantViolation(
                f"[seed={self.seed} step={self.step_no}] {host}: restore of "
                f"{name!r} v{rec.version} is not bit-identical")
        self.restored.append({
            "host": host, "name": name, "version": rec.version,
            "retries": retries, "batched": use_batch,
            "degraded": session.degraded_cxl,
            "repairs": session.repair_stats["checksum_repairs"],
            "ledger": dict(inst.ledger.seconds),
            "uffd_copies": inst.stats["uffd_copies"],
            "uffd_zeropages": inst.stats["uffd_zeropages"],
        })
        yield "restore:verified"
        self.release(rec)
        yield "restore:released"

    def predicted_restore_program(self, host: str, name: str, heat_registry,
                                  max_extent_pages: int = 8):
        """Warm restore that installs cold extents in PREDICTED first-touch
        order (:class:`~repro.core.prefetch_model.PredictedOrderPolicy` over
        the pod's heat telemetry) instead of layout order, one extent per
        scheduler turn, then verifies bit-identity against the canonical
        content — the §17 invariant: a policy re-orders fetches, it can
        never change installed bytes.  Falls back to layout order when the
        registry holds no sequence telemetry for the borrowed version."""
        from ..core.prefetch_model import PredictedOrderPolicy

        rec = yield from self.borrow_program_steps(host, name)
        if rec is None:
            self.events.append(f"cold_start:{host}")
            return
        view = self.pool.host_view(host)
        reader = SnapshotReader(rec.borrow.regions, view, self.pool.rdma)
        reader.invalidate_cxl()
        manifest, _meta = reader.machine_state()
        inst = Instance(StateImage.empty_like(manifest), clock=self.clock)
        session = RestoreSession(reader, inst, None, clock=self.clock)
        session.heat = heat_registry.find(name, rec.version)
        yield "restore:setup"
        for start, n in reader.zero_runs():
            inst.uffd_zeropage_range(int(start), int(n))
        session.pre_install_hot()
        yield "restore:hot"
        policy = PredictedOrderPolicy(max_extent_pages)
        predicted = (session.heat is not None
                     and session.heat.stats.get("seq_transitions", 0) > 0)
        for es, en, rank0, pool_off, nbytes in policy.order_extents(
                session, None):
            payload = self.pool.rdma.read(pool_off, nbytes)
            session._install_verified(
                np.arange(es, es + en),
                reader.split_cold_extent(rank0, en, payload))
            yield "restore:predicted_cold"
        canonical = self.content[name][rec.version]
        if not inst.all_present() or not np.array_equal(inst.image.buf,
                                                        canonical.buf):
            raise InvariantViolation(
                f"[seed={self.seed} step={self.step_no}] {host}: predicted-"
                f"order restore of {name!r} v{rec.version} is not "
                f"bit-identical")
        self.restored.append({
            "host": host, "name": name, "version": rec.version,
            "predicted_order": predicted,
            "ledger": dict(inst.ledger.seconds),
            "uffd_copies": inst.stats["uffd_copies"],
        })
        self.events.append(
            f"predicted_restore:{host}:{name}:"
            f"{'model' if predicted else 'layout'}")
        yield "restore:verified"
        self.release(rec)
        yield "restore:released"

    # ------------------------------------------------------------------
    # multi-pod program library (n_pods > 1; DESIGN.md §16)
    # ------------------------------------------------------------------
    def _drive_group_steps(self, tag: str, name: str, gen, img,
                           drain_limit: Optional[int], drain_sleep: float):
        """Shared wrapper over the ReplicaManager step generators: tracks
        per-pod pending regions for I6, records canonical content (``img``)
        the moment a replica republishes (``pod<i>:done``), translates
        drain/busy labels into scheduler sleeps, and aborts on
        ``drain_limit`` exhaustion."""
        polls = 0
        built: Dict[int, object] = {}
        for label, val in gen:
            pid, base = split_pod_label(label)
            if pid is not None and base in ("built_new", "rebuilt"):
                built[pid] = val
                self.pending_by_pod.setdefault(pid, []).append(val)
            elif pid is not None and base == "done":
                if pid in built:
                    self.pending_by_pod[pid].remove(built.pop(pid))
                # this replica is borrowable NOW: a borrower scheduled next
                # turn must find the version's canonical bytes
                if img is not None:
                    self.content.setdefault(name, {})[val.version] = img
                self.events.append(f"{tag}:{name}:pod{pid}:v{val.version}")
            elif label == "done":
                self.events.append(f"{tag}_done:{name}")
            yield f"{tag}:{label}"
            if base in ("draining", "owner_busy", "gc_pending") \
                    or label == "group_busy":
                polls += 1
                if drain_limit is not None and polls >= drain_limit:
                    self.events.append(f"drain_timeout:{name}")
                    gen.close()
                    return
                yield ("sleep", drain_sleep)

    def group_publish_program(self, name: str, value: float,
                              pods: Optional[List[int]] = None,
                              drain_limit: Optional[int] = None,
                              drain_sleep: float = 1e-5,
                              dedup: Optional[bool] = None, **image_kw):
        """Replicated publish/update through ``ReplicaManager.publish_steps``
        (group version, lockstep barrier), one protocol phase per turn."""
        img, ws = self.make_image(value, **image_kw)
        gen = self.replicas.publish_steps(name, img, ws, pods=pods,
                                          dedup=dedup)
        yield from self._drive_group_steps("gpub", name, gen, img,
                                           drain_limit, drain_sleep)

    def group_delete_program(self, name: str,
                             drain_limit: Optional[int] = None,
                             drain_sleep: float = 1e-4):
        """Replicated delete: tombstones every replica, then drains/GCs
        each pod — the cross-pod delete drain window of I7."""
        gen = self.replicas.delete_steps(name)
        yield from self._drive_group_steps("gdel", name, gen, None,
                                           drain_limit, drain_sleep)

    def migrate_program(self, name: str, dst_pod: int, expected_reads: int,
                        drop_source: bool = False,
                        drain_limit: Optional[int] = None,
                        drain_sleep: float = 1e-4):
        """Break-even-gated migration through ``MigrationManager``: adds a
        replica at the current version (bit-identical reconstruction) and
        optionally retires the least-demanded source."""
        gen = self.migrator.migrate_steps(name, dst_pod, expected_reads,
                                          drop_source=drop_source)
        yield from self._drive_group_steps("migrate", name, gen, None,
                                           drain_limit, drain_sleep)

    def group_borrower_program(self, host: str, name: str, attempts: int = 4,
                               read_pages: int = 2, pause_s: float = 1e-4):
        """Borrow via replica routing: home-pod CXL when an MHD port
        grants (held for the borrow, detached at release), else inter-pod
        RDMA to the least-served reachable replica; partitioned/dead pods
        fall back to cold start.  Hot reads are verified bit-identical to
        the canonical image; inter-pod reads are charged on the router
        (and a partition landing mid-read aborts the attempt cleanly)."""
        successes = 0
        for i in range(attempts):
            route = self.replicas.borrow_route(host, name)
            if route is None:
                self.events.append(f"cold_start:{host}")
                yield ("sleep", pause_s)
                continue
            mode, pid = route
            pod = self.pods[pid]
            rec = None
            try:
                rec = yield from self.borrow_program_steps(host, name, pod=pid)
                if rec is None:
                    self.events.append(f"cold_start:{host}")
                    yield ("sleep", pause_s)
                    continue
                view = pod.pool.host_view(f"{host}:g{i}")
                reader = SnapshotReader(rec.borrow.regions, view,
                                        pod.pool.rdma)
                reader.invalidate_cxl()
                yield "borrower:flushed"
                canonical = self.content[name][rec.version].pages_matrix()
                for p in reader.hot_page_indices()[:read_pages]:
                    if mode == "interpod":
                        # remote replica: the page crosses the inter-pod
                        # fabric — modeled charge + partition check
                        try:
                            self.router.charge_read(host, pid, 4096)
                        except PodLinkDown:
                            self.events.append(
                                f"partition_abort:{host}:{name}")
                            break
                    got = reader.read_page(int(p))
                    if not np.array_equal(got, canonical[int(p)]):
                        raise InvariantViolation(
                            f"[seed={self.seed} step={self.step_no}] {host} "
                            f"observed torn/stale bytes of {name!r} "
                            f"v{rec.version} page {int(p)} on pod {pid}")
                    yield f"borrower:read:{mode}"
                else:
                    successes += 1
            finally:
                if rec is not None:
                    self.release(rec)
                if mode == "cxl":
                    pod.ports.detach(host)
            yield "borrower:released"
            yield ("sleep", pause_s)
        self.events.append(f"group_borrower_done:{host}:{successes}/{attempts}")

    def partition_program(self, a: int, b: int, delay_s: float,
                          up: bool = False):
        """Scripted fabric event: after ``delay_s`` of simulated time the
        data-plane link between pods ``a`` and ``b`` goes down (or comes
        back with ``up=True``); catalog atomics are unaffected."""
        yield ("sleep", delay_s)
        self.group.set_partition(a, b, up=up)
        self.events.append(
            f"{'heal' if up else 'partition'}:{a}-{b}")
        yield "partitioned" if not up else "healed"

    def pod_loss_program(self, pod_id: int, delay_s: float):
        """Scripted pod loss: after ``delay_s`` the pod dies and the
        replica manager promotes survivors; names that lost their last
        replica are recorded as ``replica_lost:<name>`` events."""
        yield ("sleep", delay_s)
        lost = self.replicas.promote(pod_id)
        self.events.append(f"pod_lost:{pod_id}")
        for name in lost:
            self.events.append(f"replica_lost:{name}")
        yield "pod_lost"
