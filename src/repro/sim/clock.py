"""Virtual time for the deterministic cluster simulator.

``VirtualClock`` implements the :class:`repro.core.clock.Clock` interface
with a simulated-seconds counter that only moves when the simulation says so
(``sleep``/``advance``).  The blocking primitives never actually block: the
simulator is single-threaded, so if a predicate/event is not already
satisfied, no other runner can satisfy it *during* the wait — the clock
advances by the timeout and the condition is re-checked once.  This turns
every wall-clock race in the stack (lease expiry, drain timeout, page-wait)
into deterministic discrete-event state.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

from ..core.clock import Clock


class VirtualClock(Clock):
    """Discrete-event time source; seconds advance only via sleep/advance."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()

    # -- reading --------------------------------------------------------------
    def time(self) -> float:
        with self._lock:
            return self._now

    def monotonic(self) -> float:
        with self._lock:
            return self._now

    def monotonic_ns(self) -> int:
        with self._lock:
            return int(self._now * 1e9)

    # -- advancing ------------------------------------------------------------
    def advance(self, seconds: float) -> None:
        assert seconds >= 0.0, "virtual time cannot run backwards"
        with self._lock:
            self._now += seconds

    def advance_to(self, t: float) -> None:
        """Jump exactly to simulated time ``t`` (no-op if already past it).
        Exact assignment, not ``advance(t - now)``: adding the delta can land
        a float ulp short of ``t`` and leave a sleeper un-runnable."""
        with self._lock:
            self._now = max(self._now, float(t))

    def sleep(self, seconds: float) -> None:
        self.advance(max(0.0, seconds))

    # -- pseudo-blocking primitives -------------------------------------------
    def wait_event(self, event: threading.Event, timeout_s: float) -> bool:
        if event.is_set():
            return True
        self.advance(max(0.0, timeout_s))
        return event.is_set()

    def cv_wait_for(self, cv: threading.Condition, predicate: Callable[[], bool],
                    timeout_s: Optional[float]) -> bool:
        if predicate():
            return True
        if timeout_s is None:
            # an indefinite wait cannot be satisfied in the single-threaded
            # sim (no other runner can notify during it): re-check once
            # without advancing — condition-driven background loops must use
            # background=False / explicit driving under a VirtualClock
            return predicate()
        self.advance(max(0.0, timeout_s))
        return predicate()
