"""Fault injection for the cluster simulator.

Two deterministic mechanisms, both scripted per scenario:

* **label kills** — kill a host program the k-th time it yields a given
  step label.  Because program labels mark protocol phase boundaries
  (``publish:tombstoned``, ``borrow:refcount_incremented``, ...), this
  expresses crashes like "owner dies between tombstone and republish" or
  "host dies mid-borrow" exactly.
* **step hooks** — run an arbitrary callback just before global step N
  (advance the virtual clock past a lease timeout, crash a node, ...).

``FlakyTier`` wraps a ``MemoryTier`` and fails reads/writes with
:class:`SimTimeout` per script — the RDMA extent timeout/retry fault.  It is
the REFERENCE implementation of count-windowed fault schedules: the
production seam (:class:`repro.core.faults.FaultInjector`) is parity-tested
against it, and :class:`SimTimeout` subclasses
:class:`repro.core.faults.TierFaultError` so one ``except`` clause covers
both.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

from ..core.faults import TierFaultError
from ..core.pool import MemoryTier


class SimTimeout(TierFaultError):
    """Injected transfer timeout (RDMA extent read deadline exceeded)."""


class FaultPlan:
    """Scripted faults for one scenario run.  All triggers are functions of
    (program label occurrence, global step number) — both deterministic under
    a fixed seed — so an injected fault replays exactly."""

    def __init__(self):
        # program -> list of [label, remaining_occurrences]
        self._kills: Dict[str, List[List]] = {}
        self._step_hooks: Dict[int, List[Callable]] = {}

    def kill_after(self, program: str, label: str, occurrence: int = 1) -> "FaultPlan":
        """Kill ``program`` right after it yields ``label`` for the
        ``occurrence``-th time (the program never runs again; any refcounts
        or borrows it holds leak, exactly like a host crash)."""
        self._kills.setdefault(program, []).append([label, occurrence])
        return self

    def at_step(self, step_no: int, hook: Callable) -> "FaultPlan":
        """Run ``hook(cluster)`` immediately before global step ``step_no``."""
        self._step_hooks.setdefault(step_no, []).append(hook)
        return self

    # -- used by the scheduler -------------------------------------------------
    def should_kill(self, program: str, label: str) -> bool:
        for entry in self._kills.get(program, ()):
            if entry[0] == label:
                entry[1] -= 1
                if entry[1] <= 0:
                    return True
        return False

    def run_step_hooks(self, step_no: int, cluster) -> None:
        for hook in self._step_hooks.pop(step_no, ()):
            hook(cluster)


@dataclasses.dataclass
class _FailWindow:
    remaining: int                      # how many more reads to fail
    lo: int = 0                         # offset range the fault applies to
    hi: int = 1 << 62


class FlakyTier:
    """Read/write-path proxy over a :class:`MemoryTier` injecting timeouts.

    Everything except ``read``/``write`` is delegated to the wrapped tier,
    so the proxy can be handed to ``SnapshotReader`` in place of the RDMA
    tier.  Scripted failures are consumed in call order → deterministic.
    Stats are symmetric across both directions: ``reads`` /
    ``injected_timeouts`` mirror ``writes`` / ``injected_write_faults``.
    """

    def __init__(self, tier: MemoryTier):
        self._tier = tier
        self._windows: List[_FailWindow] = []
        self._write_windows: List[_FailWindow] = []
        self.stats = {"reads": 0, "injected_timeouts": 0,
                      "writes": 0, "injected_write_faults": 0}

    def fail_reads(self, n: int, lo: int = 0, hi: int = 1 << 62) -> "FlakyTier":
        """Fail the next ``n`` reads that touch [lo, hi)."""
        self._windows.append(_FailWindow(n, lo, hi))
        return self

    def fail_writes(self, n: int, lo: int = 0, hi: int = 1 << 62) -> "FlakyTier":
        """Fail the next ``n`` writes that touch [lo, hi)."""
        self._write_windows.append(_FailWindow(n, lo, hi))
        return self

    @staticmethod
    def _take(windows: List[_FailWindow], offset: int, nbytes: int) -> bool:
        for w in windows:
            if w.remaining > 0 and offset < w.hi and offset + nbytes > w.lo:
                w.remaining -= 1
                return True
        return False

    def read(self, offset: int, nbytes: int) -> np.ndarray:
        self.stats["reads"] += 1
        if self._take(self._windows, offset, nbytes):
            self.stats["injected_timeouts"] += 1
            raise SimTimeout(
                f"injected RDMA timeout: read({offset}, {nbytes})",
                tier=self._tier.name, kind="timeout")
        return self._tier.read(offset, nbytes)

    def write(self, offset: int, data: np.ndarray) -> None:
        self.stats["writes"] += 1
        nbytes = int(np.asarray(data).nbytes)
        if self._take(self._write_windows, offset, nbytes):
            self.stats["injected_write_faults"] += 1
            raise SimTimeout(
                f"injected RDMA write fault: write({offset}, {nbytes})",
                tier=self._tier.name, kind="write")
        return self._tier.write(offset, data)

    def __getattr__(self, name):
        return getattr(self._tier, name)
