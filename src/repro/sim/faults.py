"""Fault injection for the cluster simulator.

Two deterministic mechanisms, both scripted per scenario:

* **label kills** — kill a host program the k-th time it yields a given
  step label.  Because program labels mark protocol phase boundaries
  (``publish:tombstoned``, ``borrow:refcount_incremented``, ...), this
  expresses crashes like "owner dies between tombstone and republish" or
  "host dies mid-borrow" exactly.
* **step hooks** — run an arbitrary callback just before global step N
  (advance the virtual clock past a lease timeout, crash a node, ...).

``FlakyTier`` wraps a ``MemoryTier`` and fails reads with :class:`SimTimeout`
per script — the RDMA extent timeout/retry fault.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

from ..core.pool import MemoryTier


class SimTimeout(Exception):
    """Injected transfer timeout (RDMA extent read deadline exceeded)."""


class FaultPlan:
    """Scripted faults for one scenario run.  All triggers are functions of
    (program label occurrence, global step number) — both deterministic under
    a fixed seed — so an injected fault replays exactly."""

    def __init__(self):
        # program -> list of [label, remaining_occurrences]
        self._kills: Dict[str, List[List]] = {}
        self._step_hooks: Dict[int, List[Callable]] = {}

    def kill_after(self, program: str, label: str, occurrence: int = 1) -> "FaultPlan":
        """Kill ``program`` right after it yields ``label`` for the
        ``occurrence``-th time (the program never runs again; any refcounts
        or borrows it holds leak, exactly like a host crash)."""
        self._kills.setdefault(program, []).append([label, occurrence])
        return self

    def at_step(self, step_no: int, hook: Callable) -> "FaultPlan":
        """Run ``hook(cluster)`` immediately before global step ``step_no``."""
        self._step_hooks.setdefault(step_no, []).append(hook)
        return self

    # -- used by the scheduler -------------------------------------------------
    def should_kill(self, program: str, label: str) -> bool:
        for entry in self._kills.get(program, ()):
            if entry[0] == label:
                entry[1] -= 1
                if entry[1] <= 0:
                    return True
        return False

    def run_step_hooks(self, step_no: int, cluster) -> None:
        for hook in self._step_hooks.pop(step_no, ()):
            hook(cluster)


@dataclasses.dataclass
class _FailWindow:
    remaining: int                      # how many more reads to fail
    lo: int = 0                         # offset range the fault applies to
    hi: int = 1 << 62


class FlakyTier:
    """Read-path proxy over a :class:`MemoryTier` that injects timeouts.

    Everything except ``read`` is delegated to the wrapped tier, so the proxy
    can be handed to ``SnapshotReader`` in place of the RDMA tier.  Scripted
    failures are consumed in call order → deterministic.
    """

    def __init__(self, tier: MemoryTier):
        self._tier = tier
        self._windows: List[_FailWindow] = []
        self.stats = {"reads": 0, "injected_timeouts": 0}

    def fail_reads(self, n: int, lo: int = 0, hi: int = 1 << 62) -> "FlakyTier":
        """Fail the next ``n`` reads that touch [lo, hi)."""
        self._windows.append(_FailWindow(n, lo, hi))
        return self

    def read(self, offset: int, nbytes: int) -> np.ndarray:
        self.stats["reads"] += 1
        for w in self._windows:
            if w.remaining > 0 and offset < w.hi and offset + nbytes > w.lo:
                w.remaining -= 1
                self.stats["injected_timeouts"] += 1
                raise SimTimeout(
                    f"injected RDMA timeout: read({offset}, {nbytes})")
        return self._tier.read(offset, nbytes)

    def __getattr__(self, name):
        return getattr(self._tier, name)
