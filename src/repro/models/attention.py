"""Attention variants: GQA (with optional QKV bias) and DeepSeek-V3 MLA.

Full-sequence paths (train/prefill) route through the flash-attention op
(Pallas on TPU, jnp oracle on CPU); decode paths use einsum attention over
the KV cache (one query — no flash needed).

KV caches:
  GQA : k,v (B, Hkv, S, Dh) — standard cache.
  MLA : latent cache (B, S, kv_lora + qk_rope_head_dim) — the MLA memory
        saving is structural: we cache the compressed latent + rope key only.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.flash_attention.ops import flash_attention
from ..sharding.partition import cache_constrain, constrain
from .common import apply_mrope, apply_rope, dense_init


# ==========================================================================
# GQA
# ==========================================================================

def init_gqa(key, cfg: ModelConfig, dtype) -> Dict:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, hk * dh), dtype),
        "wv": dense_init(ks[2], (d, hk * dh), dtype),
        "wo": dense_init(ks[3], (h * dh, d), dtype, fan_in=h * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hk * dh,), dtype)
        p["bv"] = jnp.zeros((hk * dh,), dtype)
    return p


def _proj_qkv(params, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hk, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hk, dh).transpose(0, 2, 1, 3)
    return q, k, v


def gqa_attention(
    params: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray] = None,
    mrope_pos: Optional[jnp.ndarray] = None,
    causal: bool = True,
) -> jnp.ndarray:
    """Full-sequence GQA. x: (B, S, D)."""
    b, s, _ = x.shape
    q, k, v = _proj_qkv(params, x, cfg)
    if positions is None:
        positions = jnp.arange(s)
    if cfg.mrope and mrope_pos is not None:
        q = apply_mrope(q, mrope_pos, cfg.rope_theta)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_batch_shard:
        # §Perf: when n_heads % TP != 0, head-sharded attention forces
        # per-KV-block partial-sum all-reduces; shard the attention region
        # over batch instead (heads replicated, one gather at the boundary)
        q = constrain(q, ("pod", "data"), None, None, None)
        k = constrain(k, ("pod", "data"), None, None, None)
        v = constrain(v, ("pod", "data"), None, None, None)
    o = flash_attention(q, k, v, causal=causal)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", o, params["wo"])


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hk, max_len, dh), dtype),
        "v": jnp.zeros((batch, hk, max_len, dh), dtype),
    }


def gqa_decode(
    params: Dict,
    x: jnp.ndarray,                 # (B, 1, D)
    cache: Dict,
    pos: jnp.ndarray,               # scalar int32: index of the new token
    cfg: ModelConfig,
    mrope_pos3: Optional[jnp.ndarray] = None,   # (3, 1) M-RoPE components
) -> Tuple[jnp.ndarray, Dict]:
    b = x.shape[0]
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = h // hk
    q, k, v = _proj_qkv(params, x, cfg)       # (B, h, 1, dh), (B, hk, 1, dh)
    if cfg.mrope and mrope_pos3 is not None:
        q = apply_mrope(q, mrope_pos3, cfg.rope_theta)
        k = apply_mrope(k, mrope_pos3, cfg.rope_theta)
    else:
        q = apply_rope(q, pos[None], cfg.rope_theta)
        k = apply_rope(k, pos[None], cfg.rope_theta)
    ck = cache_constrain(jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0)))
    cv = cache_constrain(jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0)))
    qg = q.reshape(b, hk, group, dh)
    # f32 accumulation via preferred_element_type: casting the cache with
    # astype would materialize an f32 copy of the whole stacked cache (XLA
    # hoists the convert out of the layer loop)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, ck,
                   preferred_element_type=jnp.float32)
    s = s * (dh ** -0.5)
    valid = jnp.arange(ck.shape[2])[None, None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h * dh).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["wo"]), {"k": ck, "v": cv}


# ==========================================================================
# MLA (DeepSeek-V3)
# ==========================================================================

def init_mla(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dqn, dqr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, qr), dtype),                      # down
        "wq_b": dense_init(ks[1], (qr, h * (dqn + dqr)), dtype, fan_in=qr),
        "wkv_a": dense_init(ks[2], (d, kr + dqr), dtype),               # latent + rope-k
        "wkv_b": dense_init(ks[3], (kr, h * (dqn + dv)), dtype, fan_in=kr),
        "wo": dense_init(ks[4], (h * dv, d), dtype, fan_in=h * dv),
    }


def _mla_qkv(params, x, positions, cfg: ModelConfig):
    b, s, _ = x.shape
    h = cfg.n_heads
    dqn, dqr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank

    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q = jnp.einsum("bsr,re->bse", q, params["wq_b"]).reshape(b, s, h, dqn + dqr)
    q = q.transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dqn], q[..., dqn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    latent, k_rope = kv[..., :kr], kv[..., kr:]
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # (B,1,S,dqr)

    kvu = jnp.einsum("bsr,re->bse", latent, params["wkv_b"]).reshape(b, s, h, dqn + dv)
    kvu = kvu.transpose(0, 2, 1, 3)
    k_nope, v = kvu[..., :dqn], kvu[..., dqn:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, h, s, dqr))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    return qq, k, v, latent, k_rope


def mla_attention(params: Dict, x: jnp.ndarray, cfg: ModelConfig,
                  positions: Optional[jnp.ndarray] = None,
                  causal: bool = True) -> jnp.ndarray:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v, _, _ = _mla_qkv(params, x, positions, cfg)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    o = flash_attention(q, k, v, causal=causal, scale=scale)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.v_head_dim)
    return jnp.einsum("bse,ed->bsd", o, params["wo"])


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {"latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype)}


def mla_decode_absorbed(params: Dict, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
                        cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """§Perf: weight-absorbed MLA decode (DeepSeek-V2 trick, beyond-paper
    here).  Never re-expands K/V: wkv_b's key half is absorbed into the
    query (q_eff = q_nope · w_kᵀ, rank-kr) and attention runs directly over
    the cached latent; the value half is applied after the softmax.  Per-step
    traffic drops from O(S·h·(dqn+dv)) re-expansion to O(S·kr)."""
    b = x.shape[0]
    h = cfg.n_heads
    dqn, dqr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank

    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q = jnp.einsum("bsr,re->bse", q, params["wq_b"]).reshape(b, 1, h, dqn + dqr)
    q = q.transpose(0, 2, 1, 3)                                # (B,h,1,·)
    q_nope, q_rope = q[..., :dqn], q[..., dqn:]
    q_rope = apply_rope(q_rope, pos[None], cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])         # (B,1,kr+dqr)
    k_rope_new = apply_rope(kv[:, None, :, kr:], pos[None], cfg.rope_theta)
    entry = jnp.concatenate([kv[..., :kr], k_rope_new[:, 0]], axis=-1)
    lat = cache_constrain(jax.lax.dynamic_update_slice(
        cache["latent"], entry.astype(cache["latent"].dtype), (0, pos, 0)
    ), seq_shard=cfg.flash_decoding)
    latent_all, k_rope_all = lat[..., :kr], lat[..., kr:]

    wkv_b = params["wkv_b"].reshape(kr, h, dqn + dv)
    w_k, w_v = wkv_b[..., :dqn], wkv_b[..., dqn:]              # (kr,h,dqn/dv)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0], w_k,
                       preferred_element_type=jnp.float32)       # (B,h,kr)
    s_nope = jnp.einsum("bhr,bsr->bhs", q_eff.astype(latent_all.dtype),
                        latent_all, preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhqd,bsd->bhs", q_rope, k_rope_all,
                        preferred_element_type=jnp.float32)
    sc = (dqn + dqr) ** -0.5
    s = (s_nope + s_rope) * sc
    valid = jnp.arange(lat.shape[1])[None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)                              # (B,h,S)
    ctx = jnp.einsum("bhs,bsr->bhr", p.astype(latent_all.dtype), latent_all,
                     preferred_element_type=jnp.float32)
    o = jnp.einsum("bhr,rhd->bhd", ctx.astype(w_v.dtype), w_v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h * dv).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["wo"]), {"latent": lat}


def mla_decode(params: Dict, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
               cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Latent-cache decode: re-expands K/V from the cached latent (B,S,kr)."""
    if cfg.mla_absorb:
        return mla_decode_absorbed(params, x, cache, pos, cfg)
    b = x.shape[0]
    h = cfg.n_heads
    dqn, dqr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank

    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q = jnp.einsum("bsr,re->bse", q, params["wq_b"]).reshape(b, 1, h, dqn + dqr)
    q = q.transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dqn], q[..., dqn:]
    q_rope = apply_rope(q_rope, pos[None], cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])          # (B,1,kr+dqr)
    k_rope_new = apply_rope(kv[:, None, :, kr:], pos[None], cfg.rope_theta)
    entry = jnp.concatenate([kv[..., :kr], k_rope_new[:, 0]], axis=-1)
    lat = cache_constrain(jax.lax.dynamic_update_slice(
        cache["latent"], entry.astype(cache["latent"].dtype), (0, pos, 0)
    ), seq_shard=cfg.flash_decoding)
    latent_all, k_rope_all = lat[..., :kr], lat[..., kr:]

    kvu = jnp.einsum("bsr,re->bse", latent_all, params["wkv_b"].astype(cache["latent"].dtype))
    kvu = kvu.reshape(b, -1, h, dqn + dv).transpose(0, 2, 1, 3)   # (B,h,S,dqn+dv)
    k_nope, v = kvu[..., :dqn], kvu[..., dqn:]

    sc = (dqn + dqr) ** -0.5
    s_nope = jnp.einsum("bhqd,bhsd->bhqs", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhqd,bsd->bhqs", q_rope, k_rope_all,
                        preferred_element_type=jnp.float32)
    s = (s_nope + s_rope) * sc
    valid = jnp.arange(lat.shape[1])[None, None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bhsd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dv).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["wo"]), {"latent": lat}


# ==========================================================================
# dispatch
# ==========================================================================

def init_attention(key, cfg: ModelConfig, dtype) -> Dict:
    return init_mla(key, cfg, dtype) if cfg.attn_kind == "mla" else init_gqa(key, cfg, dtype)


def attention(params, x, cfg: ModelConfig, positions=None, mrope_pos=None, causal=True):
    if cfg.attn_kind == "mla":
        return mla_attention(params, x, cfg, positions, causal=causal)
    return gqa_attention(params, x, cfg, positions, mrope_pos, causal=causal)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.attn_kind == "mla":
        return init_mla_cache(cfg, batch, max_len, dtype)
    return init_gqa_cache(cfg, batch, max_len, dtype)


def decode(params, x, cache, pos, cfg: ModelConfig, mrope_pos3=None):
    if cfg.attn_kind == "mla":
        return mla_decode(params, x, cache, pos, cfg)
    return gqa_decode(params, x, cache, pos, cfg, mrope_pos3=mrope_pos3)
