"""Decoder-only LM assembly: scan-over-layers + remat, covering the dense,
moe, ssm (xLSTM), hybrid (Zamba2) and vlm families.

Layer heterogeneity is handled by scanning over *homogeneous groups*:
  dense/vlm : scan over identical (attn + SwiGLU) blocks
  moe       : unrolled leading dense layers + scan over MoE blocks
  ssm       : scan over (mLSTM, sLSTM) block pairs
  hybrid    : scan over groups of [shared-attn block + k Mamba2 blocks]
              (the shared block's params are loop-invariant — Zamba2's
              parameter reuse for free)

Caches for decode are stacked along the scan axis and threaded through
lax.scan as xs/ys, so decode HLO is as compact as train HLO.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.partition import constrain
from . import attention as attn_mod
from . import ssm as ssm_mod
from .common import (
    cast_tree,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp,
    mrope_positions,
    rmsnorm,
    unembed,
)
from .moe import init_moe, moe_ffn


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def init_dense_block(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _res_constrain(x, cfg: ModelConfig):
    """Residual-stream sharding: baseline = batch over DP; seq_parallel adds
    Megatron-SP (sequence dim sharded over the TP axis between blocks, which
    divides saved scan carries and their converts by the TP width)."""
    if cfg.seq_parallel:
        return constrain(x, ("pod", "data"), "model", None)
    return constrain(x, ("pod", "data"), None, None)


def dense_block(params, x, cfg: ModelConfig, positions=None, mrope_pos=None):
    x = _res_constrain(x, cfg)
    x = x + attn_mod.attention(params["attn"], rmsnorm(params["ln1"], x), cfg,
                               positions, mrope_pos)
    x = x + mlp(params["mlp"], rmsnorm(params["ln2"], x))
    return _res_constrain(x, cfg)


def dense_block_decode(params, x, cache, pos, cfg: ModelConfig, mrope_pos3=None):
    h, cache = attn_mod.decode(params["attn"], rmsnorm(params["ln1"], x), cache, pos, cfg,
                               mrope_pos3=mrope_pos3)
    x = x + h
    x = x + mlp(params["mlp"], rmsnorm(params["ln2"], x))
    return x, cache


def init_moe_block(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "moe": init_moe(k2, cfg, dtype),
    }


def moe_block(params, x, cfg: ModelConfig, positions=None):
    x = _res_constrain(x, cfg)
    x = x + attn_mod.attention(params["attn"], rmsnorm(params["ln1"], x), cfg, positions)
    y, aux = moe_ffn(params["moe"], rmsnorm(params["ln2"], x), cfg)
    return _res_constrain(x + y, cfg), aux


def moe_block_decode(params, x, cache, pos, cfg: ModelConfig):
    h, cache = attn_mod.decode(params["attn"], rmsnorm(params["ln1"], x), cache, pos, cfg)
    x = x + h
    y, _ = moe_ffn(params["moe"], rmsnorm(params["ln2"], x), cfg, group_size=x.shape[0])
    return x + y, cache


def init_xlstm_pair(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln_m": init_rmsnorm(cfg.d_model, dtype),
        "mlstm": ssm_mod.init_mlstm(k1, cfg, dtype),
        "ln_s": init_rmsnorm(cfg.d_model, dtype),
        "slstm": ssm_mod.init_slstm(k2, cfg, dtype),
    }


def xlstm_pair(params, x, cfg: ModelConfig):
    x = _res_constrain(x, cfg)
    x = x + ssm_mod.mlstm_parallel(params["mlstm"], rmsnorm(params["ln_m"], x), cfg)
    x = x + ssm_mod.slstm_scan(params["slstm"], rmsnorm(params["ln_s"], x), cfg)
    return _res_constrain(x, cfg)


def xlstm_pair_decode(params, x, state, cfg: ModelConfig):
    h, sm = ssm_mod.mlstm_decode(params["mlstm"], rmsnorm(params["ln_m"], x), state["m"], cfg)
    x = x + h
    h, ss = ssm_mod.slstm_decode(params["slstm"], rmsnorm(params["ln_s"], x), state["s"], cfg)
    x = x + h
    return x, {"m": sm, "s": ss}


def init_mamba_block(key, cfg: ModelConfig, dtype) -> Dict:
    return {
        "ln": init_rmsnorm(cfg.d_model, dtype),
        "mamba": ssm_mod.init_mamba2(key, cfg, dtype),
    }


def mamba_block(params, x, cfg: ModelConfig):
    x = _res_constrain(x, cfg)
    return _res_constrain(
        x + ssm_mod.mamba2_ssd(params["mamba"], rmsnorm(params["ln"], x), cfg), cfg)


def mamba_block_decode(params, x, state, cfg: ModelConfig):
    h, state = ssm_mod.mamba2_decode(params["mamba"], rmsnorm(params["ln"], x), state, cfg)
    return x + h, state


# --------------------------------------------------------------------------
# LM assembly
# --------------------------------------------------------------------------

def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_lm(key, cfg: ModelConfig) -> Dict:
    dtype = cfg.pdtype()
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, dtype, cfg.tie_embeddings,
                                   padded_vocab=cfg.padded_vocab),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _stack_init(
            keys[1], cfg.n_layers, lambda k: init_dense_block(k, cfg, dtype)
        )
    elif fam == "moe":
        if cfg.n_dense_layers:
            params["dense_layers"] = [
                init_dense_block(k, cfg, dtype)
                for k in jax.random.split(keys[1], cfg.n_dense_layers)
            ]
        params["layers"] = _stack_init(
            keys[2], cfg.n_layers - cfg.n_dense_layers,
            lambda k: init_moe_block(k, cfg, dtype),
        )
        if cfg.mtp:
            k1, k2 = jax.random.split(keys[3])
            params["mtp"] = {
                "norm_h": init_rmsnorm(cfg.d_model, dtype),
                "norm_e": init_rmsnorm(cfg.d_model, dtype),
                "proj": jax.random.normal(k1, (2 * cfg.d_model, cfg.d_model), dtype) * 0.02,
                "block": init_dense_block(k2, cfg, dtype),
            }
    elif fam == "ssm":
        assert cfg.n_layers % 2 == 0
        params["layers"] = _stack_init(
            keys[1], cfg.n_layers // 2, lambda k: init_xlstm_pair(k, cfg, dtype)
        )
    elif fam == "hybrid":
        k = cfg.attn_every
        assert cfg.n_layers % k == 0
        params["layers"] = _stack_init(
            keys[1], cfg.n_layers // k,
            lambda kk: _stack_init(kk, k, lambda k2: init_mamba_block(k2, cfg, dtype)),
        )
        params["shared_attn"] = init_dense_block(keys[2], cfg, dtype)
    else:
        raise ValueError(f"init_lm does not handle family {fam}")
    return params


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _lm_trunk(
    params: Dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    vision_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> (pre-final-norm hidden states (B, S, D), aux)."""
    cdt = cfg.cdtype()
    b, s = tokens.shape
    x = embed(params["embed"], tokens, cdt)
    x = constrain(x, ("pod", "data"), None, None)
    mrope_pos = None
    if cfg.family == "vlm":
        if vision_embeds is not None:
            vp = vision_embeds.shape[1]
            x = jnp.concatenate([vision_embeds.astype(cdt), x[:, vp:]], axis=1)
        mrope_pos = mrope_positions(s, cfg.vision_prefix, cfg.vision_grid)
    positions = jnp.arange(s)
    aux = jnp.zeros((), jnp.float32)
    cparams = cast_tree(params, cdt)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        blk = _maybe_remat(
            lambda p, h: dense_block(p, h, cfg, positions, mrope_pos), cfg
        )
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda h, p: (blk(p, h), None), x, cparams["layers"])
        else:
            n = jax.tree.leaves(cparams["layers"])[0].shape[0]
            for i in range(n):
                x = blk(jax.tree.map(lambda t: t[i], cparams["layers"]), x)
    elif fam == "moe":
        for p in cparams.get("dense_layers", []):
            x = _maybe_remat(lambda pp, h: dense_block(pp, h, cfg, positions), cfg)(p, x)
        def moe_step(carry, p):
            h, a = carry
            fn = _maybe_remat(lambda pp, hh: moe_block(pp, hh, cfg, positions), cfg)
            h, da = fn(p, h)
            return (h, a + da), None
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(moe_step, (x, aux), cparams["layers"])
        else:
            n = jax.tree.leaves(cparams["layers"])[0].shape[0]
            for i in range(n):
                (x, aux), _ = moe_step((x, aux), jax.tree.map(lambda t: t[i], cparams["layers"]))
    elif fam == "ssm":
        blk = _maybe_remat(lambda p, h: xlstm_pair(p, h, cfg), cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda h, p: (blk(p, h), None), x, cparams["layers"])
        else:
            n = jax.tree.leaves(cparams["layers"])[0].shape[0]
            for i in range(n):
                x = blk(jax.tree.map(lambda t: t[i], cparams["layers"]), x)
    elif fam == "hybrid":
        shared = cparams["shared_attn"]
        def group(p, h):
            h = dense_block(shared, h, cfg, positions)        # shared attn block
            def inner(hh, pp):
                return mamba_block(pp, hh, cfg), None
            h, _ = jax.lax.scan(inner, h, p)
            return h
        blk = _maybe_remat(group, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda h, p: (blk(p, h), None), x, cparams["layers"])
        else:
            n = jax.tree.leaves(cparams["layers"])[0].shape[0]
            for i in range(n):
                x = blk(jax.tree.map(lambda t: t[i], cparams["layers"]), x)
    else:
        raise ValueError(fam)

    return x, aux


def lm_forward(
    params: Dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    vision_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> (logits (B, S, V) f32, aux_loss scalar)."""
    h, aux = _lm_trunk(params, tokens, cfg, vision_embeds)
    cparams = cast_tree(params, cfg.cdtype())
    h = rmsnorm(cparams["final_norm"], h)
    logits = unembed(cparams["embed"], h, cfg.logits_fp32, vocab=cfg.vocab)
    return logits, aux


def lm_forward_mtp(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig):
    """DeepSeek-V3 multi-token prediction: an extra depth-1 module predicts
    token t+2 from [h_t ; emb(token_{t+1})].  Returns (logits, mtp_logits, aux)."""
    cdt = cfg.cdtype()
    h_trunk, aux = _lm_trunk(params, tokens, cfg)
    cparams = cast_tree(params, cdt)
    hn = rmsnorm(cparams["final_norm"], h_trunk)
    logits = unembed(cparams["embed"], hn, cfg.logits_fp32, vocab=cfg.vocab)
    if not cfg.mtp:
        return logits, None, aux
    x = embed(cparams["embed"], tokens, cdt)
    nxt = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)   # emb(token_{t+1})
    m = cparams["mtp"]
    comb = jnp.concatenate(
        [rmsnorm(m["norm_h"], h_trunk), rmsnorm(m["norm_e"], nxt)], axis=-1
    )
    h = jnp.einsum("bse,ed->bsd", comb, m["proj"])
    h = dense_block(m["block"], h, cfg, jnp.arange(tokens.shape[1]))
    mtp_logits = unembed(cparams["embed"], rmsnorm(cparams["final_norm"], h),
                         cfg.logits_fp32, vocab=cfg.vocab)
    return logits, mtp_logits, aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_lm_caches(cfg: ModelConfig, batch: int, max_len: int):
    cdt = cfg.cdtype()
    fam = cfg.family

    def stack(n, make):
        one = make()
        return jax.tree.map(
            lambda t: (jnp.broadcast_to(t[None], (n, *t.shape)).copy()
                       if hasattr(t, "shape") else t), one)

    if fam in ("dense", "vlm"):
        return stack(cfg.n_layers, lambda: attn_mod.init_cache(cfg, batch, max_len, cdt))
    if fam == "moe":
        caches = {"scan": stack(cfg.n_layers - cfg.n_dense_layers,
                                lambda: attn_mod.init_cache(cfg, batch, max_len, cdt))}
        if cfg.n_dense_layers:
            caches["dense"] = [attn_mod.init_cache(cfg, batch, max_len, cdt)
                               for _ in range(cfg.n_dense_layers)]
        return caches
    if fam == "ssm":
        return stack(cfg.n_layers // 2, lambda: {
            "m": ssm_mod.init_mlstm_state(cfg, batch),
            "s": ssm_mod.init_slstm_state(cfg, batch),
        })
    if fam == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        return {
            "mamba": stack(ng, lambda: stack(cfg.attn_every,
                                             lambda: ssm_mod.init_mamba2_state(cfg, batch))),
            "attn": stack(ng, lambda: attn_mod.init_cache(cfg, batch, max_len, cdt)),
        }
    raise ValueError(fam)


def lm_decode_step(params: Dict, tokens: jnp.ndarray, caches, pos: jnp.ndarray,
                   cfg: ModelConfig):
    """tokens: (B, 1) new token ids; pos: scalar index. -> (logits, caches)."""
    cdt = cfg.cdtype()
    cparams = cast_tree(params, cdt)
    x = embed(cparams["embed"], tokens, cdt)
    fam = cfg.family
    mrope_pos3 = None
    if fam == "vlm":
        # M-RoPE for one position: vision prefix raster (t=0, h, w); text
        # tokens have all three components equal, offset past the grid span.
        gh, gw = cfg.vision_grid
        vp, m = cfg.vision_prefix, max(cfg.vision_grid)
        is_vis = pos < vp
        tt = jnp.where(is_vis, 0, pos - vp + m)
        hh = jnp.where(is_vis, pos // gw, pos - vp + m)
        ww = jnp.where(is_vis, pos % gw, pos - vp + m)
        mrope_pos3 = jnp.stack([tt, hh, ww])[:, None]      # (3, 1)

    if fam in ("dense", "vlm"):
        def step(h, pc):
            p, c = pc
            h, c = dense_block_decode(p, h, c, pos, cfg, mrope_pos3)
            return h, c
        x, caches = jax.lax.scan(step, x, (cparams["layers"], caches))
    elif fam == "moe":
        new_dense = []
        for p, c in zip(cparams.get("dense_layers", []), caches.get("dense", [])):
            x, c = dense_block_decode(p, x, c, pos, cfg)
            new_dense.append(c)
        def step(h, pc):
            p, c = pc
            h, c = moe_block_decode(p, h, c, pos, cfg)
            return h, c
        x, scan_caches = jax.lax.scan(step, x, (cparams["layers"], caches["scan"]))
        caches = {"scan": scan_caches}
        if new_dense:
            caches["dense"] = new_dense
    elif fam == "ssm":
        def step(h, pc):
            p, c = pc
            h, c = xlstm_pair_decode(p, h, c, cfg)
            return h, c
        x, caches = jax.lax.scan(step, x, (cparams["layers"], caches))
    elif fam == "hybrid":
        shared = cparams["shared_attn"]
        def group(h, pc):
            p, cm, ca = pc
            h, ca = dense_block_decode(shared, h, ca, pos, cfg)
            def inner(hh, pcc):
                pp, cc = pcc
                hh, cc = mamba_block_decode(pp, hh, cc, cfg)
                return hh, cc
            h, cm = jax.lax.scan(inner, h, (p, cm))
            return h, (cm, ca)
        x, (cm, ca) = jax.lax.scan(group, x, (cparams["layers"], caches["mamba"], caches["attn"]))
        caches = {"mamba": cm, "attn": ca}
    else:
        raise ValueError(fam)

    x = rmsnorm(cparams["final_norm"], x)
    logits = unembed(cparams["embed"], x, cfg.logits_fp32, vocab=cfg.vocab)
    return logits, caches
