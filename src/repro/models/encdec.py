"""Encoder-decoder backbone (SeamlessM4T-style, audio family).

The audio frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, T_frames, d_model).  Encoder blocks use
bidirectional self-attention; decoder blocks use causal self-attention +
cross-attention over the encoder output.

Decode caches: per-layer self-attn KV plus cross-attn K/V computed once from
the encoder output at prefill.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.partition import constrain
from . import attention as attn_mod
from .common import (
    cast_tree,
    dense_init,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    unembed,
)
from ..kernels.flash_attention.ops import flash_attention
from .transformer import _stack_init


# -- cross attention -------------------------------------------------------

def init_cross_attn(key, cfg: ModelConfig, dtype) -> Dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, h * dh), dtype),
        "wv": dense_init(ks[2], (d, h * dh), dtype),
        "wo": dense_init(ks[3], (h * dh, d), dtype, fan_in=h * dh),
    }


def cross_kv(params: Dict, enc_out: jnp.ndarray, cfg: ModelConfig):
    b, t, _ = enc_out.shape
    h, dh = cfg.n_heads, cfg.head_dim
    k = jnp.einsum("btd,de->bte", enc_out, params["wk"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = jnp.einsum("btd,de->bte", enc_out, params["wv"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    return k, v


def cross_attn(params: Dict, x: jnp.ndarray, k, v, cfg: ModelConfig) -> jnp.ndarray:
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    o = flash_attention(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bse,ed->bsd", o, params["wo"])


# -- blocks -----------------------------------------------------------------

def init_enc_block(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_gqa(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def enc_block(params, x, cfg: ModelConfig):
    x = constrain(x, ("pod", "data"), None, None)
    x = x + attn_mod.gqa_attention(params["attn"], rmsnorm(params["ln1"], x), cfg, causal=False)
    x = x + mlp(params["mlp"], rmsnorm(params["ln2"], x))
    return constrain(x, ("pod", "data"), None, None)


def init_dec_block(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "self": attn_mod.init_gqa(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "cross": init_cross_attn(k2, cfg, dtype),
        "ln3": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def dec_block(params, x, enc_out, cfg: ModelConfig):
    x = constrain(x, ("pod", "data"), None, None)
    x = x + attn_mod.gqa_attention(params["self"], rmsnorm(params["ln1"], x), cfg, causal=True)
    k, v = cross_kv(params["cross"], enc_out, cfg)
    x = x + cross_attn(params["cross"], rmsnorm(params["ln2"], x), k, v, cfg)
    x = x + mlp(params["mlp"], rmsnorm(params["ln3"], x))
    return constrain(x, ("pod", "data"), None, None)


# -- model ------------------------------------------------------------------

def init_encdec(key, cfg: ModelConfig) -> Dict:
    dtype = cfg.pdtype()
    ks = jax.random.split(key, 4)
    return {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype, cfg.tie_embeddings,
                                padded_vocab=cfg.padded_vocab),
        "enc_layers": _stack_init(ks[1], cfg.n_enc_layers, lambda k: init_enc_block(k, cfg, dtype)),
        "dec_layers": _stack_init(ks[2], cfg.n_dec_layers, lambda k: init_dec_block(k, cfg, dtype)),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }


def encode(params: Dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, T, D) stub frontend embeddings -> encoder output."""
    cdt = cfg.cdtype()
    cparams = cast_tree(params, cdt)
    x = frames.astype(cdt)
    blk = jax.checkpoint(lambda p, h: enc_block(p, h, cfg)) if cfg.remat else (
        lambda p, h: enc_block(p, h, cfg))
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda h, p: (blk(p, h), None), x, cparams["enc_layers"])
    else:
        n = jax.tree.leaves(cparams["enc_layers"])[0].shape[0]
        for i in range(n):
            x = blk(jax.tree.map(lambda t: t[i], cparams["enc_layers"]), x)
    return rmsnorm(cparams["enc_norm"], x)


def encdec_forward(params: Dict, frames: jnp.ndarray, tokens: jnp.ndarray,
                   cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (decoder logits, aux=0)."""
    cdt = cfg.cdtype()
    cparams = cast_tree(params, cdt)
    enc_out = encode(params, frames, cfg)
    x = embed(cparams["embed"], tokens, cdt)
    blk = jax.checkpoint(lambda p, h: dec_block(p, h, enc_out, cfg)) if cfg.remat else (
        lambda p, h: dec_block(p, h, enc_out, cfg))
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda h, p: (blk(p, h), None), x, cparams["dec_layers"])
    else:
        n = jax.tree.leaves(cparams["dec_layers"])[0].shape[0]
        for i in range(n):
            x = blk(jax.tree.map(lambda t: t[i], cparams["dec_layers"]), x)
    x = rmsnorm(cparams["final_norm"], x)
    logits = unembed(cparams["embed"], x, cfg.logits_fp32, vocab=cfg.vocab)
    return logits, jnp.zeros((), jnp.float32)


def init_encdec_caches(params: Dict, cfg: ModelConfig, batch: int, max_len: int,
                       enc_out: Optional[jnp.ndarray] = None, enc_len: int = 0):
    """Self-attn KV caches + cross K/V (from enc_out if given, zeros else)."""
    cdt = cfg.cdtype()
    self_kv = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (cfg.n_dec_layers, *t.shape)).copy(),
        attn_mod.init_gqa_cache(cfg, batch, max_len, cdt),
    )
    h, dh = cfg.n_heads, cfg.head_dim
    t = enc_out.shape[1] if enc_out is not None else enc_len
    if enc_out is not None:
        cparams = cast_tree(params, cdt)
        def one(p):
            return jnp.stack(cross_kv(p, enc_out, cfg))   # (2, B, H, T, dh)
        ck = jax.vmap(one)(cparams["dec_layers"]["cross"])
    else:
        ck = jnp.zeros((cfg.n_dec_layers, 2, batch, h, t, dh), cdt)
    return {"self": self_kv, "cross": ck}


def encdec_decode_step(params: Dict, tokens: jnp.ndarray, caches, pos: jnp.ndarray,
                       cfg: ModelConfig):
    cdt = cfg.cdtype()
    cparams = cast_tree(params, cdt)
    x = embed(cparams["embed"], tokens, cdt)

    def step(h, pc):
        p, c_self, c_cross = pc
        hh, c_self = attn_mod.gqa_decode(p["self"], rmsnorm(p["ln1"], h), c_self, pos, cfg)
        h = h + hh
        k, v = c_cross[0], c_cross[1]
        h = h + cross_attn(p["cross"], rmsnorm(p["ln2"], h), k, v, cfg)
        h = h + mlp(p["mlp"], rmsnorm(p["ln3"], h))
        return h, c_self

    x, new_self = jax.lax.scan(step, x, (cparams["dec_layers"], caches["self"], caches["cross"]))
    x = rmsnorm(cparams["final_norm"], x)
    logits = unembed(cparams["embed"], x, cfg.logits_fp32, vocab=cfg.vocab)
    return logits, {"self": new_self, "cross": caches["cross"]}
