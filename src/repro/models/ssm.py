"""Sequence-mixing state-space cells: Mamba2 (SSD) and xLSTM (sLSTM/mLSTM).

All three support two modes:
  * full-sequence (training / prefill) — chunked formulations: quadratic
    within a chunk, linear state passing across chunks (lax.scan);
  * single-step decode — constant-size recurrent state per layer, which is
    what makes the long_500k cell tractable for ssm/hybrid archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense_init, init_rmsnorm, rmsnorm


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum' producing lower-triangular cumulative sums:
    out[..., i, j] = sum_{j < k <= i} x[..., k]  (−inf above diagonal)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


# ==========================================================================
# Mamba2 / SSD
# ==========================================================================

def init_mamba2(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * p
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * n + h), dtype),
        "conv_w": dense_init(ks[1], (4, d_in + 2 * n), dtype, fan_in=4),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_rmsnorm(d_in, dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype, fan_in=d_in),
    }


def _mamba2_inputs(params, x, cfg: ModelConfig):
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * p
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    # causal depthwise conv (width 4) over x,B,C
    w = params["conv_w"]
    xbc_pad = jnp.pad(xbc, ((0, 0), (3, 0), (0, 0)))
    conv = sum(xbc_pad[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(4))
    xbc = jax.nn.silu(conv)
    xs, B, C = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    return z, xs, B, C, dt, A


def mamba2_ssd(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence chunked SSD. x: (B, L, D); L % chunk == 0."""
    bsz, L, _ = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = min(cfg.ssm_chunk, L)
    assert L % q == 0
    nc = L // q
    z, xs, B, C, dt, A = _mamba2_inputs(params, x, cfg)
    xh = xs.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    Bh = B.reshape(bsz, nc, q, n).astype(jnp.float32)
    Ch = C.reshape(bsz, nc, q, n).astype(jnp.float32)
    dth = dt.reshape(bsz, nc, q, h)
    dA = dth * A[None, None, None, :]                     # (b, c, q, h)

    # within-chunk (diagonal) term; dt folds into the input side (x_k * dt_k)
    xdt = xh * dth[..., None]                             # (b, c, q, h, p)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))     # (b, c, h, q, q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Ch, Bh)        # (b, c, q, k)
    y_diag = jnp.einsum("bchqk,bcqk,bckhp->bcqhp", Lmat, scores, xdt)

    # chunk-final states
    decay_out = jnp.exp(dA.sum(axis=2, keepdims=True) - jnp.cumsum(dA, axis=2))
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", dth * decay_out, Bh, xh)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA.sum(axis=2))                 # (b, c, h)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state *entering* chunk

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (b, c, h, p, n)

    decay_in = jnp.exp(jnp.cumsum(dA, axis=2))             # (b, c, q, h)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Ch, decay_in, prev_states)

    y = (y_diag + y_off).reshape(bsz, L, h, p)
    y = y + xh.reshape(bsz, L, h, p) * params["D"][None, None, :, None]
    y = y.reshape(bsz, L, h * p).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return jnp.einsum("ble,ed->bld", y, params["out_proj"])


def init_mamba2_state(cfg: ModelConfig, batch: int):
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, 4, h * p + 2 * n), jnp.float32),
    }


def mamba2_decode(params: Dict, x: jnp.ndarray, state: Dict,
                  cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step. x: (B, 1, D)."""
    bsz = x.shape[0]
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * p
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])[:, 0]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    conv_buf = jnp.concatenate(
        [state["conv"][:, 1:], xbc.astype(jnp.float32)[:, None]], axis=1
    )
    w = params["conv_w"].astype(jnp.float32)
    conv = jax.nn.silu((conv_buf * w[None]).sum(axis=1)).astype(x.dtype)
    xs, B, C = jnp.split(conv, [d_in, d_in + n], axis=-1)
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (b, h)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt1 * A[None, :])                                       # (b, h)
    xhead = xs.reshape(bsz, h, p).astype(jnp.float32)
    ssm = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt1, B.astype(jnp.float32), xhead
    )
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), ssm)
    y = y + xhead * params["D"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z[:, None]))
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return out, {"ssm": ssm, "conv": conv_buf}


# ==========================================================================
# mLSTM (xLSTM matrix-memory cell) — chunked parallel / recurrent decode
# ==========================================================================

def init_mlstm(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, h * dh), dtype),
        "wv": dense_init(ks[2], (d, h * dh), dtype),
        "wif": dense_init(ks[3], (d, 2 * h), jnp.float32),
        "fb": jnp.full((h,), 3.0, jnp.float32),           # forget-gate bias >0
        "norm": init_rmsnorm(h * dh, dtype),
        "wo": dense_init(ks[4], (h * dh, d), dtype, fan_in=h * dh),
        "wog": dense_init(ks[5], (d, h * dh), dtype),     # output gate
    }


def _mlstm_qkvif(params, x, cfg: ModelConfig):
    b, L, _ = x.shape
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    q = jnp.einsum("bld,de->ble", x, params["wq"]).reshape(b, L, h, dh)
    k = jnp.einsum("bld,de->ble", x, params["wk"]).reshape(b, L, h, dh)
    v = jnp.einsum("bld,de->ble", x, params["wv"]).reshape(b, L, h, dh)
    gif = jnp.einsum("bld,de->ble", x.astype(jnp.float32), params["wif"])
    i_pre, f_pre = jnp.split(gif, 2, axis=-1)             # (b, L, h)
    f_pre = f_pre + params["fb"][None, None, :]
    return q, k, v, i_pre, f_pre


def mlstm_parallel(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Chunked stabilized mLSTM (training). x: (B, L, D)."""
    b, L, _ = x.shape
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, x, cfg)
    logf = jax.nn.log_sigmoid(f_pre)                       # (b, L, h)
    scale = dh ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # decay matrix in log space: D[i,j] = sum_{j<t<=i} logf_t + i_pre_j
    lcs = jnp.cumsum(logf, axis=1)                          # (b, L, h)
    Dlog = lcs[:, :, None, :] - lcs[:, None, :, :] + i_pre[:, None, :, :]
    mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
    Dlog = jnp.where(mask, Dlog, -jnp.inf)
    m = Dlog.max(axis=2, keepdims=True)                     # row-stabilizer
    Dmat = jnp.exp(Dlog - m)                                # (b, L, L, h)
    s = jnp.einsum("blhd,bthd->blth", qf, kf)               # (b, L, T, h)
    sw = s * Dmat
    norm = jnp.maximum(jnp.abs(sw.sum(axis=2)), jnp.exp(-m[:, :, 0]))  # (b, L, h)
    yt = jnp.einsum("blth,bthd->blhd", sw, vf) / (norm[..., None] + 1e-6)
    og = jax.nn.sigmoid(jnp.einsum("bld,de->ble", x, params["wog"]))
    y = (yt.reshape(b, L, h * dh)).astype(x.dtype) * og.astype(x.dtype)
    y = rmsnorm(params["norm"], y)
    return jnp.einsum("ble,ed->bld", y, params["wo"])


def init_mlstm_state(cfg: ModelConfig, batch: int):
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(params: Dict, x: jnp.ndarray, state: Dict,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    b = x.shape[0]
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                     # (b, h, dh)
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]                 # (b, h)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fg = jnp.exp(logf + state["m"] - m_new)
    ig = jnp.exp(i_pre - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = state["C"] * fg[..., None, None] + jnp.einsum("bhk,bhv->bhkv", ig[..., None] * kf, vf)
    n = state["n"] * fg[..., None] + ig[..., None] * kf
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), jnp.exp(-m_new))
    yt = num / (den[..., None] + 1e-6)
    og = jax.nn.sigmoid(jnp.einsum("bld,de->ble", x, params["wog"]))[:, 0]
    y = (yt.reshape(b, 1, h * dh)).astype(x.dtype) * og[:, None].astype(x.dtype)
    y = rmsnorm(params["norm"], y)
    out = jnp.einsum("ble,ed->bld", y, params["wo"])
    return out, {"C": C, "n": n, "m": m_new}


# ==========================================================================
# sLSTM (xLSTM scalar cell) — sequential scan
# ==========================================================================

def init_slstm(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wx": dense_init(ks[0], (d, 4 * h * dh), dtype),           # i,f,z,o from input
        "wr": dense_init(ks[1], (h, dh, 4 * dh), jnp.float32),     # block-diag recurrent
        "fb": jnp.full((h, dh), 3.0, jnp.float32),
        "norm": init_rmsnorm(h * dh, dtype),
        "wo": dense_init(ks[2], (h * dh, d), dtype, fan_in=h * dh),
    }


def _slstm_step(params, cfg, carry, xg):
    """xg: (b, h, 4*dh) pre-activations from the input path."""
    c, n, m, hprev = carry
    rec = jnp.einsum("bhd,hde->bhe", hprev, params["wr"])   # (b, h, 4*dh)
    g = xg + rec
    dh = cfg.ssm_head_dim
    i_pre, f_pre, z_pre, o_pre = jnp.split(g, 4, axis=-1)
    f_pre = f_pre + params["fb"][None]
    m_new = jnp.maximum(f_pre + m, i_pre)
    ig = jnp.exp(i_pre - m_new)
    fg = jnp.exp(f_pre + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = fg * c + ig * z
    n_new = fg * n + ig
    hnew = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, hnew), hnew


def slstm_scan(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    b, L, _ = x.shape
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    xg = jnp.einsum("bld,de->ble", x, params["wx"]).astype(jnp.float32)
    xg = xg.reshape(b, L, h, 4 * dh).transpose(1, 0, 2, 3)   # (L, b, h, 4dh)
    zeros = jnp.zeros((b, h, dh), jnp.float32)
    carry = (zeros, zeros, jnp.full((b, h, dh), -1e30, jnp.float32), zeros)
    step = lambda c, g: _slstm_step(params, cfg, c, g)
    _, ys = jax.lax.scan(step, carry, xg)
    y = ys.transpose(1, 0, 2, 3).reshape(b, L, h * dh).astype(x.dtype)
    y = rmsnorm(params["norm"], y)
    return jnp.einsum("ble,ed->bld", y, params["wo"])


def init_slstm_state(cfg: ModelConfig, batch: int):
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, dh), -1e30, jnp.float32), "h": z}


def slstm_decode(params: Dict, x: jnp.ndarray, state: Dict,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    b = x.shape[0]
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    xg = jnp.einsum("bld,de->ble", x, params["wx"]).astype(jnp.float32)
    xg = xg.reshape(b, h, 4 * dh)
    carry = (state["c"], state["n"], state["m"], state["h"])
    (c, n, m, hh), y = _slstm_step(params, cfg, carry, xg)
    y = y.reshape(b, 1, h * dh).astype(x.dtype)
    y = rmsnorm(params["norm"], y)
    out = jnp.einsum("ble,ed->bld", y, params["wo"])
    return out, {"c": c, "n": n, "m": m, "h": hh}
