"""Shared model components: norms, rotary embeddings (incl. M-RoPE), SwiGLU,
initializers.  Pure functional style: params are nested dicts of jnp arrays;
every module provides ``init_*`` and an apply function.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# Rotary embeddings — standard RoPE and Qwen2-VL M-RoPE
# --------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, D); positions: broadcastable to (..., S). Half-split RoPE."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(
    seq_len: int, vision_prefix: int, grid: Tuple[int, int], start: int = 0
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE position ids: int32[3, S] = (temporal, height, width).

    The vision prefix occupies a (grid_h × grid_w) patch raster at temporal
    position 0..; text tokens resume with all three components equal
    (degenerating to 1-D RoPE), offset past the vision span — the Qwen2-VL
    scheme with dynamic resolution stubbed to a fixed grid.
    """
    gh, gw = grid
    vp = min(vision_prefix, seq_len)
    idx = jnp.arange(vp, dtype=jnp.int32)
    t_vis = jnp.zeros((vp,), jnp.int32)
    h_vis = idx // gw
    w_vis = idx % gw
    text_start = max(gh, gw)  # continue past the max spatial extent
    n_text = seq_len - vp
    t_txt = jnp.arange(n_text, dtype=jnp.int32) + text_start
    pos = jnp.stack([
        jnp.concatenate([t_vis, t_txt]),
        jnp.concatenate([h_vis, t_txt]),
        jnp.concatenate([w_vis, t_txt]),
    ])
    return pos + start


def apply_mrope(x: jnp.ndarray, pos3: jnp.ndarray, theta: float,
                sections=(16, 24, 24)) -> jnp.ndarray:
    """M-RoPE: frequency channels split into (t, h, w) sections (scaled to
    d_head/2 lanes).  x: (B, H, S, D); pos3: (3, S)."""
    d = x.shape[-1]
    half = d // 2
    # scale the published 1/4-1/4-1/2-ish section split to this head dim
    total = sum(sections)
    sec = [max(1, round(s * half / total)) for s in sections]
    sec[2] = half - sec[0] - sec[1]
    freqs = rope_freqs(d, theta)                       # (half,)
    # choose the position component per frequency channel
    comp = jnp.concatenate([
        jnp.full((sec[0],), 0, jnp.int32),
        jnp.full((sec[1],), 1, jnp.int32),
        jnp.full((sec[2],), 2, jnp.int32),
    ])
    pos_per_chan = pos3[comp, :]                       # (half, S)
    angles = pos_per_chan.T.astype(jnp.float32) * freqs  # (S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d, f), dtype),
        "wg": dense_init(k2, (d, f), dtype),
        "wo": dense_init(k3, (f, d), dtype, fan_in=f),
    }


def mlp(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    g = jnp.einsum("...d,df->...f", x, params["wg"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * h, params["wo"])


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype, tie: bool,
                   padded_vocab: Optional[int] = None) -> Dict:
    """Tables are allocated at `padded_vocab` (TP-divisible); pad logits are
    masked to -1e30 in `unembed`, so they never win argmax and contribute
    exp(-1e30)=0 to the CE logsumexp."""
    vp = padded_vocab or vocab
    k1, k2 = jax.random.split(key)
    p = {"table": embed_init(k1, (vp, d), dtype)}
    if not tie:
        p["head"] = dense_init(k2, (d, vp), dtype)
    return p


def embed(params: Dict, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params: Dict, x: jnp.ndarray, logits_fp32: bool = True,
            vocab: Optional[int] = None) -> jnp.ndarray:
    if "head" in params:
        w = params["head"]
        out = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    else:
        w = params["table"]
        out = jnp.einsum("...d,vd->...v", x, w.astype(x.dtype))
    if vocab is not None and vocab != out.shape[-1]:
        mask = jnp.arange(out.shape[-1]) < vocab
        out = jnp.where(mask, out, jnp.asarray(-1e30, out.dtype))
    return out.astype(jnp.float32) if logits_fp32 else out


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
