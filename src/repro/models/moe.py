"""Mixture-of-Experts FFN: shared experts + routed top-k with capacity-factor
dispatch/combine einsums (GShard-style "dropping" baseline).

This is deliberately the *baseline* formulation — the §Perf hillclimb swaps
the (tokens, experts, capacity) dispatch for a sort-based formulation and
records the delta.  Router softmax runs in f32; an auxiliary load-balancing
loss (Switch-style) is returned for the train step.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.partition import constrain
from .common import dense_init, init_mlp, mlp


def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    d, fe, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wi": dense_init(ks[1], (e, d, fe), dtype),
        "wg": dense_init(ks[2], (e, d, fe), dtype),
        "wo": dense_init(ks[3], (e, fe, d), dtype, fan_in=fe),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, fe * cfg.n_shared_experts, dtype)
    return p


def moe_ffn_sorted(params: Dict, x: jnp.ndarray, cfg: ModelConfig
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """§Perf alternative: sort-based dropless dispatch (MegaBlocks-style).

    Tokens are argsorted by expert id and run through `jax.lax.ragged_dot`
    grouped GEMMs — no (tokens, experts, capacity) one-hot tensors, no
    drops.  Working set is tokens x top_k x d instead of tokens x 10 x d
    (~e*c/(k) smaller dispatch state at DeepSeek shapes)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                     # (t, k)
    topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)

    flat_e = topi.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = topw.reshape(t * k)
    order = jnp.argsort(flat_e)                              # stable
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]
    xs = jnp.take(xt, tok_sorted, axis=0)                    # (t*k, d)
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    h = jax.lax.ragged_dot(xs, params["wi"], group_sizes)
    g = jax.lax.ragged_dot(xs, params["wg"], group_sizes)
    act = jax.nn.silu(g) * h
    out = jax.lax.ragged_dot(act, params["wo"], group_sizes)  # (t*k, d)

    y = jnp.zeros((t, d), x.dtype).at[tok_sorted].add(
        out * w_sorted[:, None].astype(out.dtype))

    top1 = jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32)
    aux = (top1.mean(axis=0) * probs.mean(axis=0)).sum() * e

    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x)
    return y, aux.astype(jnp.float32)


def moe_ffn(params: Dict, x: jnp.ndarray, cfg: ModelConfig,
            group_size: int = 2048) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss).

    Tokens are processed in groups with per-group expert capacity
    C = group_size * top_k / E * capacity_factor (overflow tokens drop to the
    residual path, standard for dropping MoE).  cfg.moe_impl="sorted" routes
    to the dropless sort-based formulation instead.
    """
    if getattr(cfg, "moe_impl", "dispatch") == "sorted":
        return moe_ffn_sorted(params, x, cfg)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = max(1, t // group_size)
    gs = t // g
    xt = x.reshape(g, gs, d)

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (g, s, e)
    topw, topi = jax.lax.top_k(probs, k)                         # (g, s, k)
    topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)

    cap = max(1, int(gs * k / e * cfg.capacity_factor))
    # position of each (token, choice) in its expert's queue
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)          # (g, s, k, e)
    pos_in_e = (jnp.cumsum(onehot.sum(2), axis=1) - onehot.sum(2))  # (g, s, e)
    # per-choice slot: recover via gather of pos + intra-token offset
    prior_within = jnp.cumsum(onehot, axis=2) - onehot            # (g, s, k, e)
    slot = jnp.einsum("gske,gse->gsk", onehot, pos_in_e) + jnp.einsum(
        "gske,gske->gsk", onehot, prior_within
    )
    keep = slot < cap
    w = topw * keep

    # dispatch/combine tensors — bf16: they are 0/1 masks (disp) and softmax
    # weights (comb); the (g,s,e,c) materialization is the structural cost of
    # dropping-MoE and dominates MoE-train memory, so halving its bytes
    # matters (§Perf)
    slot_oh = jax.nn.one_hot(slot.astype(jnp.int32), cap, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("gske,gskc->gsec", onehot, slot_oh).astype(x.dtype)
    comb = jnp.einsum("gsk,gske,gskc->gsec", w, onehot, slot_oh).astype(x.dtype)

    xin = jnp.einsum("gsec,gsd->gecd", disp.astype(x.dtype), xt)  # (g, e, c, d)
    # expert-parallel layout: dispatched tokens live on the expert's shard
    # (all-to-all at this boundary), groups ride the DP axes
    xin = constrain(xin, ("pod", "data"), "model", None, None)
    h = jnp.einsum("gecd,edf->gecf", xin, params["wi"])
    gate = jnp.einsum("gecd,edf->gecf", xin, params["wg"])
    act = jax.nn.silu(gate) * h
    xout = jnp.einsum("gecf,efd->gecd", act, params["wo"])
    xout = constrain(xout, ("pod", "data"), "model", None, None)
    y = jnp.einsum("gsec,gecd->gsd", comb.astype(x.dtype), xout)

    # Switch aux loss: fraction of tokens per expert x mean router prob
    frac = onehot[:, :, 0, :].mean(axis=1)                       # top-1 assignment share
    mean_p = probs.mean(axis=1)
    aux = (frac * mean_p).sum(-1).mean() * e

    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x)
    return y, aux.astype(jnp.float32)
