"""Uniform model interface over all assigned architecture families.

``build(cfg)`` returns a ``Model`` with:
  init(key) -> params
  forward(params, batch) -> (logits, aux)           # train/prefill
  init_caches(params, batch, max_len) -> caches     # decode state
  decode_step(params, batch, caches) -> (logits, caches)
  input_specs(shape) -> {name: ShapeDtypeStruct}    # dry-run stand-ins
  make_batch(rng, shape) -> concrete small batch    # smoke tests
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeSpec
from . import encdec as encdec_mod
from . import transformer as tf_mod


@dataclasses.dataclass
class Model:
    """A built model: config plus its init/forward/cache constructors."""

    cfg: ModelConfig
    init: Callable
    forward: Callable
    init_caches: Callable
    decode_step: Callable
    input_specs: Callable
    make_batch: Callable


def _frames_len(seq_len: int) -> int:
    return seq_len  # stub frontend: one embedding per "frame" position


def build(cfg: ModelConfig) -> Model:
    cdt = cfg.cdtype()

    if cfg.is_encdec:
        def init(key):
            return encdec_mod.init_encdec(key, cfg)

        def forward(params, batch):
            return encdec_mod.encdec_forward(params, batch["frames"], batch["tokens"], cfg)

        def init_caches(params, batch_size, max_len, enc_out=None):
            return encdec_mod.init_encdec_caches(
                params, cfg, batch_size, max_len,
                enc_out=enc_out, enc_len=_frames_len(max_len),
            )

        def decode_step(params, batch, caches):
            return encdec_mod.encdec_decode_step(params, batch["tokens"], caches, batch["pos"], cfg)

        def input_specs(shape: ShapeSpec) -> Dict[str, Any]:
            b, s = shape.global_batch, shape.seq_len
            if shape.kind == "train":
                return {
                    "frames": jax.ShapeDtypeStruct((b, _frames_len(s), cfg.d_model), cdt),
                    "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                }
            if shape.kind == "prefill":
                return {
                    "frames": jax.ShapeDtypeStruct((b, _frames_len(s), cfg.d_model), cdt),
                    "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }

        def make_batch(rng: np.random.Generator, shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            out = {
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab,
                                 (b, max(1, s) if shape.kind != "decode" else 1)),
                    jnp.int32),
            }
            if shape.kind != "decode":
                out["frames"] = jnp.asarray(
                    rng.standard_normal((b, _frames_len(s), cfg.d_model)), cdt)
            if shape.kind == "train":
                out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
            if shape.kind == "decode":
                out["pos"] = jnp.asarray(s // 2, jnp.int32)
            return out

        return Model(cfg, init, forward, init_caches, decode_step, input_specs, make_batch)

    # -- decoder-only families ------------------------------------------------
    def init(key):
        return tf_mod.init_lm(key, cfg)

    def forward(params, batch):
        return tf_mod.lm_forward(params, batch["tokens"], cfg,
                                 vision_embeds=batch.get("vision_embeds"))

    def init_caches(params, batch_size, max_len, enc_out=None):
        del params, enc_out
        return tf_mod.init_lm_caches(cfg, batch_size, max_len)

    def decode_step(params, batch, caches):
        return tf_mod.lm_decode_step(params, batch["tokens"], caches, batch["pos"], cfg)

    def input_specs(shape: ShapeSpec) -> Dict[str, Any]:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct((b, cfg.vision_prefix, cfg.d_model), cdt)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs

    def make_batch(rng: np.random.Generator, shape: ShapeSpec):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, 1)), jnp.int32),
                "pos": jnp.asarray(s // 2, jnp.int32),
            }
        out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
        if cfg.family == "vlm":
            out["vision_embeds"] = jnp.asarray(
                rng.standard_normal((b, cfg.vision_prefix, cfg.d_model)), cdt)
        if shape.kind == "train":
            out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
        return out

    return Model(cfg, init, forward, init_caches, decode_step, input_specs, make_batch)
