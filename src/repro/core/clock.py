"""Injectable time source for the whole coherence/failover/serving stack.

Every place the stack used to call ``time.time()`` / ``time.monotonic()`` /
``time.sleep()`` now goes through a :class:`Clock` handle, defaulting to
:data:`REAL_CLOCK` (the wall clock).  The deterministic cluster simulator
(:mod:`repro.sim`) injects a ``VirtualClock`` instead, so timeouts, lease
expiries and drain waits become discrete-event state that reproduces exactly
from a seed — no wall-clock races in tests.

The interface deliberately covers the three blocking primitives the stack
uses, not just "now":

* :meth:`Clock.sleep` — plain delay (poll loops, backoff);
* :meth:`Clock.wait_event` — ``threading.Event.wait`` with a timeout
  (heartbeat loops that must exit promptly on ``stop``);
* :meth:`Clock.cv_wait_for` — ``Condition.wait_for`` with a timeout
  (page-install waits).

Under the real clock these delegate to the stdlib primitives; a virtual
clock can instead advance simulated time and re-check the predicate.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class Clock:
    """Time-source interface; see module docstring.  Subclass and override."""

    def time(self) -> float:
        raise NotImplementedError

    def monotonic(self) -> float:
        raise NotImplementedError

    def monotonic_ns(self) -> int:
        return int(self.monotonic() * 1e9)

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    def wait_event(self, event: threading.Event, timeout_s: float) -> bool:
        """Block up to ``timeout_s`` for ``event``; True iff it is set."""
        raise NotImplementedError

    def cv_wait_for(self, cv: threading.Condition, predicate: Callable[[], bool],
                    timeout_s: Optional[float]) -> bool:
        """``Condition.wait_for`` analogue; caller must hold ``cv``.
        ``timeout_s=None`` waits indefinitely (until a notify satisfies the
        predicate) — condition-driven loops use it so an idle thread parks
        with ZERO periodic wakeups instead of spin-polling a timeout."""
        raise NotImplementedError


class RealClock(Clock):
    """The wall clock — production default."""

    def time(self) -> float:
        return time.time()

    def monotonic(self) -> float:
        return time.monotonic()

    def monotonic_ns(self) -> int:
        return time.monotonic_ns()

    def sleep(self, seconds: float) -> None:
        time.sleep(seconds)

    def wait_event(self, event: threading.Event, timeout_s: float) -> bool:
        return event.wait(timeout_s)

    def cv_wait_for(self, cv: threading.Condition, predicate: Callable[[], bool],
                    timeout_s: Optional[float]) -> bool:
        return cv.wait_for(predicate, timeout=timeout_s)


REAL_CLOCK = RealClock()
