"""Offline hotness profiling (§3.2).

The orchestrator replays sampled invocations against a freshly restored
instance and records every page it serves into a *working-set array*.  Since
read-only pages are negligible (0.05% of pages, §2.3.3), we do not separate
reads from writes — only touched/untouched matters.

`AccessRecorder` is the framework-side hook: model code (embedding gathers,
MoE routing, KV writes, layer weight reads) reports logical accesses and the
recorder resolves them to page indices through the image manifest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set

import numpy as np

from .pagestore import Manifest, runs_from_pages


class AccessRecorder:
    def __init__(self, manifest: Manifest):
        self.manifest = manifest
        self._extents = manifest.by_name()
        self.pages: Set[int] = set()

    # -- logical access APIs ---------------------------------------------------
    def touch_array(self, name: str) -> None:
        self.pages.update(self._extents[name].pages())

    def touch_rows(self, name: str, rows: Iterable[int]) -> None:
        """Leading-axis rows (embedding rows, expert slices, cache slots)."""
        e = self._extents[name]
        row_elems = int(np.prod(e.shape[1:])) if len(e.shape) > 1 else 1
        for r in rows:
            self.pages.update(e.row_pages(int(r), row_elems))

    def touch_elements(self, name: str, start: int, stop: int) -> None:
        e = self._extents[name]
        self.pages.update(e.element_pages(start, stop))

    def touch_pages(self, pages: Iterable[int]) -> None:
        self.pages.update(int(p) for p in pages)

    # -- results ---------------------------------------------------------------
    def working_set(self) -> np.ndarray:
        return np.asarray(sorted(self.pages), dtype=np.int64)

    def run_lengths(self) -> List[int]:
        return [n for _, n in runs_from_pages(sorted(self.pages))]


@dataclasses.dataclass
class WorkloadProfile:
    """Result of replaying N invocations: the recorded working set + stats."""

    name: str
    invocations: int
    working_set: np.ndarray

    def fragment_stats(self) -> Dict[str, float]:
        runs = runs_from_pages(self.working_set.tolist())
        lens = np.asarray([n for _, n in runs], dtype=np.float64)
        if lens.size == 0:
            return {"n_runs": 0, "mean_run": 0.0, "p90_run": 0.0}
        return {
            "n_runs": int(lens.size),
            "mean_run": float(lens.mean()),
            "p90_run": float(np.percentile(lens, 90)),
            "frac_runs_lt4": float((lens < 4).mean()),
        }


def profile_invocations(
    manifest: Manifest,
    invocation_fn,
    n_invocations: int = 16,
    name: str = "workload",
) -> WorkloadProfile:
    """Replay `n_invocations` calls of ``invocation_fn(recorder, i)`` (§3.2).

    16 is the paper's default: 80% of production invocation streaks are ≤16
    per keep-alive window (Fig. 2).
    """
    rec = AccessRecorder(manifest)
    for i in range(n_invocations):
        invocation_fn(rec, i)
    return WorkloadProfile(name, n_invocations, rec.working_set())
