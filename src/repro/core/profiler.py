"""Hotness profiling: offline replay (§3.2) + online feedback.

Offline: the orchestrator replays sampled invocations against a freshly
restored instance and records every page it serves into a *working-set
array*.  Since read-only pages are negligible (0.05% of pages, §2.3.3), we
do not separate reads from writes — only touched/untouched matters.

Online (beyond the paper's frozen hot set): every restore exports
per-``(name, version)`` access telemetry — demand faults, prefetch hits and
guest touches — into a :class:`HeatMap`, a decayed per-page counter array.
Telemetry enters as typed :class:`TouchEvent` records through
``HeatRegistry.record`` (the single public feed seam); events that carry a
``stream`` id additionally feed a *first-touch sequence* model: the map
counts page-run → page-run transitions over each stream's first touches
(``RUN_PAGES`` pages per run, virtual ``START_RUN`` before the first), which
``core/prefetch_model.fit_prefetch_model`` turns into a Markov
predicted-next-touch ordering (DESIGN.md §17).  The re-curation pipeline
(``core/snapshot.plan_recuration`` + ``PoolMaster.recurate``) consumes the
heat map to promote hot-faulting cold pages into the CXL region and demote
never-touched "hot" pages to RDMA when the modeled benefit exceeds the
rebuild break-even (``serve/strategies.recuration_economics``).

`AccessRecorder` is the framework-side hook: model code (embedding gathers,
MoE routing, KV writes, layer weight reads) reports logical accesses and the
recorder resolves them to page indices through the image manifest.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from .clock import Clock, REAL_CLOCK
from .pagestore import PAGE_SIZE, Manifest, runs_from_pages


class AccessRecorder:
    """Records page touches against a manifest to derive the working set."""

    def __init__(self, manifest: Manifest):
        self.manifest = manifest
        self._extents = manifest.by_name()
        self.pages: set = set()

    # -- logical access APIs ---------------------------------------------------
    def touch_array(self, name: str) -> None:
        self.pages.update(self._extents[name].pages())

    def touch_rows(self, name: str, rows: Iterable[int]) -> None:
        """Leading-axis rows (embedding rows, expert slices, cache slots).

        Vectorized: the byte span of every requested row is computed in one
        shot and expanded to page indices with a repeat/cumsum range
        expansion + ``np.unique`` — no per-row Python loop.  Equivalent to
        ``extent.row_pages`` per row (reference-equivalence tested).
        """
        e = self._extents[name]
        rows_arr = np.asarray(list(rows) if not isinstance(rows, np.ndarray) else rows,
                              dtype=np.int64).reshape(-1)
        if rows_arr.size == 0:
            return
        row_elems = int(np.prod(e.shape[1:])) if len(e.shape) > 1 else 1
        itemsize = np.dtype(e.dtype).itemsize
        lo = e.byte_offset + rows_arr * row_elems * itemsize
        hi = e.byte_offset + (rows_arr + 1) * row_elems * itemsize
        first = lo // PAGE_SIZE
        last = -(-hi // PAGE_SIZE)                       # exclusive page end
        lens = last - first
        offsets = np.cumsum(lens) - lens
        pages = (np.repeat(first, lens)
                 + np.arange(int(lens.sum()), dtype=np.int64)
                 - np.repeat(offsets, lens))
        self.pages.update(np.unique(pages).tolist())

    def touch_elements(self, name: str, start: int, stop: int) -> None:
        e = self._extents[name]
        self.pages.update(e.element_pages(start, stop))

    def touch_pages(self, pages: Iterable[int]) -> None:
        self.pages.update(int(p) for p in pages)

    # -- results ---------------------------------------------------------------
    def working_set(self) -> np.ndarray:
        return np.asarray(sorted(self.pages), dtype=np.int64)

    def run_lengths(self) -> List[int]:
        return [n for _, n in runs_from_pages(sorted(self.pages))]


@dataclasses.dataclass
class WorkloadProfile:
    """Result of replaying N invocations: the recorded working set + stats."""

    name: str
    invocations: int
    working_set: np.ndarray

    def fragment_stats(self) -> Dict[str, float]:
        runs = runs_from_pages(self.working_set.tolist())
        lens = np.asarray([n for _, n in runs], dtype=np.float64)
        if lens.size == 0:
            return {"n_runs": 0, "mean_run": 0.0, "p90_run": 0.0,
                    "frac_runs_lt4": 0.0}
        return {
            "n_runs": int(lens.size),
            "mean_run": float(lens.mean()),
            "p90_run": float(np.percentile(lens, 90)),
            "frac_runs_lt4": float((lens < 4).mean()),
        }


def profile_invocations(
    manifest: Manifest,
    invocation_fn,
    n_invocations: int = 16,
    name: str = "workload",
) -> WorkloadProfile:
    """Replay `n_invocations` calls of ``invocation_fn(recorder, i)`` (§3.2).

    16 is the paper's default: 80% of production invocation streaks are ≤16
    per keep-alive window (Fig. 2).
    """
    rec = AccessRecorder(manifest)
    for i in range(n_invocations):
        invocation_fn(rec, i)
    return WorkloadProfile(name, n_invocations, rec.working_set())


# --------------------------------------------------------------------------
# Online hotness feedback
# --------------------------------------------------------------------------

#: pages per sequence "run" — the granule of the first-touch Markov model.
RUN_PAGES = 8
#: virtual run a stream is in before its first touch (restore entry point).
START_RUN = -1


@dataclasses.dataclass(frozen=True)
class TouchEvent:
    """One typed telemetry observation: pages *in touch order* plus its kind.

    This is the single shape every telemetry producer emits
    (``HeatRegistry.record`` / ``HeatMap.record`` consume it):

      pages        page indices, ordered as the guest touched them;
      kind         ``demand_fault`` / ``prefetch_hit`` / ``touch``
                   (``HeatMap.KIND_WEIGHT`` sets the heat weight);
      stream       opaque per-restore sequence id — when set, the event also
                   feeds the first-touch run-transition counts behind
                   ``core/prefetch_model``; ``None`` means heat-only
                   (order-free) telemetry;
      name/version/total_pages
                   address the target map when fed through
                   ``HeatRegistry.record``; unused by ``HeatMap.record``;
      weight/now   optional overrides (tests, replayed traces).
    """

    pages: object
    kind: str = "demand_fault"
    name: Optional[str] = None
    version: Optional[int] = None
    total_pages: Optional[int] = None
    stream: Optional[int] = None
    weight: Optional[float] = None
    now: Optional[float] = None


class HeatMap:
    """Decayed per-page access-heat accumulator for one ``(name, version)``.

    Counters decay exponentially with half-life ``half_life_s`` in the
    pod clock's time base (lazy, vectorized: one multiply of the whole
    array per observation batch, no per-page timers).  Three telemetry
    kinds feed it, each with its own weight:

      demand_fault  1.0   cold page demand-faulted over RDMA — the page the
                          frozen hot set is most wrong about;
      prefetch_hit  0.6   demand fault that landed while a prefetch extent
                          covering the page was already in flight (latency
                          partially hidden, but the page is clearly needed);
      touch         0.25  guest touch served without a major fault (hot
                          pre-installed or already prefetched) — the
                          keep-me-hot signal for demotion scoring.

    Beyond decayed heat, events that carry a ``stream`` id feed *first-touch
    sequences*: pages collapse to runs of ``run_pages``, and for each stream
    only the first touch of a run counts — recording a ``prev_run → run``
    transition (``START_RUN`` before the first).  These counts are the
    sufficient statistic for the Markov predicted-next-touch model in
    ``core/prefetch_model`` (DESIGN.md §17).

    Thread-safe: fault handlers and completion workers record concurrently.
    """

    KIND_WEIGHT = {"demand_fault": 1.0, "prefetch_hit": 0.6, "touch": 0.25}

    def __init__(self, total_pages: int, half_life_s: float = 30.0,
                 clock: Optional[Clock] = None, run_pages: int = RUN_PAGES):
        self.total_pages = total_pages
        self.half_life_s = float(half_life_s)
        self.clock = clock or REAL_CLOCK
        self.run_pages = int(run_pages)
        self.n_runs = -(-int(total_pages) // self.run_pages)
        self._counts = np.zeros(total_pages, dtype=np.float64)
        self._last_t = self.clock.monotonic()
        self._lock = threading.Lock()
        self.restores = 0
        self._transitions: Dict[Tuple[int, int], float] = {}
        self._stream_prev: Dict[int, int] = {}
        self._stream_seen: Dict[int, set] = {}
        self.stats = {"demand_faults": 0, "prefetch_hits": 0, "touches": 0,
                      "records": 0, "seq_transitions": 0}

    def _decay_locked(self, now: float) -> None:
        dt = now - self._last_t
        if dt <= 0.0:
            return
        self._counts *= 0.5 ** (dt / self.half_life_s)
        self._last_t = now

    def record(self, event, kind: str = "demand_fault",
               weight: Optional[float] = None, now: Optional[float] = None) -> None:
        """Accumulate one :class:`TouchEvent` (vectorized; duplicates add up).

        The legacy ``record(pages, kind=...)`` shape still works but is
        deprecated — ``HeatRegistry.record(TouchEvent)`` is the public seam.
        """
        if not isinstance(event, TouchEvent):
            warnings.warn(
                "HeatMap.record(pages, kind=...) is deprecated; pass a "
                "TouchEvent (HeatRegistry.record is the public entrypoint)",
                DeprecationWarning, stacklevel=2)
            event = TouchEvent(pages=event, kind=kind, weight=weight, now=now)
        pages = np.asarray(event.pages, dtype=np.int64).reshape(-1)
        if pages.size == 0:
            return
        w = (self.KIND_WEIGHT[event.kind] if event.weight is None
             else float(event.weight))
        t = self.clock.monotonic() if event.now is None else float(event.now)
        with self._lock:
            self._decay_locked(t)
            np.add.at(self._counts, pages, w)
            self.stats["records"] += 1
            if event.kind == "demand_fault":
                self.stats["demand_faults"] += int(pages.size)
            elif event.kind == "prefetch_hit":
                self.stats["prefetch_hits"] += int(pages.size)
            else:
                self.stats["touches"] += int(pages.size)
            if event.stream is not None:
                self._record_sequence_locked(int(event.stream), pages)

    # -- first-touch sequence telemetry ------------------------------------
    def _record_sequence_locked(self, stream: int, pages: np.ndarray) -> None:
        runs = pages // self.run_pages
        if runs.size > 1:
            keep = np.empty(runs.size, dtype=bool)
            keep[0] = True
            keep[1:] = runs[1:] != runs[:-1]          # collapse intra-run steps
            runs = runs[keep]
        seen = self._stream_seen.setdefault(stream, set())
        prev = self._stream_prev.get(stream, START_RUN)
        added = 0
        for r in runs.tolist():
            if r in seen:
                continue                              # first touch only
            seen.add(r)
            key = (prev, r)
            self._transitions[key] = self._transitions.get(key, 0.0) + 1.0
            prev = r
            added += 1
        self._stream_prev[stream] = prev
        self.stats["seq_transitions"] += added

    def end_stream(self, stream: int) -> None:
        """Forget a stream's cursor (restore detached); its recorded
        transitions stay — only the per-stream dedup state is dropped."""
        with self._lock:
            self._stream_prev.pop(int(stream), None)
            self._stream_seen.pop(int(stream), None)

    def transition_counts(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(src_runs, dst_runs, counts)`` of recorded first-touch
        transitions, sorted by ``(src, dst)`` for deterministic model fits.
        ``src`` may be ``START_RUN``; counts are raw (undecayed) tallies."""
        with self._lock:
            if not self._transitions:
                z = np.zeros(0, dtype=np.int64)
                return z, z.copy(), np.zeros(0, dtype=np.float64)
            keys = sorted(self._transitions)
            src = np.asarray([k[0] for k in keys], dtype=np.int64)
            dst = np.asarray([k[1] for k in keys], dtype=np.int64)
            cnt = np.asarray([self._transitions[k] for k in keys],
                             dtype=np.float64)
            return src, dst, cnt

    def note_restore(self) -> None:
        """Called once per restore of this snapshot (demotion scoring needs
        to know how many chances a hot page had to be touched)."""
        with self._lock:
            self.restores += 1

    def counts(self, now: Optional[float] = None) -> np.ndarray:
        """Decayed heat per page at ``now`` (copy; does not mutate state
        when an explicit ``now`` is given)."""
        with self._lock:
            if now is None:
                self._decay_locked(self.clock.monotonic())
                return self._counts.copy()
            dt = max(0.0, float(now) - self._last_t)
            return self._counts * (0.5 ** (dt / self.half_life_s))

    def promotion_candidates(self, cold_pages: np.ndarray,
                             min_heat: float = 1.0) -> np.ndarray:
        """Cold pages whose decayed heat says they belong in CXL."""
        cold_pages = np.asarray(cold_pages, dtype=np.int64)
        if cold_pages.size == 0:
            return cold_pages
        c = self.counts()
        return cold_pages[c[cold_pages] >= min_heat]

    def demotion_candidates(self, hot_pages: np.ndarray,
                            max_heat: float = 1e-3,
                            min_restores: int = 2) -> np.ndarray:
        """Hot pages never (meaningfully) touched across enough restores."""
        hot_pages = np.asarray(hot_pages, dtype=np.int64)
        if hot_pages.size == 0 or self.restores < min_restores:
            return np.zeros(0, dtype=np.int64)
        c = self.counts()
        return hot_pages[c[hot_pages] <= max_heat]


class HeatRegistry:
    """Pod-level registry of heat maps, keyed ``(name, version)``.

    The :class:`~repro.core.nodeserver.NodePageServer` and the per-instance
    restore path both resolve their session's map here at attach time, so
    telemetry from every host lands in one place the re-curation pipeline
    can read.
    """

    def __init__(self, clock: Optional[Clock] = None, half_life_s: float = 30.0):
        self.clock = clock or REAL_CLOCK
        self.half_life_s = half_life_s
        self._lock = threading.Lock()
        self.maps: Dict[Tuple[str, int], HeatMap] = {}

    def map_for(self, name: str, version: int, total_pages: int) -> HeatMap:
        key = (name, int(version))
        with self._lock:
            hm = self.maps.get(key)
            if hm is None:
                hm = self.maps[key] = HeatMap(total_pages, self.half_life_s,
                                              clock=self.clock)
            return hm

    def record(self, event: TouchEvent) -> HeatMap:
        """THE typed telemetry entrypoint: resolve the event's
        ``(name, version)`` map and feed it (sequence order included when
        the event carries a ``stream``).  Returns the map so callers can
        cache it for the session's lifetime."""
        if event.name is None or event.version is None \
                or event.total_pages is None:
            raise ValueError(
                "HeatRegistry.record needs name, version and total_pages "
                "set on the TouchEvent")
        hm = self.map_for(event.name, event.version, int(event.total_pages))
        hm.record(event)
        return hm

    def find(self, name: str, version: int) -> Optional[HeatMap]:
        with self._lock:
            return self.maps.get((name, int(version)))

    def latest(self, name: str) -> Optional[Tuple[int, HeatMap]]:
        """(version, map) with the highest version recorded for ``name``."""
        with self._lock:
            versions = [v for (n, v) in self.maps if n == name]
            if not versions:
                return None
            v = max(versions)
            return v, self.maps[(name, v)]

    def prune(self, name: str, min_version: int) -> int:
        """Drop ``name``'s maps below ``min_version`` (superseded snapshot
        versions — the master prunes to version-1 on every publish, so a
        long-lived pod keeps at most the current and the draining version
        per name instead of one counter array per republish forever)."""
        with self._lock:
            dead = [k for k in self.maps if k[0] == name and k[1] < min_version]
            for k in dead:
                del self.maps[k]
            return len(dead)
