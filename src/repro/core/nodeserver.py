"""Host-wide page-serving runtime (§3.5, §5.3 deployment regime).

The paper's deployment story is many co-located restores per host sharing
ONE RNIC and ONE CXL link.  A :class:`NodePageServer` is that host's single
serving runtime: one shared :class:`~repro.core.serving.AsyncRDMAEngine`,
one completion worker and one prefetch pump multiplex every active
:class:`~repro.core.serving.RestoreEngine` session on the host, replacing
the engine + worker thread + BufferPool + completion thread that each
restore used to build privately.

What the shared runtime buys (DESIGN.md §10):

* **Demand-over-prefetch priority across instances** — demand faults from
  ANY instance are posted urgent on the shared submit queue, so they
  overtake every queued prefetch extent, including a neighbour's.
* **Cross-instance fairness** — prefetch extents are drained round-robin
  with a deficit counter (DRR) across fan-out groups, so a heavy
  prefetcher cannot starve a co-located light restore.
* **Cross-instance doorbell batching** — the pump coalesces posts from
  multiple restores into one doorbell, amortizing the per-op latency
  budget (QP-depth pipelining) across instances instead of per instance.
* **Hot-chunk fan-out** — when k instances concurrently restore the same
  ``(name, version)``, each CXL hot chunk and each RDMA cold extent is
  physically read ONCE and scattered k times (:class:`HotChunkCache`,
  refcounted per group, released on un-borrow).  The link then carries 1x
  bytes instead of kx, which the per-host :class:`~repro.core.pool.LinkArbiter`
  turns into k-fold lower modeled contention.

Lifecycle: the server parks its threads when the last session detaches and
restarts them on the next attach, so idle hosts carry no thread residue.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .faults import call_with_retries
from .pagestore import PAGE_SIZE
from .pool import HierarchicalPool, TimeLedger
from .prefetch_model import PrefetchPolicy, resolve_policy
from .serving import AsyncRDMAEngine, BufferPool, Instance, RestoreEngine, ScatterFn
from .snapshot import SnapshotReader


class _ChunkEntry:
    __slots__ = ("data", "modeled_s", "ready")

    def __init__(self):
        self.data: Optional[np.ndarray] = None
        self.modeled_s = 0.0
        self.ready = threading.Event()


class HotChunkCache:
    """Refcounted fan-out cache: one physical read, k borrowers.

    Entries are keyed ``(group_key, byte_offset, nbytes)`` for the private
    snapshot layout — or ``("content", byte_offset, nbytes)`` for dedup
    snapshots, where equal store offsets imply equal BYTES, so co-located
    restores of *different variants* share one physical read.  The first
    requester (the leader) performs the read and records the modeled seconds
    it was charged; followers wait on the entry and replay the same charge to
    their own ledger — they logically waited for the same transfer, but the
    link only carried the bytes once.

    Every entry tracks the set of fan-out groups that touched it; an entry is
    dropped once the LAST owning group un-borrows (for per-group keys that is
    exactly the old one-group lifetime).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[Tuple[object, int, int], _ChunkEntry] = {}
        self._owners: Dict[Tuple[object, int, int], set] = {}
        self.stats = {"reads": 0, "fanout_hits": 0, "cross_group_hits": 0}

    def get_or_read(self, key, read_fn, owner=None) -> Tuple[np.ndarray, float, bool]:
        """-> (data, modeled_s, was_leader); `read_fn() -> (data, modeled_s)`.
        ``owner`` is the fan-out group holding the entry alive (defaults to
        ``key[0]``, the pre-content-keying behaviour)."""
        owner = key[0] if owner is None else owner
        with self._lock:
            entry = self._entries.get(key)
            leader = entry is None
            if leader:
                entry = self._entries[key] = _ChunkEntry()
            owners = self._owners.setdefault(key, set())
            cross = not leader and owner not in owners
            owners.add(owner)
        if leader:
            try:
                entry.data, entry.modeled_s = read_fn()
            finally:
                entry.ready.set()
            with self._lock:
                self.stats["reads"] += 1
            return entry.data, entry.modeled_s, True
        entry.ready.wait(timeout=30.0)
        if entry.data is None:      # leader failed: fall back to a private read
            data, t = read_fn()
            return data, t, True
        with self._lock:
            self.stats["fanout_hits"] += 1
            if cross:
                self.stats["cross_group_hits"] += 1
        return entry.data, entry.modeled_s, False

    def drop_group(self, group_key) -> int:
        with self._lock:
            dead = []
            for k, owners in self._owners.items():
                owners.discard(group_key)
                if not owners:
                    dead.append(k)
            for k in dead:
                del self._owners[k]
                self._entries.pop(k, None)
            return len(dead)


class _Extent:
    __slots__ = ("es", "en", "rank0", "pool_off", "nbytes")

    def __init__(self, es, en, rank0, pool_off, nbytes):
        self.es, self.en, self.rank0 = es, en, rank0
        self.pool_off, self.nbytes = pool_off, nbytes


class FanoutGroup:
    """All co-located sessions restoring one published ``(name, version)``."""

    def __init__(self, key, reader: SnapshotReader):
        self.key = key
        self.reader = reader
        self.sessions: Dict[int, RestoreEngine] = {}
        self.queue: Deque[_Extent] = deque()
        self.deficit = 0
        self.enqueued = False
        self.poster: Optional[RestoreEngine] = None
        # ordering policy behind the queue (DESIGN.md §17): kept only when
        # it wants demand-miss re-seeding (PredictedOrderPolicy)
        self.policy: Optional[PrefetchPolicy] = None
        self.policy_session: Optional[RestoreEngine] = None
        # extent starts currently covered by the pump (queued or in flight):
        # a session joining AFTER some extents completed re-enqueues exactly
        # the ones it still needs (they are no longer in this set)
        self.covered: set = set()


class NodePageServer:
    """One per host: the shared page-serving runtime for all restores."""

    DRR_QUANTUM = 1 << 20    # prefetch bytes a group may post per DRR round

    def __init__(self, host: str, pool: HierarchicalPool,
                 buffer_pool_pages: int = 512, poll_budget: int = 1024,
                 drr_quantum: Optional[int] = None, heat=None):
        self.host = host
        self.pool = pool
        # online hotness feedback: a HeatRegistry shared with the pod's
        # PoolMaster; every attached session reports per-(name, version)
        # demand-fault / prefetch-hit / touch telemetry into it
        self.heat = heat
        self.drr_quantum = drr_quantum or self.DRR_QUANTUM
        self.engine = AsyncRDMAEngine(pool.rdma, TimeLedger(),
                                      poll_budget=poll_budget, host=host,
                                      start=False)
        self.buffers = BufferPool(buffer_pool_pages)
        self.chunks = HotChunkCache()
        self._cxl_arbiter = pool.cxl.arbiter_for(host)
        self._rdma_arbiter = pool.rdma.arbiter_for(host)
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._lifecycle = threading.Lock()
        self._stop = threading.Event()
        self._sem = threading.Semaphore(max(1, pool.rdma.cost.max_inflight))
        self._groups: Dict[object, FanoutGroup] = {}
        self._sessions: Dict[int, RestoreEngine] = {}
        self._completion_thread: Optional[threading.Thread] = None
        self._pump_thread: Optional[threading.Thread] = None
        self.stats = {"attached": 0, "detached": 0, "demand_reads": 0,
                      "extents_posted": 0, "extents_skipped": 0,
                      "doorbells": 0, "fanout_installs": 0,
                      "demand_fanout_installs": 0, "prefetch_reseeds": 0}
        # post order of (group_key, extent_start): fairness is observable
        self.post_order: Deque[Tuple[object, int]] = deque(maxlen=4096)

    # -- session lifecycle ---------------------------------------------------
    def attach(self, name: str, version: int, reader: SnapshotReader,
               instance: Instance,
               scatter_fn: Optional[ScatterFn] = None) -> RestoreEngine:
        """Join the host runtime; sessions restoring the same ``(name,
        version)`` form one fan-out group (ONE arbiter stream: their reads
        are served by shared physical transfers)."""
        session = RestoreEngine(reader, instance, rdma_engine=None,
                                buffer_pool=self.buffers,
                                scatter_fn=scatter_fn, server=self)
        if self.heat is not None:
            hm = self.heat.map_for(name, version, instance.image.total_pages)
            hm.note_restore()
            session.heat = hm
        gkey = (name, version)
        with self._lifecycle:
            self._ensure_running()
            with self._lock:
                group = self._groups.get(gkey)
                if group is None:
                    group = self._groups[gkey] = FanoutGroup(gkey, reader)
                    self._cxl_arbiter.register(gkey)
                    self._rdma_arbiter.register(gkey)
                group.sessions[id(session)] = session
                self._sessions[id(session)] = session
                session._group = group
            self.stats["attached"] += 1
        return session

    def detach(self, session: RestoreEngine) -> None:
        """Un-borrow: leave the group; the last session out drops the
        group's fan-out cache entries and its arbiter stream, and parks the
        runtime threads when the host goes fully idle."""
        with self._lifecycle:
            with self._lock:
                self._sessions.pop(id(session), None)
                group = session._group
                session._group = None
                emptied = False
                if group is not None:
                    group.sessions.pop(id(session), None)
                    if not group.sessions:
                        self._groups.pop(group.key, None)
                        group.queue.clear()
                        emptied = True
                idle = not self._sessions
            if group is not None and emptied:
                self.chunks.drop_group(group.key)
                self._cxl_arbiter.unregister(group.key)
                self._rdma_arbiter.unregister(group.key)
            self.stats["detached"] += 1
            if idle:
                self._park()

    def close(self) -> None:
        """Park the runtime if the host is idle.  With sessions still
        attached this is a no-op — live restores stay wired to a running
        engine, and the threads park on the last detach anyway."""
        with self._lifecycle:
            with self._lock:
                busy = bool(self._sessions)
            if not busy:
                self._park()

    def _ensure_running(self) -> None:
        if self._pump_thread is not None:
            return
        self._stop.clear()
        self.engine.start()
        self._completion_thread = threading.Thread(
            target=self._completion_loop, daemon=True)
        self._completion_thread.start()
        self._pump_thread = threading.Thread(target=self._pump_loop, daemon=True)
        self._pump_thread.start()

    def _park(self) -> None:
        """Stop threads, drain the engine, keep the server reusable."""
        self._stop.set()
        with self._work:
            self._work.notify_all()
        for t in (self._pump_thread, self._completion_thread):
            if t is not None:
                t.join(timeout=5.0)
        self._pump_thread = self._completion_thread = None
        self.engine.quiesce()
        while True:     # orphaned completions: return buffers / QP slots
            item = self.engine.poll_completion(block=False)
            if item is None:
                break
            self._route(*item)
        self.engine.close()
        self._stop.clear()

    # -- hot-chunk fan-out ----------------------------------------------------
    def hot_chunk(self, session: RestoreEngine, off: int, nbytes: int) -> np.ndarray:
        group = session._group
        if session.reader.regions.dedup:
            # content-keyed: equal store offsets == equal bytes under dedup,
            # so co-located restores of DIFFERENT variants (distinct fan-out
            # groups) share one physical read of their common base chunks
            key = ("content", off, nbytes)
        else:
            with self._lock:
                solo = len(group.sessions) <= 1
            if solo:
                # nothing to fan out to — don't duplicate the hot region in
                # the cache for the common one-restore-per-snapshot case
                return call_with_retries(
                    lambda: session.reader.view.read(off, nbytes),
                    policy=session.retry, rng=session._retry_rng,
                    ledger=session.ledger, clock=session.clock,
                    trace=session.retry_trace)
            key = (group.key, off, nbytes)
        # fan-out-aware retry (§15): only the LEADER's physical read can
        # fault, and its bounded retries happen here — once — so a failed
        # shared chunk read is re-issued once for the whole group, not k
        # times by k borrowers
        data, modeled_s, leader = self.chunks.get_or_read(
            key,
            lambda: call_with_retries(
                lambda: session.reader.view.read_charged(off, nbytes),
                policy=session.retry, rng=session._retry_rng,
                ledger=session.ledger, clock=session.clock,
                trace=session.retry_trace),
            owner=group.key)
        if not leader:
            # borrower: the bytes crossed the link once (leader's read);
            # we waited for the same transfer, so we model the same time
            session.ledger.add("cxl_read", modeled_s)
        return data

    # -- demand faults ---------------------------------------------------------
    def submit_demand(self, session: RestoreEngine, pool_off: int, nbytes: int,
                      buf: np.ndarray, token_tail: tuple) -> None:
        """Urgent one-sided read for a demand fault: overtakes every queued
        prefetch extent from EVERY co-located instance.

        Fan-out: the page is marked in flight in every session of the
        group BEFORE posting, so a sibling faulting the same page records a
        ``prefetch_hit`` and waits for this read instead of posting a
        duplicate — one physical read credits (and installs into) the whole
        group, mirroring the pump's ``gext`` behaviour.  A predicted-order
        policy additionally re-seeds the group's queued extents from the
        faulting page (the model's next-touch chain restarts here)."""
        page = int(token_tail[0])
        group = session._group
        gkey = None
        if group is not None:
            gkey = group.key
            with self._lock:
                others = [s for s in group.sessions.values()
                          if s is not session]
            for s in others:
                with s._inflight_lock:
                    s._inflight.setdefault(page, True)
        self.stats["demand_reads"] += 1
        self.engine.submit_read(pool_off, nbytes, buf,
                                ("spage", id(session), gkey) + token_tail,
                                urgent=True, ledger=session.ledger)
        self._reseed_prefetch(session, page)

    def _reseed_prefetch(self, session: RestoreEngine, page: int) -> None:
        """Demand miss under a predicted-order policy: re-order the group's
        still-queued extents by the prediction seeded at the faulting page.
        Only the fetch ORDER changes — covered/queued membership does not,
        so installs stay bit-identical."""
        group = session._group
        if group is None:
            return
        with self._lock:
            policy = group.policy
            if policy is None or not group.queue:
                return
        rank = {es: i for i, (es, _en, _r0, _off, _nb)
                in enumerate(policy.order_extents(session, faulting_page=page))}
        with self._work:
            if not group.queue:
                return
            q = sorted(group.queue, key=lambda e: rank.get(e.es, len(rank)))
            group.queue.clear()
            group.queue.extend(q)
            self.stats["prefetch_reseeds"] += 1
            self._work.notify_all()

    # -- prefetch pump ---------------------------------------------------------
    def enqueue_prefetch(self, session: RestoreEngine,
                         max_extent_pages: Optional[int] = None,
                         policy: Optional[PrefetchPolicy] = None) -> None:
        """Queue the group's cold extents in ``policy`` order (default
        :class:`LayoutOrderPolicy`: largest runs first, the pre-§17
        behaviour; ``max_extent_pages=N`` is its deprecated spelling);
        completed extents are scattered into every session of the group.

        The first caller enqueues the full walk.  A session that joins the
        group LATER re-enqueues only the extents it still needs and the
        pump no longer covers (an extent that is queued or in flight will
        install into this session on completion, so it is never duplicated;
        one already completed before this session attached is re-fetched)."""
        policy = resolve_policy(policy, max_extent_pages,
                                "NodePageServer.enqueue_prefetch")
        group = session._group
        if group is None:
            return
        extents = [_Extent(*tup)
                   for tup in policy.order_extents(session, None)]
        present = session.instance.present

        def needs(ext: _Extent) -> bool:
            """True iff some page of the extent will NOT reach this session:
            not covered by the pump, not installed, and not already arriving
            via an in-flight read (pump-marked extent or demand single)."""
            if ext.es in group.covered:
                return False
            if present[ext.es : ext.es + ext.en].all():
                return False
            with session._inflight_lock:
                return not all(present[p] or session._inflight.get(p)
                               for p in range(ext.es, ext.es + ext.en))

        with self._work:
            # decide first-vs-joiner and fill queue+covered in ONE critical
            # section: a concurrent enqueuer must observe the full walk as
            # covered, never a half-filled one (else it would duplicate it)
            first = not group.enqueued
            group.enqueued = True
            if first:
                group.poster = session
            if policy.reseed_on_demand:
                group.policy = policy
                group.policy_session = session
            for ext in extents:
                if not first and not needs(ext):
                    continue
                group.covered.add(ext.es)
                group.queue.append(ext)
            self._work.notify_all()

    def _flush_doorbell(self, pend: Dict[FanoutGroup, List[int]]) -> None:
        """One doorbell over extents from possibly MANY groups: the QP-depth
        latency budget is amortized across the whole batch and split by op
        share; every session of a group is charged the group's share (they
        all wait on the same shared transfer)."""
        if not pend:
            return
        cost = self.pool.rdma.cost
        total_ops = sum(o for _b, o in pend.values())
        lat_total = -(-total_ops // max(1, cost.max_inflight)) * cost.op_latency_s
        for group, (nbytes, ops) in pend.items():
            serial_g = (ops / total_ops) * lat_total + nbytes / cost.bandwidth_Bps
            t_g = self._rdma_arbiter.shared(serial_g, nbytes)
            with self._lock:
                sessions = list(group.sessions.values())
            for s in sessions:
                s.ledger.add("rdma_prefetch", t_g)
                s.prefetch_stats["doorbells"] += 1
        self.stats["doorbells"] += 1
        pend.clear()

    def _pump_loop(self) -> None:
        qp = max(1, self.pool.rdma.cost.max_inflight)
        pend: Dict[FanoutGroup, List[int]] = {}

        def pend_ops() -> int:
            return sum(o for _b, o in pend.values())

        while not self._stop.is_set():
            with self._work:
                ready = [g for g in self._groups.values() if g.queue]
                if not ready:
                    pass_groups = None
                else:
                    pass_groups = ready
            if pass_groups is None:
                self._flush_doorbell(pend)
                with self._work:
                    if not any(g.queue for g in self._groups.values()):
                        self._work.wait(timeout=0.05)
                continue
            for group in pass_groups:       # one DRR round
                if self._stop.is_set():
                    break
                group.deficit += self.drr_quantum
                while True:
                    with self._lock:
                        if not group.queue:
                            group.deficit = 0
                            break
                        ext = group.queue[0]
                        if ext.nbytes > group.deficit:
                            break
                        group.queue.popleft()
                        group.deficit -= ext.nbytes
                        sessions = list(group.sessions.values())
                    if not sessions or all(
                            s.instance.present[ext.es : ext.es + ext.en].all()
                            for s in sessions):
                        with self._lock:
                            group.covered.discard(ext.es)
                        self.stats["extents_skipped"] += 1
                        continue
                    got = False
                    while not got:
                        got = self._sem.acquire(timeout=0.05)
                        if self._stop.is_set():
                            if got:
                                self._sem.release()
                            self._flush_doorbell(pend)
                            return
                    for s in sessions:
                        with s._inflight_lock:
                            for p in range(ext.es, ext.es + ext.en):
                                s._inflight.setdefault(p, True)
                    buf = np.empty(ext.nbytes, dtype=np.uint8)
                    self.engine.submit_read(
                        ext.pool_off, ext.nbytes, buf,
                        ("gext", group.key, ext.es, ext.en, ext.rank0),
                        urgent=False, charge=False)
                    if group.poster is not None:
                        group.poster.prefetch_stats["extents_posted"] += 1
                    self.stats["extents_posted"] += 1
                    self.post_order.append((group.key, ext.es))
                    b_o = pend.setdefault(group, [0, 0])
                    b_o[0] += ext.nbytes
                    b_o[1] += 1
                    if pend_ops() >= qp:
                        self._flush_doorbell(pend)
            self._flush_doorbell(pend)
        self._flush_doorbell(pend)

    # -- completion routing -----------------------------------------------------
    def _route(self, buf: np.ndarray, token: tuple) -> None:
        if token[0] == "gext":
            _tag, gkey, es, en, rank0 = token
            with self._lock:
                group = self._groups.get(gkey)
                sessions = list(group.sessions.values()) if group else []
                reader = group.reader if group else None
                if group is not None:
                    # un-cover INSIDE the snapshot's critical section: a
                    # joiner that saw this extent as covered is in `sessions`
                    group.covered.discard(es)
            try:
                if sessions:
                    mat = reader.split_cold_extent(rank0, en, buf)
                    pages = np.arange(es, es + en)
                    for s in sessions:
                        try:
                            k = s._install_verified(pages, mat)
                            s.prefetch_stats["pages_installed"] += k
                        except RuntimeError as e:
                            # pump context: record per session so one
                            # exhausted repair cannot sink its neighbours
                            if not s._is_fault(e):
                                raise
                            s.repair_error = e
                        finally:
                            with s._inflight_lock:
                                for p in range(es, es + en):
                                    s._inflight.pop(p, None)
                    if len(sessions) > 1:
                        self.stats["fanout_installs"] += len(sessions) - 1
            finally:
                self._sem.release()
            return
        _tag, sid, gkey, page, nbytes, raw, kind = token
        with self._lock:
            session = self._sessions.get(sid)
            group = self._groups.get(gkey) if gkey is not None else None
            if group is not None:
                # demand fan-out: the single physical read installs into
                # every session of the group (submit_demand marked the page
                # in flight in all of them)
                sessions = list(group.sessions.values())
                reader = group.reader
            else:
                sessions = [session] if session is not None else []
                reader = session.reader if session is not None else None
        try:
            if sessions:
                data = (reader.decompress_page(buf[:nbytes], raw)
                        if kind == "rdma_z" else buf[:PAGE_SIZE])
                for s in sessions:
                    try:
                        s._install_verified(
                            np.array([int(page)], dtype=np.int64), data)
                    except RuntimeError as e:
                        if not s._is_fault(e):
                            raise
                        s.repair_error = e
                    finally:
                        with s._inflight_lock:
                            s._inflight.pop(int(page), None)
                if len(sessions) > 1:
                    self.stats["demand_fanout_installs"] += len(sessions) - 1
        finally:
            self.buffers.release(buf)

    def _completion_loop(self) -> None:
        eng = self.engine
        while not self._stop.is_set():
            item = eng.poll_completion(block=True)
            if item is None:
                continue
            while item is not None:
                self._route(*item)
                polled = None
                for _ in range(eng.poll_budget):
                    polled = eng.poll_completion(block=False)
                    if polled is not None:
                        eng.stats["busy_polls"] += 1
                        break
                item = polled
