"""Hierarchical memory pool: pod-local CXL tier + cluster-wide RDMA tier.

Emulation strategy (this container has no CXL MHD or RNIC):

* **Data movement is real** — tiers are backed by numpy buffers and every
  read/write actually copies bytes, so restore correctness is testable
  end-to-end (restored state must be bit-identical to the published one).
* **Time is modeled** — each tier carries a calibrated ``CostModel`` and the
  pool accumulates modeled seconds per operation class.  Benchmarks report
  modeled time (CPU wall-clock on this box says nothing about CXL/RDMA).
* **Non-coherence is emulated** — the CXL tier hands out per-host
  ``HostView``s with a private "CPU cache": reads are served from cached
  lines when present, so a host that skips the protocol's ``invalidate()``
  (clflushopt analogue) observably reads stale data.  Tests rely on this.

Cost-model constants (see DESIGN.md §8 for sources):
  CXL   ~400 ns load-to-use, ~26 GB/s per-host link, uffd.copy ~1.1 µs/page,
        mmap install 2.6x uffd.copy (paper §2.3.4), clflushopt ~50 ns/line.
  RDMA  ~3 µs one-sided read latency, 100 Gb/s link, many ops in flight.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from .clock import Clock, REAL_CLOCK
from .pagestore import PAGE_SIZE

CACHELINE = 64

# Backend tags (encoded in the offset array's top bits, see snapshot.py).
TIER_CXL = 0
TIER_RDMA = 1


@dataclasses.dataclass
class CostModel:
    """Per-tier latency/bandwidth model; times in seconds, sizes in bytes."""

    op_latency_s: float          # fixed per-operation cost (load-to-use / RDMA op)
    bandwidth_Bps: float         # sustained sequential bandwidth
    max_inflight: int = 1        # concurrent ops the fabric sustains (RDMA QP depth)

    def xfer_time(self, nbytes: int, ops: int = 1) -> float:
        """Modeled time for `ops` transfers totalling `nbytes`, serialized."""
        return ops * self.op_latency_s + nbytes / self.bandwidth_Bps

    def xfer_time_pipelined(self, nbytes: int, ops: int) -> float:
        """Latency hidden by max_inflight concurrent ops (one-sided RDMA)."""
        serial_ops = -(-ops // max(1, self.max_inflight))
        return serial_ops * self.op_latency_s + nbytes / self.bandwidth_Bps


# Calibrated defaults (DESIGN.md §8).
CXL_COST = CostModel(op_latency_s=400e-9, bandwidth_Bps=50e9, max_inflight=1)
RDMA_COST = CostModel(op_latency_s=3e-6, bandwidth_Bps=100e9 / 8, max_inflight=64)
# uffd ioctl cost split: a fixed syscall/wakeup component amortized over a
# contiguous range, plus an incremental per-4KiB-page copy component.  The
# single-page constants below are their sum, so the batched and per-page
# paths agree exactly at n=1 and batching can only amortize, never undercount.
UFFD_IOCTL_S = 0.6e-6                  # fixed cost per uffd.copy ioctl (syscall+wake)
UFFD_COPY_PAGE_S = 0.5e-6              # per-page copy within one uffd.copy range
UFFD_ZEROPAGE_IOCTL_S = 0.4e-6         # fixed cost per uffd.zeropage ioctl (no copy setup)
UFFD_ZEROPAGE_PAGE_S = 0.15e-6         # per-page cost within one uffd.zeropage range
UFFD_COPY_PER_PAGE_S = UFFD_IOCTL_S + UFFD_COPY_PAGE_S        # 1.1 µs single page
UFFD_ZEROPAGE_PER_PAGE_S = UFFD_ZEROPAGE_IOCTL_S + UFFD_ZEROPAGE_PAGE_S  # 0.55 µs
MMAP_PER_PAGE_S = UFFD_COPY_PER_PAGE_S * 2.6   # paper: mmap 2.6x slower per page
MMAP_SYSCALL_S = 1.0e-6     # fixed mmap()+setup cost per mapped range (§2.3.4)
CLFLUSH_PER_LINE_S = 2e-9   # clflushopt of *uncached* lines: ~issue cost


def uffd_copy_batch_cost(n_pages: int, n_ranges: int = 1) -> float:
    """Modeled cost of installing `n_pages` via `n_ranges` uffd.copy ioctls."""
    return n_ranges * UFFD_IOCTL_S + n_pages * UFFD_COPY_PAGE_S


def uffd_zeropage_range_cost(n_pages: int, n_ranges: int = 1) -> float:
    """Modeled cost of zero-filling `n_pages` via `n_ranges` uffd.zeropage ioctls."""
    return n_ranges * UFFD_ZEROPAGE_IOCTL_S + n_pages * UFFD_ZEROPAGE_PAGE_S


class AllocError(RuntimeError):
    """A tier allocation could not be satisfied (capacity or fragmentation)."""


class CXLBudget:
    """Per-pod byte budget over snapshot CXL regions (Pond-style capacity
    management: the CXL tier must be actively managed per-pod to stay inside
    its latency/capacity envelope, instead of letting snapshots accumulate
    until ``alloc`` fails).

    This is the accounting substrate only — the eviction *policy* (clock
    sweep over snapshot hot regions, LRU by restore recency) lives in
    :class:`repro.core.master.CXLCapacityManager`, which recomputes the
    authoritative usage from the catalog and syncs it here via
    :meth:`set_usage` so the gauge can never drift from the truth.
    """

    def __init__(self, budget_bytes: int):
        self.budget_bytes = int(budget_bytes)
        self._lock = threading.Lock()
        self._in_use = 0
        self.stats = {"admitted": 0, "degraded": 0, "demotions": 0,
                      "sweeps": 0, "shared_skips": 0}

    @property
    def in_use(self) -> int:
        with self._lock:
            return self._in_use

    def set_usage(self, nbytes: int) -> None:
        with self._lock:
            self._in_use = int(nbytes)

    def report(self) -> Dict[str, int]:
        with self._lock:
            return {"budget_bytes": self.budget_bytes, "in_use": self._in_use,
                    **self.stats}


class LinkArbiter:
    """Contention-aware modeled time for one host's link to a tier.

    Streams that offer traffic to the link register for their active
    restore window (attach/restore until stop/detach) — a restore session
    with its own engine, or a fan-out group of same-snapshot sessions
    whose reads are served by one physical transfer
    (`repro.core.nodeserver`).  Each modeled transfer is charged

        max(serial_time,  nbytes * active_streams / bandwidth)

    i.e. its own serial pipeline time or its fair share of the link,
    whichever is slower.  This is the executed-path counterpart of the
    analytic contention model in ``serve/strategies._shared``; with at most
    one stream registered every charge equals the uncontended serial time,
    so single-restore ledgers are unchanged.
    """

    def __init__(self, cost: CostModel):
        self.cost = cost
        self._lock = threading.Lock()
        self._streams: Dict[object, int] = {}

    def register(self, key: object) -> None:
        """Refcounted: k registrations of one key count as ONE stream."""
        with self._lock:
            self._streams[key] = self._streams.get(key, 0) + 1

    def unregister(self, key: object) -> None:
        with self._lock:
            n = self._streams.get(key, 0) - 1
            if n <= 0:
                self._streams.pop(key, None)
            else:
                self._streams[key] = n

    def active(self) -> int:
        with self._lock:
            return max(1, len(self._streams))

    def shared(self, serial_s: float, nbytes: int) -> float:
        """max(serial, fair-share-bandwidth time) — `strategies._shared`."""
        return max(serial_s, nbytes * self.active() / self.cost.bandwidth_Bps)

    def charge(self, nbytes: int, ops: int = 1) -> float:
        return self.shared(self.cost.xfer_time(nbytes, ops), nbytes)

    def charge_pipelined(self, nbytes: int, ops: int) -> float:
        return self.shared(self.cost.xfer_time_pipelined(nbytes, ops), nbytes)


@dataclasses.dataclass
class TimeLedger:
    """Accumulated modeled time, by operation class."""

    seconds: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, key: str, t: float) -> None:
        self.seconds[key] = self.seconds.get(key, 0.0) + t

    def total(self) -> float:
        return sum(self.seconds.values())

    def merge(self, other: "TimeLedger") -> None:
        for k, v in other.seconds.items():
            self.add(k, v)


class MemoryTier:
    """One tier of the pool: a byte arena + first-fit allocator + cost model."""

    def __init__(self, name: str, capacity: int, cost: CostModel):
        self.name = name
        self.capacity = capacity
        self.cost = cost
        self.buf = np.zeros(capacity, dtype=np.uint8)
        self._lock = threading.Lock()
        self._free: List[Tuple[int, int]] = [(0, capacity)]  # (offset, size), sorted
        self.bytes_in_use = 0
        self._arbiters: Dict[str, LinkArbiter] = {}
        # fault-tolerance seam (DESIGN.md §15): both default to inert.
        # ``fault_injector`` is the deterministic fault schedule (None = the
        # fault-free path, one attribute check of overhead); ``health`` is
        # the per-tier circuit breaker serving consults before host-link
        # reads; ``dedup_store`` back-points at this tier's content store
        # so checksum repair can quarantine a corrupt shared offset.
        self.fault_injector = None
        self.health = None
        self.dedup_store = None

    def arbiter_for(self, host: str = "") -> LinkArbiter:
        """The contention arbiter for `host`'s link to this tier (per-host
        CXL link / per-host RNIC — co-located restores on one host share it,
        restores on different hosts do not)."""
        with self._lock:
            arb = self._arbiters.get(host)
            if arb is None:
                arb = self._arbiters[host] = LinkArbiter(self.cost)
            return arb

    # -- allocator --------------------------------------------------------
    def alloc(self, nbytes: int) -> int:
        nbytes = max(1, -(-nbytes // PAGE_SIZE) * PAGE_SIZE)
        with self._lock:
            for i, (off, size) in enumerate(self._free):
                if size >= nbytes:
                    if size == nbytes:
                        self._free.pop(i)
                    else:
                        self._free[i] = (off + nbytes, size - nbytes)
                    self.bytes_in_use += nbytes
                    return off
        err = AllocError(f"tier {self.name}: cannot alloc {nbytes} B "
                         f"({self.bytes_in_use}/{self.capacity} in use)")
        err.tier = self.name    # which tier failed (degrade paths branch on it)
        raise err

    def free(self, offset: int, nbytes: int) -> None:
        """Return a block: O(log n) position search + O(1) neighbor merge
        (the free list is kept sorted and fully coalesced at all times, so
        no append-then-full-sort pass is ever needed)."""
        nbytes = max(1, -(-nbytes // PAGE_SIZE) * PAGE_SIZE)
        with self._lock:
            i = bisect.bisect_left(self._free, (offset, 0))
            prev_adj = i > 0 and self._free[i - 1][0] + self._free[i - 1][1] == offset
            next_adj = i < len(self._free) and offset + nbytes == self._free[i][0]
            if prev_adj and next_adj:
                po, ps = self._free[i - 1]
                self._free[i - 1] = (po, ps + nbytes + self._free[i][1])
                self._free.pop(i)
            elif prev_adj:
                po, ps = self._free[i - 1]
                self._free[i - 1] = (po, ps + nbytes)
            elif next_adj:
                no, ns = self._free[i]
                self._free[i] = (offset, nbytes + ns)
            else:
                self._free.insert(i, (offset, nbytes))
            self.bytes_in_use -= nbytes

    def free_list_stats(self) -> Dict[str, int]:
        """Fragmentation snapshot: block count + total free bytes."""
        with self._lock:
            return {"blocks": len(self._free),
                    "free_bytes": sum(s for _o, s in self._free)}

    # -- raw access (owner-side; bypasses host caches) ---------------------
    def write(self, offset: int, data: np.ndarray) -> None:
        raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        if self.fault_injector is not None:
            self.fault_injector.check_write(self.name, offset, raw.nbytes)
        self.buf[offset : offset + raw.nbytes] = raw

    def read(self, offset: int, nbytes: int) -> np.ndarray:
        fi = self.fault_injector
        if fi is not None:
            fi.check_read(self.name, offset, nbytes)
        data = self.buf[offset : offset + nbytes].copy()
        if fi is not None:
            fi.filter_read(self.name, offset, nbytes, data)
        return data


class HostView:
    """A host's view of the CXL tier, with an *incoherent* private cache.

    Reads populate the cache; later reads hit it even if the underlying pool
    bytes changed — exactly the CXL 2.0 MHD hazard (§2.3.2).  ``invalidate``
    is the clflushopt analogue and also charges the modeled flush cost.
    """

    def __init__(self, host: str, tier: MemoryTier, ledger: Optional[TimeLedger] = None):
        self.host = host
        self.tier = tier
        self.ledger = ledger or TimeLedger()
        self.arbiter = tier.arbiter_for(host)
        self._cache: Dict[int, np.ndarray] = {}  # line index -> 64B snapshot
        self.stats = {"cached_reads": 0, "pool_reads": 0, "flushed_lines": 0,
                      "bytes_read": 0}

    def read_charged(self, offset: int, nbytes: int) -> Tuple[np.ndarray, float]:
        """Like :meth:`read`, also returning the modeled seconds charged for
        this read — the fan-out cache replays that charge to borrowers that
        reuse the bytes without re-reading the link."""
        fi = self.tier.fault_injector
        if fi is not None:
            # the host CXL.mem link: brownout windows apply here (owner-side
            # pool-fabric reads via MemoryTier.read are NOT browned out)
            fi.check_read(self.tier.name, offset, nbytes, host_link=True)
        out = np.empty(nbytes, dtype=np.uint8)
        first = offset // CACHELINE
        last = (offset + nbytes - 1) // CACHELINE
        pos = 0
        for line in range(first, last + 1):
            lo = max(offset, line * CACHELINE)
            hi = min(offset + nbytes, (line + 1) * CACHELINE)
            cached = self._cache.get(line)
            if cached is None:
                cached = self.tier.buf[line * CACHELINE : (line + 1) * CACHELINE].copy()
                self._cache[line] = cached
                self.stats["pool_reads"] += 1
            else:
                self.stats["cached_reads"] += 1
            out[pos : pos + hi - lo] = cached[lo - line * CACHELINE : hi - line * CACHELINE]
            pos += hi - lo
        self.stats["bytes_read"] += nbytes
        if fi is not None:
            # poison the returned copy only — the line cache and the pool
            # bytes stay clean, so a budgeted re-read repairs the page
            fi.filter_read(self.tier.name, offset, nbytes, out)
        t = self.arbiter.charge(nbytes)
        self.ledger.add("cxl_read", t)
        return out, t

    def read(self, offset: int, nbytes: int) -> np.ndarray:
        return self.read_charged(offset, nbytes)[0]

    def read_page(self, offset: int) -> np.ndarray:
        return self.read(offset, PAGE_SIZE)

    def invalidate(self, offset: int, nbytes: int) -> None:
        """clflushopt over [offset, offset+nbytes): drop cached lines."""
        first = offset // CACHELINE
        last = (offset + nbytes - 1) // CACHELINE
        n = 0
        for line in range(first, last + 1):
            if self._cache.pop(line, None) is not None:
                n += 1
        self.stats["flushed_lines"] += last - first + 1
        self.ledger.add("clflush", (last - first + 1) * CLFLUSH_PER_LINE_S)

    def drop_all(self) -> None:
        self._cache.clear()


class HierarchicalPool:
    """The two-tier pool a pod sees: CXL (fast/near) + RDMA (big/far)."""

    def __init__(
        self,
        cxl_capacity: int = 256 << 20,
        rdma_capacity: int = 1 << 30,
        cxl_cost: CostModel = CXL_COST,
        rdma_cost: CostModel = RDMA_COST,
        clock: Optional[Clock] = None,
        dedup_hash_fn=None,
    ):
        # The pool is the one object every component of a pod shares, so it
        # carries the pod's time source: PoolMaster / FailoverNode / serving
        # default their clock from here (repro.sim injects a VirtualClock).
        self.clock = clock or REAL_CLOCK
        self.fault_injector = None
        self.cxl = MemoryTier("cxl", cxl_capacity, cxl_cost)
        self.rdma = MemoryTier("rdma", rdma_capacity, rdma_cost)
        # content-addressed page stores (one per tier): dedup publishes
        # route page payloads through these; the offset array then points
        # at refcounted absolute tier offsets instead of a private region.
        # ``dedup_hash_fn`` is the stores' hash seam — pass
        # ``dedup.pallas_hash_fn`` and the fused publish sweep's checksum
        # column doubles as the stores' hash input (no separate hash pass).
        from .dedup import DedupStore  # local import: dedup imports pool

        self.dedup_cxl = DedupStore(self.cxl, hash_fn=dedup_hash_fn)
        self.dedup_rdma = DedupStore(self.rdma, hash_fn=dedup_hash_fn)
        # per-tier circuit breakers (DESIGN.md §15); inert until a failure
        from .faults import TierHealth

        self.health = {"cxl": TierHealth("cxl", self.clock),
                       "rdma": TierHealth("rdma", self.clock)}
        self.cxl.health = self.health["cxl"]
        self.rdma.health = self.health["rdma"]

    def attach_fault_injector(self, injector) -> None:
        """Arm the deterministic fault seam on both tiers (None to disarm)."""
        self.fault_injector = injector
        self.cxl.fault_injector = injector
        self.rdma.fault_injector = injector

    def dedup_store(self, tag: int):
        if tag == TIER_CXL:
            return self.dedup_cxl
        if tag == TIER_RDMA:
            return self.dedup_rdma
        raise ValueError(f"unknown tier tag {tag}")

    def tier(self, tag: int) -> MemoryTier:
        if tag == TIER_CXL:
            return self.cxl
        if tag == TIER_RDMA:
            return self.rdma
        raise ValueError(f"unknown tier tag {tag}")

    def host_view(self, host: str, ledger: Optional[TimeLedger] = None) -> HostView:
        return HostView(host, self.cxl, ledger)
