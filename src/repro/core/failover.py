"""Pool-master failover (§3.6).

The paper: the pool master is a single point of failure but off the critical
path — orchestrators restore from published snapshots without contacting it;
"a replacement node can be elected as the new pool master and resume normal
operation", optionally automated with Raft-style heartbeats.

This module implements that: a heartbeat lease in shared (CXL) memory and a
CAS-based election among orchestrator nodes.  All durable state (catalog,
data regions) already lives in the shared pool, so the new master resumes
with zero state transfer — it only re-derives its version counters from the
catalog.

Time is injected (:mod:`repro.core.clock`): under the real clock a
``FailoverNode`` runs its heartbeat in a thread; the deterministic simulator
(:mod:`repro.sim`) instead calls :meth:`FailoverNode.tick` directly under a
``VirtualClock``, so elections and lease expiries replay exactly from a seed.
"""
from __future__ import annotations

import threading
from typing import Optional

from .clock import Clock, REAL_CLOCK
from .coherence import AtomicU64, Catalog
from .master import PoolMaster
from .pool import HierarchicalPool

NO_MASTER = 0


class MasterLease:
    """Shared-memory heartbeat lease: (holder_id, last_beat_ns) words updated
    with atomics — the CXL-resident election state."""

    def __init__(self, timeout_s: float = 0.2, clock: Optional[Clock] = None):
        self.holder = AtomicU64(NO_MASTER)
        self.last_beat = AtomicU64(0)
        self.term = AtomicU64(0)
        self.timeout_s = timeout_s
        self.clock = clock or REAL_CLOCK

    def beat(self, node_id: int) -> bool:
        if self.holder.load() != node_id:
            return False
        self.last_beat.store(self.clock.monotonic_ns())
        return True

    def expired(self) -> bool:
        if self.holder.load() == NO_MASTER:
            return True
        return (self.clock.monotonic_ns() - self.last_beat.load()) > self.timeout_s * 1e9

    def try_elect(self, node_id: int) -> bool:
        """CAS-based takeover: succeed only if the lease is vacant/expired.
        The term counter disambiguates two nodes racing on an expired lease:
        only the CAS winner bumps the term."""
        current = self.holder.load()
        if current != NO_MASTER and not self.expired():
            return False
        if self.holder.compare_exchange(current, node_id):
            self.term.fetch_add(1)
            self.last_beat.store(self.clock.monotonic_ns())
            return True
        return False


class FailoverNode:
    """An orchestrator node that can assume pool-master duty."""

    def __init__(self, node_id: int, pool: HierarchicalPool, catalog: Catalog,
                 lease: MasterLease, beat_interval_s: float = 0.05,
                 clock: Optional[Clock] = None):
        assert node_id != NO_MASTER
        self.node_id = node_id
        self.pool = pool
        self.catalog = catalog
        self.lease = lease
        self.beat_interval_s = beat_interval_s
        self.clock = clock or getattr(pool, "clock", None) or REAL_CLOCK
        self.master: Optional[PoolMaster] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.events = []

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _join(self, timeout_s: float) -> None:
        """Bounded join; the loop waits on the stop event (not a bare sleep),
        so it exits within one scheduling quantum and tests never leak the
        heartbeat thread between cases."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=timeout_s)
            assert not t.is_alive(), f"node {self.node_id}: heartbeat thread leaked"
            self._thread = None

    def stop(self, timeout_s: float = 2.0) -> None:
        self._join(timeout_s)

    def crash(self, timeout_s: float = 2.0) -> None:
        """Simulated failure: heartbeats cease immediately."""
        self._join(timeout_s)
        self.master = None
        self.events.append("crashed")

    @property
    def is_master(self) -> bool:
        return self.lease.holder.load() == self.node_id and self.master is not None

    def _become_master(self) -> None:
        # All state is pool-resident: adopt the shared catalog and re-derive
        # version counters from it (zero state transfer).
        m = PoolMaster(self.pool, self.catalog)
        for entry in self.catalog.entries:
            if entry.name:
                m._versions[entry.name] = entry.version
        self.master = m
        self.events.append(f"elected(term={self.lease.term.load()})")

    def tick(self) -> None:
        """One heartbeat-loop iteration: beat if master, else try to elect.
        Called from the thread loop under the real clock, or directly by the
        deterministic simulator as one scheduled host step."""
        if self.lease.holder.load() == self.node_id:
            self.lease.beat(self.node_id)
        elif self.lease.expired():
            if self.lease.try_elect(self.node_id):
                self._become_master()

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.tick()
            self.clock.wait_event(self._stop, self.beat_interval_s)
