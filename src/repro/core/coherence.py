"""Ownership-based coherence protocol for non-coherent shared CXL memory (§3.3).

CXL 2.0 MHDs give multiple hosts load/store access to the same bytes with
**no inter-host cache coherence**.  Aquifer sidesteps general coherence by
construction:

* snapshot data is **immutable while borrowed** — borrowers only read;
* the only mutable shared words are each catalog entry's ``state`` and
  ``refcount``, manipulated **only with atomic operations** (assumed per
  [49]; the ``LeaseFallback`` below covers devices without cross-host
  atomics);
* a successful borrow is followed by ``clflushopt`` over the snapshot's CXL
  sections so subsequent loads observe current bytes (HostView.invalidate).

Protocol (verbatim from the paper):
  borrow:   refcount.fetch_add(1); CAS(state, PUBLISHED→PUBLISHED).
            CAS failure ⇒ entry is tombstoned ⇒ refcount.fetch_sub(1) and
            fall back to cold start.  Incrementing refcount *first* closes
            the window where the owner could see refcount==0 mid-borrow.
  release:  refcount.fetch_sub(1).
  owner:    delete  = state←TOMBSTONE; reclaim data only once refcount==0.
            update  = state←TOMBSTONE; wait refcount==0; rewrite data;
                      state←PUBLISHED (refcount already 0).
            add     = pick a TOMBSTONE entry with refcount==0; write data;
                      state←PUBLISHED.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from .clock import Clock, REAL_CLOCK
from .snapshot import SnapshotRegions

# Catalog entry states.
STATE_FREE = 0         # never used / fully reclaimed
STATE_PUBLISHED = 1
STATE_TOMBSTONE = 2


class AtomicU64:
    """Linearizable 64-bit atomic cell (stand-in for CXL cross-host atomics)."""

    __slots__ = ("_v", "_lock")

    def __init__(self, value: int = 0):
        self._v = value
        self._lock = threading.Lock()

    def load(self) -> int:
        with self._lock:
            return self._v

    def store(self, value: int) -> None:
        with self._lock:
            self._v = value

    def fetch_add(self, delta: int) -> int:
        with self._lock:
            old = self._v
            self._v += delta
            return old

    def compare_exchange(self, expected: int, desired: int) -> bool:
        with self._lock:
            if self._v == expected:
                self._v = desired
                return True
            return False

    def exchange(self, desired: int) -> int:
        with self._lock:
            old = self._v
            self._v = desired
            return old


@dataclasses.dataclass
class CatalogEntry:
    """One slot of the snapshot catalog, resident in CXL memory."""

    index: int
    state: AtomicU64 = dataclasses.field(default_factory=lambda: AtomicU64(STATE_FREE))
    refcount: AtomicU64 = dataclasses.field(default_factory=AtomicU64)
    borrow_counter: AtomicU64 = dataclasses.field(default_factory=AtomicU64)  # §3.6 eviction
    # clock-eviction metadata (CXLCapacityManager): the reference bit gives
    # borrowed-since-last-sweep second chances, the timestamp records restore
    # recency for introspection/LRU tie-breaks.
    referenced: AtomicU64 = dataclasses.field(default_factory=AtomicU64)
    last_borrow_s: float = 0.0
    # Region record (rewritten only by the owner while TOMBSTONE & refcount==0).
    regions: Optional[SnapshotRegions] = None
    name: str = ""
    version: int = 0


class Borrow:
    """RAII-ish handle for an established borrow."""

    def __init__(self, entry: CatalogEntry, on_release: Callable[[], None]):
        self.entry = entry
        self.regions = entry.regions
        self.version = entry.version
        self._on_release = on_release
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self.entry.refcount.fetch_add(-1)
            self._on_release()

    def __enter__(self) -> "Borrow":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class Catalog:
    """Fixed-size snapshot catalog shared by the pool master + orchestrators."""

    def __init__(self, capacity: int = 256, clock: Optional[Clock] = None):
        self.entries: List[CatalogEntry] = [CatalogEntry(i) for i in range(capacity)]
        self.clock = clock or REAL_CLOCK
        self._by_name_lock = threading.Lock()
        self._by_name: Dict[str, int] = {}

    # -- lookup -------------------------------------------------------------
    def find(self, name: str) -> Optional[CatalogEntry]:
        with self._by_name_lock:
            idx = self._by_name.get(name)
        return self.entries[idx] if idx is not None else None

    def _bind(self, name: str, index: int) -> None:
        with self._by_name_lock:
            self._by_name[name] = index

    def _unbind(self, name: str) -> None:
        with self._by_name_lock:
            self._by_name.pop(name, None)

    # -- borrower side (§3.3 Borrow protocol) ---------------------------------
    def borrow_steps(self, name: str, noop=lambda: None,
                     state_precheck: bool = True) -> Iterator[Tuple[str, object]]:
        """Generator form of :meth:`borrow`, yielding at the protocol's
        inter-host visibility points so a deterministic scheduler (repro.sim)
        can interleave other hosts *between* the refcount increment and the
        state CAS.  Yields ``(label, value)``:

        * ``("refcount_incremented", entry)`` — increment done, CAS pending;
        * ``("doomed", entry)``  — CAS failed, increment already backed out;
        * ``("done", Borrow | None)`` — terminal; None ⇒ caller cold-starts.

        ``state_precheck=False`` reverts the PR-1 doomed-borrow fix (the
        fast-path state test), for tests that reproduce the pre-fix livelock.
        """
        entry = self.find(name)
        if entry is None:
            yield ("done", None)
            return
        # 0) fast-path reject on a non-published entry WITHOUT touching the
        # refcount.  Doomed borrows (inc → CAS-fail → dec) are protocol-safe
        # but their transient increments can livelock the owner's
        # wait-for-drain when borrowers retry in a tight loop; testing the
        # state first makes them rare.  A stale PUBLISHED read here only
        # leads to the doomed-borrow path below, which remains correct.
        if state_precheck and entry.state.load() != STATE_PUBLISHED:
            yield ("done", None)
            return
        # 1) refcount++ first (closes the owner-sees-zero window)
        entry.refcount.fetch_add(1)
        yield ("refcount_incremented", entry)
        # 2) CAS state expecting PUBLISHED — atomic, ordered after the increment
        if entry.state.compare_exchange(STATE_PUBLISHED, STATE_PUBLISHED):
            entry.borrow_counter.fetch_add(1)
            entry.referenced.store(1)
            entry.last_borrow_s = self.clock.monotonic()
            yield ("done", Borrow(entry, noop))
            return
        # CAS failed: snapshot is being reclaimed → back out, cold start
        entry.refcount.fetch_add(-1)
        yield ("doomed", entry)
        yield ("done", None)

    def borrow(self, name: str, noop=lambda: None) -> Optional[Borrow]:
        result: Optional[Borrow] = None
        for label, value in self.borrow_steps(name, noop):
            if label == "done":
                result = value
        return result

    # -- owner side (pool master only) ----------------------------------------
    def publish_new(self, name: str, regions: SnapshotRegions, version: int = 0) -> CatalogEntry:
        entry = self._claim_reusable_entry()
        entry.regions = regions
        entry.name = name
        entry.version = version
        entry.borrow_counter.store(0)
        entry.referenced.store(0)
        entry.last_borrow_s = 0.0
        assert entry.refcount.load() == 0
        self._bind(name, entry.index)
        ok = entry.state.compare_exchange(entry.state.load(), STATE_PUBLISHED)
        assert ok
        return entry

    def tombstone(self, name: str) -> Optional[CatalogEntry]:
        """Prevent new borrows; in-flight borrows continue until release."""
        entry = self.find(name)
        if entry is None:
            return None
        entry.state.store(STATE_TOMBSTONE)
        return entry

    def wait_unborrowed(self, entry: CatalogEntry, timeout_s: float = 30.0) -> bool:
        deadline = self.clock.monotonic() + timeout_s
        while entry.refcount.load() != 0:
            if self.clock.monotonic() > deadline:
                return False
            self.clock.sleep(1e-5)
        return True

    def republish(self, entry: CatalogEntry, regions: SnapshotRegions, version: int) -> None:
        """Owner update: caller must hold TOMBSTONE state after a drain.

        Note: refcount may be transiently nonzero here — a *doomed* borrow
        (refcount++ already done, state CAS about to fail) never reads data,
        so the rewrite/republish is safe; only successful borrows matter,
        and those are excluded by the TOMBSTONE state."""
        assert entry.state.load() == STATE_TOMBSTONE
        entry.regions = regions
        entry.version = version
        ok = entry.state.compare_exchange(STATE_TOMBSTONE, STATE_PUBLISHED)
        assert ok

    def reclaim(self, entry: CatalogEntry) -> None:
        """Logical delete → FREE once the last successful borrow drains
        (transient doomed-borrow increments are harmless, see republish)."""
        assert entry.state.load() == STATE_TOMBSTONE
        self._unbind(entry.name)
        entry.regions = None
        entry.name = ""
        entry.state.store(STATE_FREE)

    def _claim_reusable_entry(self) -> CatalogEntry:
        # Prefer FREE slots; else TOMBSTONE slots whose refcount drained (§3.3 Add).
        for entry in self.entries:
            if entry.state.load() == STATE_FREE:
                if entry.state.compare_exchange(STATE_FREE, STATE_TOMBSTONE):
                    if entry.refcount.load() == 0:
                        return entry
        for entry in self.entries:
            if (
                entry.state.load() == STATE_TOMBSTONE
                and entry.refcount.load() == 0
                and entry.regions is None
                and not entry.name      # still-bound entries are mid-update
            ):
                return entry
        raise RuntimeError("catalog full")


class LeaseFallback:
    """§3.6: RDMA-RPC leases for CXL pools without cross-host atomics.

    All orchestrators talk to the pool master, which serializes lease
    grant/release against update/delete.  Same observable semantics as the
    atomic protocol, at the cost of one RPC per restore and one per shutdown.
    """

    def __init__(self, catalog: Catalog, rpc_latency_s: float = 10e-6):
        self.catalog = catalog
        self.rpc_latency_s = rpc_latency_s
        self._lock = threading.Lock()   # the pool master's serialization point
        self.rpc_count = 0

    def acquire(self, name: str) -> Optional[Borrow]:
        with self._lock:
            self.rpc_count += 1
            entry = self.catalog.find(name)
            if entry is None or entry.state.load() != STATE_PUBLISHED:
                return None
            entry.refcount.fetch_add(1)
            entry.borrow_counter.fetch_add(1)
            entry.referenced.store(1)
            entry.last_borrow_s = self.catalog.clock.monotonic()
            return Borrow(entry, self._on_release)

    def _on_release(self) -> None:
        with self._lock:
            self.rpc_count += 1
