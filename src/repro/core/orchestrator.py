"""Node-side orchestrator (§3.1): MicroVM lifecycle on one server host.

Each orchestrator owns a host-private (incoherent) view of the CXL tier and
restores instances by: borrow → clflushopt the snapshot's CXL sections →
load machine state → pre-install hot set → resume, with cold pages
demand-paged asynchronously from RDMA.  Falls back to cold start when the
borrow CAS fails (§3.3).

Restores are served through the host-wide :class:`NodePageServer` by
default — one shared RDMA engine / completion worker / prefetch pump per
host, with hot-chunk fan-out across same-snapshot restores (DESIGN.md §10).
``scatter_fn`` accepts any ``ScatterFn`` — the numpy oracle, the Pallas
``page_scatter`` op, or the fused gather→checksum→scatter kernel
(``kernels/snapshot_fuse.FusedScatter``, DESIGN.md §13); the fused form is
additionally bound per restore to the snapshot's publish-time checksum
table, so pre-install and fan-out installs verify content as they land.
``use_node_server=False`` keeps the legacy per-instance engine path (one
private engine + completion thread per restore) for A/B comparison; that
path registers each restore as its own stream on the host's link arbiters
so its modeled time is contention-aware too.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

from .coherence import Borrow, Catalog
from .nodeserver import NodePageServer
from .pagestore import StateImage
from .pool import HierarchicalPool, TimeLedger
from .prefetch_model import PrefetchPolicy, resolve_policy
from .serving import AsyncRDMAEngine, BufferPool, Instance, RestoreEngine
from .snapshot import SnapshotReader


@dataclasses.dataclass
class RestoredInstance:
    """A restored microVM instance plus the borrow pinning its snapshot."""

    name: str
    instance: Instance
    engine: RestoreEngine
    borrow: Borrow
    ledger: TimeLedger
    cold_start: bool = False

    def shutdown(self) -> None:
        self.engine.stop()
        if self.engine.rdma_engine is not None:
            self.engine.rdma_engine.close()
        self.borrow.release()


class Orchestrator:
    """One per server node; connected to the pod's shared pool + catalog."""

    def __init__(
        self,
        host: str,
        pool: HierarchicalPool,
        catalog: Catalog,
        use_async_rdma: bool = True,
        buffer_pool_pages: int = 256,
        prefetch_cold: bool = False,
        max_extent_pages: Optional[int] = None,
        scatter_fn=None,
        node_server: Optional[NodePageServer] = None,
        use_node_server: bool = True,
        heat=None,
        prefetch_policy: Optional[PrefetchPolicy] = None,
    ):
        self.host = host
        self.pool = pool
        self.catalog = catalog
        # online hotness feedback: pod-shared HeatRegistry; every restore's
        # demand-fault / prefetch-hit / touch telemetry lands there keyed by
        # the borrowed (name, version)
        self.heat = heat
        self.use_async_rdma = use_async_rdma
        self.buffer_pool_pages = buffer_pool_pages
        self.prefetch_cold = prefetch_cold
        # cold-extent ordering seam (DESIGN.md §17); ``max_extent_pages=N``
        # is the deprecated pre-policy spelling of LayoutOrderPolicy(N)
        if max_extent_pages is not None or prefetch_policy is None:
            prefetch_policy = resolve_policy(
                prefetch_policy, max_extent_pages, "Orchestrator")
        self.prefetch_policy = prefetch_policy
        self.scatter_fn = scatter_fn
        self.node_server = node_server
        self.use_node_server = bool(use_node_server) and use_async_rdma
        self._owned_server: Optional[NodePageServer] = None
        self.stats = {"warm_restores": 0, "cold_starts": 0}
        self._lock = threading.Lock()

    def _get_server(self) -> NodePageServer:
        if self.node_server is not None:
            return self.node_server
        with self._lock:
            if self._owned_server is None:
                self._owned_server = NodePageServer(
                    self.host, self.pool,
                    buffer_pool_pages=self.buffer_pool_pages,
                    heat=self.heat)
            return self._owned_server

    def close(self) -> None:
        """Park the owned node server (its threads auto-park when the last
        session detaches, so this is belt-and-braces for early teardown)."""
        with self._lock:
            srv, self._owned_server = self._owned_server, None
        if srv is not None:
            srv.close()

    def restore(self, name: str, pre_install: bool = True,
                prefetch_cold: Optional[bool] = None,
                prefetch_policy: Optional[PrefetchPolicy] = None,
                ) -> Optional[RestoredInstance]:
        """Warm-restore an instance from the pool; None ⇒ caller cold-boots.

        The hot set is pre-installed run-at-a-time (one CXL read + one
        uffd.copy ioctl per contiguous run); with ``prefetch_cold`` the cold
        extents are additionally streamed in the background in
        ``prefetch_policy`` order (default: the orchestrator's policy, i.e.
        snapshot layout) while demand faults retain priority (§3.4)."""
        borrow = self.catalog.borrow(name)
        if borrow is None or borrow.regions is None:
            with self._lock:
                self.stats["cold_starts"] += 1
            return None

        ledger = TimeLedger()
        view = self.pool.host_view(self.host, ledger)
        reader = SnapshotReader(borrow.regions, view, self.pool.rdma)
        # §3.3: after a successful borrow, invalidate potentially-stale lines
        reader.invalidate_cxl()
        manifest, _meta = reader.machine_state()

        instance = Instance(StateImage.empty_like(manifest), ledger,
                            clock=self.pool.clock)
        if self.use_node_server:
            engine = self._get_server().attach(
                name, borrow.regions.version, reader, instance,
                scatter_fn=self.scatter_fn)
        else:
            rdma_engine = (
                AsyncRDMAEngine(self.pool.rdma, ledger, host=self.host)
                if self.use_async_rdma else None
            )
            engine = RestoreEngine(
                reader, instance, rdma_engine, BufferPool(self.buffer_pool_pages),
                scatter_fn=self.scatter_fn,
            )
            if self.heat is not None:
                hm = self.heat.map_for(name, borrow.regions.version,
                                       instance.image.total_pages)
                hm.note_restore()
                engine.heat = hm
            # A/B honesty: a private-engine restore is still one stream on
            # the host's CXL link and RNIC — register it so its modeled
            # time sees the same contention the shared runtime sees
            key = ("restore", id(engine))
            for tier in (self.pool.cxl, self.pool.rdma):
                arbiter = tier.arbiter_for(self.host)
                arbiter.register(key)
                engine.link_keys.append((arbiter, key))
        try:
            if pre_install:
                engine.pre_install_hot()
            engine.start_completion_handler()
            do_prefetch = (self.prefetch_cold if prefetch_cold is None
                           else prefetch_cold)
            if do_prefetch:
                engine.start_prefetcher(
                    policy=prefetch_policy or self.prefetch_policy)
        except BaseException:
            # failed restore (e.g. a fused-scatter checksum mismatch during
            # pre-install) must not leak the engine session or the borrow
            engine.stop()
            if engine.rdma_engine is not None:
                engine.rdma_engine.close()
            borrow.release()
            raise
        with self._lock:
            self.stats["warm_restores"] += 1
        return RestoredInstance(name, instance, engine, borrow, ledger)
