"""Pool master: sole owner of pool-side snapshot storage (§3.1, §3.3, §3.6).

Responsibilities: publish / update / delete snapshots under the ownership
protocol, reclaim tombstoned regions once their refcount drains, and run the
borrow-counter based CXL eviction policy (§3.6).  Content-hash deduplication
(§3.6) is an optional layer applied at publish time.

Beyond the paper: a per-pod CXL capacity manager (clock eviction over
snapshot hot regions, degrade-to-RDMA on over-subscription) and the
heat-feedback re-curation pipeline (``recurate``), which rebuilds a
published snapshot with a corrected hot set and republishes it through the
same ownership protocol — so the coherence invariants I1–I5 cover
re-curation with no new protocol states.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .clock import Clock, REAL_CLOCK
from .coherence import STATE_PUBLISHED, STATE_TOMBSTONE, Catalog, CatalogEntry
from .pagestore import StateImage
from .pool import AllocError, CXLBudget, HierarchicalPool
from .prefetch_model import fit_prefetch_model
from .snapshot import (
    SnapshotRegions,
    build_snapshot,
    estimate_snapshot_cxl_size,
    exclusive_cxl_bytes,
    free_snapshot,
    plan_recuration,
    reconstruct_image,
)


class CXLCapacityManager:
    """Per-pod CXL budget enforcement with clock eviction (§3.6 grown up).

    Admission: before a publish builds its CXL region, the master asks
    :meth:`admit` whether the estimated bytes fit the pod budget.  When they
    do not, a clock hand sweeps the catalog's published snapshots:

    * entries borrowed since the last sweep carry a ``referenced`` bit —
      the hand clears it and gives them a second chance (clock ≈ LRU by
      restore recency without a sorted list in shared memory);
    * entries with a nonzero refcount are SKIPPED, never evicted — a live
      borrow (including fan-out restores holding ``HotChunkCache`` chunks
      borrowed against the entry) pins the hot region;
    * the victim is *demoted*, not deleted: its image is reconstructed and
      republished with an empty working set through the ownership protocol,
      so its hot region moves to RDMA and later restores degrade to
      demand-paging instead of disappearing.

    When even a full sweep cannot make room, :meth:`admit` returns False
    and the caller publishes the NEW snapshot all-cold (hot set spilled to
    RDMA) — over-subscription degrades, it never fails ``alloc``.
    """

    def __init__(self, master: "PoolMaster", budget_bytes: int,
                 demote_drain_timeout_s: float = 0.25):
        self.master = master
        self.budget = CXLBudget(budget_bytes)
        self.demote_drain_timeout_s = demote_drain_timeout_s
        self._hand = 0
        self._lock = threading.Lock()

    def usage(self) -> int:
        """Authoritative: sum of live catalog entries' CXL regions (the
        gauge in :class:`~repro.core.pool.CXLBudget` is synced from this,
        so accounting can never drift from the shared truth).  Each entry's
        ``regions`` is read ONCE — a concurrent update may null it between
        a check and a re-read.

        Dedup snapshots contribute only their private metadata region here;
        their page payloads are accounted ONCE, as the content store's
        unique bytes — publishing ten variants of one base costs the budget
        one copy of the shared pages plus each variant's deltas."""
        regions = [e.regions for e in self.master.catalog.entries]
        total = sum(r.cxl_size for r in regions if r is not None)
        total += self.master.pool.dedup_cxl.unique_bytes()
        self.budget.set_usage(total)
        return total

    def admit(self, needed_bytes: int, exclude_name: str = "") -> bool:
        """True ⇒ the CXL region fits (possibly after demotions); False ⇒
        caller must degrade the publish to RDMA."""
        with self._lock:
            budget = self.budget.budget_bytes
            usage = self.usage()
            if usage + needed_bytes <= budget:
                self.budget.stats["admitted"] += 1
                return True
            self.budget.stats["sweeps"] += 1
            # Incremental sweep: ``usage()`` is a full O(catalog) region sum
            # plus a dedup-store scan, so recomputing it per demotion made
            # the sweep O(victims x catalog).  Each victim instead reports
            # the bytes its demotion actually freed (old-minus-new private
            # region + store-unique delta) and the running gauge is
            # decremented — one recompute at entry, one at exit.
            while usage + needed_bytes > budget:
                freed = self._demote_one(exclude_name)
                if freed is None:
                    break
                usage -= freed
            # conservation check: the incremental estimate must agree with
            # the authoritative recompute (which also re-syncs the gauge) —
            # a drift here means a victim mis-reported its freed bytes
            actual = self.usage()
            assert usage == actual, (
                f"capacity sweep conservation: incremental usage {usage} "
                f"!= recomputed {actual}")
            if actual + needed_bytes <= budget:
                self.budget.stats["admitted"] += 1
                return True
            self.budget.stats["degraded"] += 1
            return False

    def _demote_one(self, exclude_name: str) -> Optional[int]:
        """One clock sweep: demote the first unreferenced, unborrowed
        published snapshot with a non-empty hot region.  Two full rounds so
        every referenced bit can be cleared once before we give up.
        Returns the CXL bytes the demotion freed (for the caller's
        incremental usage accounting), or None when no victim demoted —
        including the empty-catalog and everything-excluded cases."""
        entries = self.master.catalog.entries
        n = len(entries)
        for _ in range(2 * n):
            entry = entries[self._hand % n]
            self._hand += 1
            r = entry.regions
            if (entry.state.load() != STATE_PUBLISHED or r is None
                    or not entry.name or entry.name == exclude_name
                    or r.hot_bytes <= 0):
                continue
            if entry.referenced.exchange(0):
                continue                      # second chance (recently restored)
            if entry.refcount.load() != 0:
                continue                      # pinned by live borrows / fan-out
            name = entry.name
            # pin the regions while READING them (exclusive-footprint scoring
            # decodes the stored offset array, materialization reads the data
            # pages): a concurrent owner op on this name cannot free bytes we
            # are still reading.  Released BEFORE the demoting publish — our
            # own pin would deadlock its drain otherwise.
            pin = self.master.catalog.borrow(name)
            if pin is None or pin.regions is not r:
                if pin is not None:
                    pin.release()
                continue                      # owner op raced us: skip victim
            try:
                image = None
                if exclusive_cxl_bytes(self.master.pool, r) <= 0:
                    # every hot page is shared with another live snapshot:
                    # demoting this victim frees ~nothing (the content store
                    # keeps the pages for its co-owners), so the clock skips it
                    self.budget.stats["shared_skips"] += 1
                else:
                    image = reconstruct_image(self.master.pool, r)
            finally:
                pin.release()
                # our own pin set the reference bit — clear it so a FAILED
                # demotion does not grant the victim an unearned second
                # chance on every later sweep
                entry.referenced.store(0)
            if image is None:
                continue
            # measure what this demotion frees WITHOUT a full recompute: the
            # victim's private CXL region shrinks (hot data moves to RDMA)
            # and, for dedup victims, the store releases this snapshot's
            # exclusive pages (shared pages stay for their co-owners)
            old_cxl = r.cxl_size
            unique_before = self.master.pool.dedup_cxl.unique_bytes()
            if not self._demote_publish(name, image, r.version, dedup=r.dedup):
                continue                      # a borrow landed mid-drain: skip
            self.budget.stats["demotions"] += 1
            new_entry = self.master.catalog.find(name)
            new_cxl = (new_entry.regions.cxl_size
                       if new_entry is not None and new_entry.regions is not None
                       else 0)
            store_freed = unique_before - self.master.pool.dedup_cxl.unique_bytes()
            return (old_cxl - new_cxl) + store_freed
        return None

    def _demote_publish(self, name: str, image: StateImage, old_version: int,
                        dedup: bool = False) -> bool:
        """Drive the demoting publish with a bounded drain.  On a drain
        timeout the victim is rolled back to PUBLISHED (the update path
        tombstones before freeing; until the drain completes the old
        regions are untouched, so flipping the state back simply restores
        borrowability) — a timed-out demotion must never wedge the victim
        as a permanent TOMBSTONE."""
        gen = self.master.publish_steps(name, image, [],
                                        metadata={"demoted_from": old_version},
                                        expect_version=old_version,
                                        dedup=dedup)
        clock = self.master.clock
        deadline: Optional[float] = None
        entry: Optional[CatalogEntry] = None
        for label, value in gen:
            if label == "tombstoned":
                entry = value
            elif label == "done":
                return True
            elif label == "stale":
                return False      # an owner update raced us: not our victim
            if label in ("draining", "owner_busy"):
                if deadline is None:
                    deadline = clock.monotonic() + self.demote_drain_timeout_s
                if clock.monotonic() > deadline:
                    gen.close()
                    if (label == "draining" and entry is not None
                            and entry.regions is not None):
                        entry.state.compare_exchange(STATE_TOMBSTONE,
                                                     STATE_PUBLISHED)
                    return False
                clock.sleep(1e-5)
        return False

    def report(self) -> Dict[str, int]:
        self.usage()
        return self.budget.report()


class PoolMaster:
    """Ownership-protocol control plane for one pod's snapshot catalog."""

    def __init__(self, pool: HierarchicalPool, catalog: Optional[Catalog] = None,
                 clock: Optional[Clock] = None, cxl_budget: Optional[int] = None,
                 heat=None, dedup: bool = False, publish_fn=None):
        self.pool = pool
        # default fused publish sweep (kernels/snapshot_fuse): used by every
        # publish this master drives — including re-curation rebuilds and
        # capacity demotions — unless the call site overrides it
        self.publish_fn = publish_fn
        self.clock = clock or getattr(pool, "clock", None) or REAL_CLOCK
        self.catalog = catalog or Catalog(clock=self.clock)
        # per-pod CXL capacity manager (None ⇒ unmanaged, paper behaviour)
        self.capacity = (CXLCapacityManager(self, cxl_budget)
                         if cxl_budget is not None else None)
        # pod-level HeatRegistry (online feedback); recurate() reads it
        self.heat = heat
        # default publish mode: content-addressed page store (per-publish
        # ``dedup=`` overrides; updates/demotions/re-curations preserve the
        # existing snapshot's mode so a pod can mix layouts)
        self.dedup_default = dedup
        self._versions: Dict[str, int] = {}
        self._pending_reclaim: List[CatalogEntry] = []
        self._lock = threading.Lock()
        # Owner-op serialization (two concurrent tombstone→free→republish
        # sequences of one snapshot would double-free the old regions; two
        # concurrent first publishes of one name would leak an entry):
        #   _busy_names  — names with a publish in flight (claimed first)
        #   _owner_busy  — entry indices mid-update; gc() defers these
        self._busy_names: set = set()
        self._owner_busy: set = set()

    # -- snapshot lifecycle (§3.3 Owner protocol) -------------------------------
    def publish_steps(
        self,
        name: str,
        image: StateImage,
        working_set: Sequence[int],
        metadata: Optional[dict] = None,
        zero_bitmap: Optional[np.ndarray] = None,
        gather_fn=None,
        compress_cold: bool = False,
        expect_version: Optional[int] = None,
        dedup: Optional[bool] = None,
        publish_fn=None,
        version: Optional[int] = None,
    ) -> Iterator[Tuple[str, object]]:
        """Generator form of :meth:`publish`, yielding at the owner protocol's
        phase boundaries so the deterministic simulator can interleave
        borrowers (and crash the owner) *between* phases.  Yields
        ``(label, value)``:

        * ``("owner_busy", name)``     — another publish of this name is in
          flight; the driver waits (sleep / timeout) and resumes to re-poll;
        * ``("stale", entry)``         — terminal: ``expect_version`` was
          given and the entry's version moved before we claimed the name
          (used by re-curation, which republishes *reconstructed* bytes and
          must never overwrite a newer legitimate update with them);
        * ``("built_new", regions)``   — new-name path, data written;
        * ``("tombstoned", entry)``    — update path, new borrows now fail;
        * ``("draining", entry)``      — refcount still nonzero; the driver
          decides how to wait (sleep / timeout) and resumes to re-poll;
        * ``("freed_old", entry)``     — old data regions returned to the pool;
        * ``("rebuilt", regions)``     — new data written, not yet visible;
        * ``("done", regions)``        — terminal: snapshot is PUBLISHED.
        """
        dedup = self.dedup_default if dedup is None else bool(dedup)
        publish_fn = self.publish_fn if publish_fn is None else publish_fn
        # claim the name BEFORE assigning a version or inspecting the catalog:
        # serialized publishes then get monotonic versions and concurrent
        # first-publishes of a new name cannot both take the create path
        while True:
            with self._lock:
                if name not in self._busy_names:
                    self._busy_names.add(name)
                    break
            yield ("owner_busy", name)
        existing = None
        try:
            existing = self.catalog.find(name)
            if expect_version is not None and (
                    existing is None or existing.version != expect_version):
                yield ("stale", existing)
                return
            with self._lock:
                # ``version``: a group-level replica manager (topology layer)
                # assigns ONE version for a (name, version) replicated across
                # pods, overriding this master's private counter — replicas
                # of a snapshot must agree on version, not just bytes (I7)
                if version is None:
                    version = self._versions.get(name, -1) + 1
                self._versions[name] = max(self._versions.get(name, -1),
                                           version)
            if existing is None:
                regions = self._build_admitted(
                    name, image, working_set,
                    version=version, metadata=metadata,
                    zero_bitmap=zero_bitmap, gather_fn=gather_fn,
                    compress_cold=compress_cold, dedup=dedup,
                    publish_fn=publish_fn,
                )
                yield ("built_new", regions)
                self.catalog.publish_new(name, regions, version)
                if self.heat is not None:
                    self.heat.prune(name, version - 1)
                yield ("done", regions)
                return
            # Update (§3.3): tombstone → wait for borrows to drain → rewrite
            # the data regions → republish.  Freeing before rebuilding lets
            # first-fit reuse the same pool addresses (the paper writes in
            # place), which is exactly why borrowers must clflushopt after a
            # successful borrow.
            old = existing.regions
            # A pending delete of this name is superseded by the update:
            # cancel its deferred reclaim BEFORE tombstoning (gc() skips
            # PUBLISHED entries), else a concurrent gc() during our drain
            # window would free the old regions a second time and reclaim
            # the entry mid-update.  Deletes issued *during* the drain are
            # handled by gc() deferring entries in _owner_busy.
            with self._lock:
                while existing in self._pending_reclaim:
                    self._pending_reclaim.remove(existing)
                self._owner_busy.add(existing.index)
            self.catalog.tombstone(name)
            yield ("tombstoned", existing)
            while existing.refcount.load() != 0:
                yield ("draining", existing)
            if old is not None:
                free_snapshot(self.pool, old)
                # drop the dangling reference NOW: if we crash (generator
                # close) or the rebuild raises before republish, a later
                # delete()+gc() must not free these bytes a second time
                existing.regions = None
            yield ("freed_old", existing)
            regions = self._build_admitted(
                name, image, working_set,
                version=version, metadata=metadata,
                zero_bitmap=zero_bitmap, gather_fn=gather_fn,
                compress_cold=compress_cold, dedup=dedup,
                publish_fn=publish_fn,
            )
            yield ("rebuilt", regions)
            self.catalog.republish(existing, regions, version)
            if self.heat is not None:
                self.heat.prune(name, version - 1)
            # a delete() that landed during our drain window is superseded by
            # this update (last writer wins): clear its pending reclaim, else
            # the now-PUBLISHED entry sits in _pending_reclaim forever
            with self._lock:
                while existing in self._pending_reclaim:
                    self._pending_reclaim.remove(existing)
        finally:
            # also runs on generator close (aborted/crashed owner), so a dead
            # update never wedges later publishes of the same name
            with self._lock:
                self._busy_names.discard(name)
                if existing is not None:
                    self._owner_busy.discard(existing.index)
        yield ("done", regions)

    def publish(
        self,
        name: str,
        image: StateImage,
        working_set: Sequence[int],
        metadata: Optional[dict] = None,
        zero_bitmap: Optional[np.ndarray] = None,
        gather_fn=None,
        compress_cold: bool = False,
        drain_timeout_s: float = 30.0,
        dedup: Optional[bool] = None,
        publish_fn=None,
        version: Optional[int] = None,
    ) -> SnapshotRegions:
        """Blocking driver over :meth:`publish_steps` (production path)."""
        regions = self._drive_steps(
            self.publish_steps(name, image, working_set, metadata=metadata,
                               zero_bitmap=zero_bitmap, gather_fn=gather_fn,
                               compress_cold=compress_cold, dedup=dedup,
                               publish_fn=publish_fn, version=version),
            name, drain_timeout_s)
        assert regions is not None
        return regions

    def _drive_steps(self, gen: Iterator[Tuple[str, object]], name: str,
                     drain_timeout_s: float) -> Optional[SnapshotRegions]:
        """Shared blocking driver for the owner-op step generators: poll
        through draining/owner_busy with one overall drain deadline, return
        the regions on ``done`` or None on ``skipped``/``missing``."""
        deadline: Optional[float] = None
        regions: Optional[SnapshotRegions] = None
        for label, value in gen:
            if label in ("draining", "owner_busy"):
                if deadline is None:
                    deadline = self.clock.monotonic() + drain_timeout_s
                if self.clock.monotonic() > deadline:
                    raise TimeoutError(f"borrows of {name} did not drain")
                self.clock.sleep(1e-5)
            elif label == "done":
                regions = value
            elif label in ("skipped", "missing", "stale"):
                return None
        return regions

    def _build_admitted(self, name: str, image: StateImage,
                        working_set: Sequence[int], **build_kw) -> SnapshotRegions:
        """Build one snapshot under the pod CXL budget: ask the capacity
        manager to admit the estimated CXL bytes (demoting clock victims if
        needed), and degrade the hot set to RDMA (empty working set) when it
        cannot — or when first-fit fragmentation still fails the alloc.
        Over-subscribed pods degrade; they do not raise ``AllocError``."""
        ws = working_set
        if self.capacity is not None and len(ws):
            need = estimate_snapshot_cxl_size(
                image, ws, build_kw.get("zero_bitmap"),
                metadata=build_kw.get("metadata"),
                compress_cold=build_kw.get("compress_cold", False),
                dedup=build_kw.get("dedup", False), pool=self.pool)
            if not self.capacity.admit(need, exclude_name=name):
                ws = []
        try:
            return build_snapshot(self.pool, image, ws, name, **build_kw)
        except AllocError as e:
            # degrade only on a CXL-side failure: an all-cold rebuild needs
            # strictly MORE RDMA bytes, so retrying an RDMA failure is
            # guaranteed to fail again (and in the update path would leave
            # the entry wedged with its old regions already freed)
            if (self.capacity is None or not len(ws)
                    or getattr(e, "tier", "") != "cxl"):
                raise
            self.capacity.budget.stats["degraded"] += 1
            return build_snapshot(self.pool, image, [], name, **build_kw)

    # -- online re-curation (heat feedback → snapshot rebuild) -----------------
    def recurate_steps(
        self,
        name: str,
        heat=None,
        min_promote_heat: float = 1.0,
        demote_max_heat: float = 1e-3,
        min_restores: int = 2,
        expected_restores: int = 64,
        force: bool = False,
    ) -> Iterator[Tuple[str, object]]:
        """Generator form of :meth:`recurate` (simulator-steppable).

        Phases: ``("planned", (plan, economics))`` → either
        ``("skipped", economics)`` (benefit below break-even and not
        forced) or the full :meth:`publish_steps` update sequence —
        re-curation IS an owner update, so tombstone/drain/republish and
        the I1–I5 invariants cover it unchanged.  The rebuilt image is
        reconstructed from the stored snapshot itself, so restores of the
        new version remain bit-identical to the original publish.
        """
        from ..serve.strategies import recuration_economics

        # pin the published regions for the whole read phase (plan +
        # reconstruction): a concurrent owner update/delete of this name
        # frees the old regions only after borrows drain, so the bytes we
        # materialize can never be reused under us.  The pin is released
        # before the republish below — our own borrow would deadlock its
        # drain.  (A legitimate update landing between release and our
        # tombstone is overwritten last-writer-wins, same as delete-vs-
        # update; it cannot corrupt data.)
        pin = self.catalog.borrow(name)
        if pin is None or pin.regions is None:
            if pin is not None:
                pin.release()
            yield ("missing", name)
            return
        image = None
        try:
            # NO yields while pinned: the pin must not outlive this block
            # (a paused generator would hold the refcount indefinitely, and
            # our own borrow would deadlock the republish drain below)
            regions = pin.regions
            if heat is None and self.heat is not None:
                heat = self.heat.find(name, regions.version)
            if heat is not None:
                # the same first-touch model the prefetch pump schedules
                # by: the promote set tracks observed touch ORDER, not just
                # decayed heat (None with no sequence telemetry — pure
                # heat-ranked recuration, the pre-§17 behaviour)
                model = fit_prefetch_model(heat)
                plan = plan_recuration(self.pool, regions, heat,
                                       min_promote_heat=min_promote_heat,
                                       demote_max_heat=demote_max_heat,
                                       min_restores=min_restores,
                                       model=model)
                econ = recuration_economics(regions, plan, expected_restores)
                if force or (plan.changed and econ["worthwhile"]):
                    image = reconstruct_image(self.pool, regions)
        finally:
            pin.release()
        if heat is None:
            yield ("missing", name)
            return
        yield ("planned", (plan, econ))
        if image is None:
            yield ("skipped", econ)
            return
        yield ("reconstructed", image)
        # expect_version: if a legitimate owner update raced in after the
        # pin was released, our reconstructed (now stale) bytes must NOT
        # overwrite it — the republish aborts with ("stale", ...) instead.
        # dedup=regions.dedup: re-curation preserves the snapshot's layout
        # (a content-addressed snapshot republishes content-addressed)
        yield from self.publish_steps(
            name, image, plan.new_working_set,
            metadata={"recurated_from": regions.version,
                      "promoted": int(plan.promote.size),
                      "demoted": int(plan.demote.size)},
            expect_version=regions.version,
            dedup=regions.dedup,
        )

    def recurate(self, name: str, heat=None, drain_timeout_s: float = 30.0,
                 **kw) -> Optional[SnapshotRegions]:
        """Blocking driver over :meth:`recurate_steps`.  Returns the new
        regions, or None when re-curation was skipped (below break-even,
        no change, or no heat recorded for the published version)."""
        return self._drive_steps(self.recurate_steps(name, heat=heat, **kw),
                                 name, drain_timeout_s)

    def delete(self, name: str, gc_now: bool = True) -> bool:
        """Tombstone + schedule reclaim.  ``gc_now=False`` defers the reclaim
        to an explicit :meth:`gc` call (the simulator interleaves other hosts
        — and lease expiry — between the tombstone and the reclaim).

        Owner ops are last-writer-wins: a delete that lands while an update
        of the same name is draining is superseded by the update (the entry
        is republished and the pending reclaim cancelled)."""
        entry = self.catalog.tombstone(name)
        if entry is None:
            return False
        with self._lock:
            if entry not in self._pending_reclaim:
                self._pending_reclaim.append(entry)
        if gc_now:
            self.gc()
        return True

    def gc(self) -> int:
        """Reclaim tombstoned entries whose refcount has drained (§3.3)."""
        freed = 0
        with self._lock:
            remaining: List[CatalogEntry] = []
            for entry in self._pending_reclaim:
                if entry.index in self._owner_busy:
                    # an update owns this entry's transition (its drain window
                    # is transiently TOMBSTONE/refcount==0): reclaiming now
                    # would double-free the old regions under the updater
                    remaining.append(entry)
                    continue
                if entry.refcount.load() == 0 and entry.state.load() == STATE_TOMBSTONE:
                    # free what the entry holds NOW (a delete-time copy could
                    # be stale if an update swapped the regions in between)
                    if entry.regions is not None:
                        free_snapshot(self.pool, entry.regions)
                    self.catalog.reclaim(entry)
                    freed += 1
                else:
                    remaining.append(entry)
            self._pending_reclaim = remaining
        return freed

    # -- §3.6 CXL pool eviction ---------------------------------------------------
    def collect_borrow_counters(self) -> Dict[str, int]:
        """Periodic collection; resets counters to build the ranked candidate
        list (temporal locality = recency of this window, frequency = count)."""
        out: Dict[str, int] = {}
        for entry in self.catalog.entries:
            if entry.regions is not None and entry.name:
                out[entry.name] = entry.borrow_counter.exchange(0)
        return out

    def evict_for(self, needed_bytes: int) -> List[str]:
        """Delete lowest-ranked snapshots until `needed_bytes` of CXL frees.

        Dedup snapshots are scored by their EXCLUSIVE footprint (metadata +
        pages no other live snapshot references): deleting a mostly-shared
        victim reclaims only its private region, and the ranking must not
        credit it with bytes its co-owners keep alive."""
        counters = self.collect_borrow_counters()
        ranked = sorted(counters.items(), key=lambda kv: kv[1])
        evicted: List[str] = []
        freed = 0
        for name, _count in ranked:
            if freed >= needed_bytes:
                break
            entry = self.catalog.find(name)
            if entry is None or entry.regions is None:
                continue
            r = entry.regions
            if r.dedup:
                # pin while decoding the stored offset array (same rule as
                # the capacity sweep: never read regions bytes unpinned)
                pin = self.catalog.borrow(name)
                if pin is not None and pin.regions is r:
                    try:
                        freed += r.cxl_size + exclusive_cxl_bytes(self.pool, r)
                    finally:
                        pin.release()
                else:
                    if pin is not None:
                        pin.release()
                    freed += r.cxl_size
            else:
                freed += r.cxl_size
            self.delete(name)
            evicted.append(name)
        return evicted

    # -- introspection ---------------------------------------------------------
    def capacity_report(self) -> Dict[str, int]:
        return {
            "cxl_in_use": self.pool.cxl.bytes_in_use,
            "cxl_capacity": self.pool.cxl.capacity,
            "rdma_in_use": self.pool.rdma.bytes_in_use,
            "rdma_capacity": self.pool.rdma.capacity,
        }
