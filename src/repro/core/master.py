"""Pool master: sole owner of pool-side snapshot storage (§3.1, §3.3, §3.6).

Responsibilities: publish / update / delete snapshots under the ownership
protocol, reclaim tombstoned regions once their refcount drains, and run the
borrow-counter based CXL eviction policy (§3.6).  Content-hash deduplication
(§3.6) is an optional layer applied at publish time.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .clock import Clock, REAL_CLOCK
from .coherence import STATE_TOMBSTONE, Catalog, CatalogEntry
from .pagestore import StateImage
from .pool import HierarchicalPool
from .snapshot import SnapshotRegions, build_snapshot, free_snapshot


class PoolMaster:
    def __init__(self, pool: HierarchicalPool, catalog: Optional[Catalog] = None,
                 clock: Optional[Clock] = None):
        self.pool = pool
        self.clock = clock or getattr(pool, "clock", None) or REAL_CLOCK
        self.catalog = catalog or Catalog(clock=self.clock)
        self._versions: Dict[str, int] = {}
        self._pending_reclaim: List[CatalogEntry] = []
        self._lock = threading.Lock()
        # Owner-op serialization (two concurrent tombstone→free→republish
        # sequences of one snapshot would double-free the old regions; two
        # concurrent first publishes of one name would leak an entry):
        #   _busy_names  — names with a publish in flight (claimed first)
        #   _owner_busy  — entry indices mid-update; gc() defers these
        self._busy_names: set = set()
        self._owner_busy: set = set()

    # -- snapshot lifecycle (§3.3 Owner protocol) -------------------------------
    def publish_steps(
        self,
        name: str,
        image: StateImage,
        working_set: Sequence[int],
        metadata: Optional[dict] = None,
        zero_bitmap: Optional[np.ndarray] = None,
        gather_fn=None,
        compress_cold: bool = False,
    ) -> Iterator[Tuple[str, object]]:
        """Generator form of :meth:`publish`, yielding at the owner protocol's
        phase boundaries so the deterministic simulator can interleave
        borrowers (and crash the owner) *between* phases.  Yields
        ``(label, value)``:

        * ``("owner_busy", name)``     — another publish of this name is in
          flight; the driver waits (sleep / timeout) and resumes to re-poll;
        * ``("built_new", regions)``   — new-name path, data written;
        * ``("tombstoned", entry)``    — update path, new borrows now fail;
        * ``("draining", entry)``      — refcount still nonzero; the driver
          decides how to wait (sleep / timeout) and resumes to re-poll;
        * ``("freed_old", entry)``     — old data regions returned to the pool;
        * ``("rebuilt", regions)``     — new data written, not yet visible;
        * ``("done", regions)``        — terminal: snapshot is PUBLISHED.
        """
        # claim the name BEFORE assigning a version or inspecting the catalog:
        # serialized publishes then get monotonic versions and concurrent
        # first-publishes of a new name cannot both take the create path
        while True:
            with self._lock:
                if name not in self._busy_names:
                    self._busy_names.add(name)
                    break
            yield ("owner_busy", name)
        existing = None
        try:
            with self._lock:
                version = self._versions.get(name, -1) + 1
                self._versions[name] = version
            existing = self.catalog.find(name)
            if existing is None:
                regions = build_snapshot(
                    self.pool, image, working_set, name,
                    version=version, metadata=metadata,
                    zero_bitmap=zero_bitmap, gather_fn=gather_fn,
                    compress_cold=compress_cold,
                )
                yield ("built_new", regions)
                self.catalog.publish_new(name, regions, version)
                yield ("done", regions)
                return
            # Update (§3.3): tombstone → wait for borrows to drain → rewrite
            # the data regions → republish.  Freeing before rebuilding lets
            # first-fit reuse the same pool addresses (the paper writes in
            # place), which is exactly why borrowers must clflushopt after a
            # successful borrow.
            old = existing.regions
            # A pending delete of this name is superseded by the update:
            # cancel its deferred reclaim BEFORE tombstoning (gc() skips
            # PUBLISHED entries), else a concurrent gc() during our drain
            # window would free the old regions a second time and reclaim
            # the entry mid-update.  Deletes issued *during* the drain are
            # handled by gc() deferring entries in _owner_busy.
            with self._lock:
                while existing in self._pending_reclaim:
                    self._pending_reclaim.remove(existing)
                self._owner_busy.add(existing.index)
            self.catalog.tombstone(name)
            yield ("tombstoned", existing)
            while existing.refcount.load() != 0:
                yield ("draining", existing)
            if old is not None:
                free_snapshot(self.pool, old)
                # drop the dangling reference NOW: if we crash (generator
                # close) or the rebuild raises before republish, a later
                # delete()+gc() must not free these bytes a second time
                existing.regions = None
            yield ("freed_old", existing)
            regions = build_snapshot(
                self.pool, image, working_set, name,
                version=version, metadata=metadata,
                zero_bitmap=zero_bitmap, gather_fn=gather_fn,
                compress_cold=compress_cold,
            )
            yield ("rebuilt", regions)
            self.catalog.republish(existing, regions, version)
            # a delete() that landed during our drain window is superseded by
            # this update (last writer wins): clear its pending reclaim, else
            # the now-PUBLISHED entry sits in _pending_reclaim forever
            with self._lock:
                while existing in self._pending_reclaim:
                    self._pending_reclaim.remove(existing)
        finally:
            # also runs on generator close (aborted/crashed owner), so a dead
            # update never wedges later publishes of the same name
            with self._lock:
                self._busy_names.discard(name)
                if existing is not None:
                    self._owner_busy.discard(existing.index)
        yield ("done", regions)

    def publish(
        self,
        name: str,
        image: StateImage,
        working_set: Sequence[int],
        metadata: Optional[dict] = None,
        zero_bitmap: Optional[np.ndarray] = None,
        gather_fn=None,
        compress_cold: bool = False,
        drain_timeout_s: float = 30.0,
    ) -> SnapshotRegions:
        """Blocking driver over :meth:`publish_steps` (production path)."""
        deadline: Optional[float] = None
        regions: Optional[SnapshotRegions] = None
        for label, value in self.publish_steps(
            name, image, working_set, metadata=metadata,
            zero_bitmap=zero_bitmap, gather_fn=gather_fn,
            compress_cold=compress_cold,
        ):
            if label in ("draining", "owner_busy"):
                if deadline is None:
                    deadline = self.clock.monotonic() + drain_timeout_s
                if self.clock.monotonic() > deadline:
                    raise TimeoutError(f"borrows of {name} did not drain")
                self.clock.sleep(1e-5)
            elif label == "done":
                regions = value
        assert regions is not None
        return regions

    def delete(self, name: str, gc_now: bool = True) -> bool:
        """Tombstone + schedule reclaim.  ``gc_now=False`` defers the reclaim
        to an explicit :meth:`gc` call (the simulator interleaves other hosts
        — and lease expiry — between the tombstone and the reclaim).

        Owner ops are last-writer-wins: a delete that lands while an update
        of the same name is draining is superseded by the update (the entry
        is republished and the pending reclaim cancelled)."""
        entry = self.catalog.tombstone(name)
        if entry is None:
            return False
        with self._lock:
            if entry not in self._pending_reclaim:
                self._pending_reclaim.append(entry)
        if gc_now:
            self.gc()
        return True

    def gc(self) -> int:
        """Reclaim tombstoned entries whose refcount has drained (§3.3)."""
        freed = 0
        with self._lock:
            remaining: List[CatalogEntry] = []
            for entry in self._pending_reclaim:
                if entry.index in self._owner_busy:
                    # an update owns this entry's transition (its drain window
                    # is transiently TOMBSTONE/refcount==0): reclaiming now
                    # would double-free the old regions under the updater
                    remaining.append(entry)
                    continue
                if entry.refcount.load() == 0 and entry.state.load() == STATE_TOMBSTONE:
                    # free what the entry holds NOW (a delete-time copy could
                    # be stale if an update swapped the regions in between)
                    if entry.regions is not None:
                        free_snapshot(self.pool, entry.regions)
                    self.catalog.reclaim(entry)
                    freed += 1
                else:
                    remaining.append(entry)
            self._pending_reclaim = remaining
        return freed

    # -- §3.6 CXL pool eviction ---------------------------------------------------
    def collect_borrow_counters(self) -> Dict[str, int]:
        """Periodic collection; resets counters to build the ranked candidate
        list (temporal locality = recency of this window, frequency = count)."""
        out: Dict[str, int] = {}
        for entry in self.catalog.entries:
            if entry.regions is not None and entry.name:
                out[entry.name] = entry.borrow_counter.exchange(0)
        return out

    def evict_for(self, needed_bytes: int) -> List[str]:
        """Delete lowest-ranked snapshots until `needed_bytes` of CXL frees."""
        counters = self.collect_borrow_counters()
        ranked = sorted(counters.items(), key=lambda kv: kv[1])
        evicted: List[str] = []
        freed = 0
        for name, _count in ranked:
            if freed >= needed_bytes:
                break
            entry = self.catalog.find(name)
            if entry is None or entry.regions is None:
                continue
            freed += entry.regions.cxl_size
            self.delete(name)
            evicted.append(name)
        return evicted

    # -- introspection ---------------------------------------------------------
    def capacity_report(self) -> Dict[str, int]:
        return {
            "cxl_in_use": self.pool.cxl.bytes_in_use,
            "cxl_capacity": self.pool.cxl.capacity,
            "rdma_in_use": self.pool.rdma.bytes_in_use,
            "rdma_capacity": self.pool.rdma.capacity,
        }
