"""Pool master: sole owner of pool-side snapshot storage (§3.1, §3.3, §3.6).

Responsibilities: publish / update / delete snapshots under the ownership
protocol, reclaim tombstoned regions once their refcount drains, and run the
borrow-counter based CXL eviction policy (§3.6).  Content-hash deduplication
(§3.6) is an optional layer applied at publish time.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .coherence import STATE_TOMBSTONE, Catalog, CatalogEntry
from .pagestore import StateImage
from .pool import HierarchicalPool
from .snapshot import SnapshotRegions, build_snapshot, free_snapshot


class PoolMaster:
    def __init__(self, pool: HierarchicalPool, catalog: Optional[Catalog] = None):
        self.pool = pool
        self.catalog = catalog or Catalog()
        self._versions: Dict[str, int] = {}
        self._pending_reclaim: List[CatalogEntry] = []
        self._pending_regions: Dict[int, SnapshotRegions] = {}
        self._lock = threading.Lock()

    # -- snapshot lifecycle (§3.3 Owner protocol) -------------------------------
    def publish(
        self,
        name: str,
        image: StateImage,
        working_set: Sequence[int],
        metadata: Optional[dict] = None,
        zero_bitmap: Optional[np.ndarray] = None,
        gather_fn=None,
        compress_cold: bool = False,
    ) -> SnapshotRegions:
        with self._lock:
            version = self._versions.get(name, -1) + 1
            self._versions[name] = version
        existing = self.catalog.find(name)
        if existing is None:
            regions = build_snapshot(
                self.pool, image, working_set, name,
                version=version, metadata=metadata,
                zero_bitmap=zero_bitmap, gather_fn=gather_fn,
                compress_cold=compress_cold,
            )
            self.catalog.publish_new(name, regions, version)
            return regions
        # Update (§3.3): tombstone → wait for borrows to drain → rewrite the
        # data regions → republish.  Freeing before rebuilding lets first-fit
        # reuse the same pool addresses (the paper writes in place), which is
        # exactly why borrowers must clflushopt after a successful borrow.
        old = existing.regions
        self.catalog.tombstone(name)
        if not self.catalog.wait_unborrowed(existing):
            raise TimeoutError(f"borrows of {name} did not drain")
        if old is not None:
            free_snapshot(self.pool, old)
        regions = build_snapshot(
            self.pool, image, working_set, name,
            version=version, metadata=metadata,
            zero_bitmap=zero_bitmap, gather_fn=gather_fn,
            compress_cold=compress_cold,
        )
        self.catalog.republish(existing, regions, version)
        return regions

    def delete(self, name: str) -> bool:
        entry = self.catalog.tombstone(name)
        if entry is None:
            return False
        with self._lock:
            self._pending_reclaim.append(entry)
            if entry.regions is not None:
                self._pending_regions[entry.index] = entry.regions
        self.gc()
        return True

    def gc(self) -> int:
        """Reclaim tombstoned entries whose refcount has drained (§3.3)."""
        freed = 0
        with self._lock:
            remaining: List[CatalogEntry] = []
            for entry in self._pending_reclaim:
                if entry.refcount.load() == 0 and entry.state.load() == STATE_TOMBSTONE:
                    regions = self._pending_regions.pop(entry.index, None)
                    if regions is not None:
                        free_snapshot(self.pool, regions)
                    self.catalog.reclaim(entry)
                    freed += 1
                else:
                    remaining.append(entry)
            self._pending_reclaim = remaining
        return freed

    # -- §3.6 CXL pool eviction ---------------------------------------------------
    def collect_borrow_counters(self) -> Dict[str, int]:
        """Periodic collection; resets counters to build the ranked candidate
        list (temporal locality = recency of this window, frequency = count)."""
        out: Dict[str, int] = {}
        for entry in self.catalog.entries:
            if entry.regions is not None and entry.name:
                out[entry.name] = entry.borrow_counter.exchange(0)
        return out

    def evict_for(self, needed_bytes: int) -> List[str]:
        """Delete lowest-ranked snapshots until `needed_bytes` of CXL frees."""
        counters = self.collect_borrow_counters()
        ranked = sorted(counters.items(), key=lambda kv: kv[1])
        evicted: List[str] = []
        freed = 0
        for name, _count in ranked:
            if freed >= needed_bytes:
                break
            entry = self.catalog.find(name)
            if entry is None or entry.regions is None:
                continue
            freed += entry.regions.cxl_size
            self.delete(name)
            evicted.append(name)
        return evicted

    # -- introspection ---------------------------------------------------------
    def capacity_report(self) -> Dict[str, int]:
        return {
            "cxl_in_use": self.pool.cxl.bytes_in_use,
            "cxl_capacity": self.pool.cxl.capacity,
            "rdma_in_use": self.pool.rdma.bytes_in_use,
            "rdma_capacity": self.pool.rdma.capacity,
        }
