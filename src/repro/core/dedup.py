"""Snapshot deduplication layer (§3.6 extension).

Serverless snapshots share runtime pages (interpreter, shared libraries); in
our analogue, snapshots of fine-tuned variants share base-model pages.  The
offset array can point anywhere in a tier, so dedup integrates at publish
time: pages are content-hashed (FNV-1a 64-bit — same function as the
``page_checksum`` Pallas kernel) and identical pages are stored once with a
reference count.

Restore-path consequence recorded by the cost model: a deduplicated snapshot
can no longer clflush one contiguous CXL extent; the orchestrator must walk
the offset array and flush per page (§3.6).
"""
from __future__ import annotations

import threading
from typing import Dict, Tuple

import numpy as np

from .pagestore import PAGE_SIZE
from .pool import MemoryTier

FNV_OFFSET = np.uint64(0xCBF29CE484222325)
FNV_PRIME = np.uint64(0x100000001B3)


def fnv1a_page(page: np.ndarray) -> int:
    """FNV-1a over a 4 KiB page, processed as u64 lanes (vector-friendly —
    this exact formulation is what kernels/page_checksum implements)."""
    lanes = page.view(np.uint64)
    h = FNV_OFFSET
    with np.errstate(over="ignore"):
        for lane in lanes:
            h = (h ^ lane) * FNV_PRIME
    return int(h)


def fnv1a_pages(pages_matrix: np.ndarray) -> np.ndarray:
    """Vectorized FNV-1a per page row. pages_matrix: uint8[N, PAGE_SIZE]."""
    lanes = pages_matrix.view(np.uint64).reshape(pages_matrix.shape[0], -1)
    h = np.full(pages_matrix.shape[0], FNV_OFFSET, dtype=np.uint64)
    with np.errstate(over="ignore"):
        for j in range(lanes.shape[1]):
            h = (h ^ lanes[:, j]) * FNV_PRIME
    return h


class DedupStore:
    """Content-addressed page store inside one tier, with refcounts."""

    def __init__(self, tier: MemoryTier):
        self.tier = tier
        self._by_hash: Dict[int, Tuple[int, int]] = {}  # hash -> (offset, refcount)
        self._lock = threading.Lock()
        self.stats = {"unique": 0, "dedup_hits": 0}

    def put(self, page: np.ndarray) -> int:
        """Store (or reuse) a page; returns its tier byte offset."""
        h = fnv1a_page(page)
        with self._lock:
            hit = self._by_hash.get(h)
            if hit is not None:
                off, rc = hit
                # hash collision guard: verify bytes
                if np.array_equal(self.tier.buf[off : off + PAGE_SIZE],
                                  page.view(np.uint8).reshape(-1)):
                    self._by_hash[h] = (off, rc + 1)
                    self.stats["dedup_hits"] += 1
                    return off
            off = self.tier.alloc(PAGE_SIZE)
            self.tier.write(off, page)
            self._by_hash[h] = (off, 1)
            self.stats["unique"] += 1
            return off

    def drop(self, page: np.ndarray) -> None:
        h = fnv1a_page(page)
        with self._lock:
            hit = self._by_hash.get(h)
            if hit is None:
                return
            off, rc = hit
            if rc <= 1:
                self.tier.free(off, PAGE_SIZE)
                del self._by_hash[h]
            else:
                self._by_hash[h] = (off, rc - 1)

    def dedup_ratio(self) -> float:
        total = self.stats["unique"] + self.stats["dedup_hits"]
        return self.stats["dedup_hits"] / total if total else 0.0
