"""Content-addressed snapshot page store (§3.6 extension).

Serverless snapshots share runtime pages (interpreter, shared libraries); in
our analogue, snapshots of fine-tuned variants share base-model pages.  The
offset array can point anywhere in a tier, so dedup integrates at publish
time: pages are content-hashed (vectorized FNV-1a 64-bit by default; the
``kernels/page_checksum`` Pallas op plugs in behind the same ``hash_fn``
signature) and identical pages are stored ONCE with a reference count.

Refcount protocol (the ownership protocol's extension, DESIGN.md §12):

* ``put_pages`` on publish/update/re-curation — one increment per catalog
  offset that will point at the page;
* ``release_offsets`` when an owner op retires an offset array (update's
  free-old phase, delete's gc, demotion's republish) — decrements only;
* the tier byte range is freed exactly when a page's refcount reaches zero.

A hash match NEVER shares a page on its own: the candidate page's bytes are
compared against the stored bytes first (hash collisions fall back to a
separate physical page in the same bucket).  ``hash_fn`` is an injectable
seam, so tests force collisions deliberately and the Pallas checksum kernel
can replace the numpy fold on the hashing hot path.

Restore-path consequence recorded by the cost model: a deduplicated snapshot
can no longer flush/read one contiguous CXL extent; readers walk the offset
array and coalesce only *adjacent* store offsets (§3.6,
``SnapshotReader.iter_hot_extents`` / ``iter_cold_extents``).

Invariant I6 (refcount conservation, checked every sim step): each store
refcount equals the number of live catalog offsets pointing at it — see
``repro.sim.invariants``.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from .pagestore import PAGE_SIZE
from .pool import MemoryTier

FNV_OFFSET = np.uint64(0xCBF29CE484222325)
FNV_PRIME = np.uint64(0x100000001B3)

# hash_fn(pages_matrix uint8[N, PAGE_SIZE]) -> integer ndarray[N]
HashFn = Callable[[np.ndarray], np.ndarray]


def fnv1a_page(page: np.ndarray) -> int:
    """FNV-1a over a 4 KiB page, processed as u64 lanes (vector-friendly —
    this exact formulation is what kernels/page_checksum implements)."""
    lanes = np.ascontiguousarray(page).view(np.uint64).reshape(-1)
    h = FNV_OFFSET
    with np.errstate(over="ignore"):
        for lane in lanes:
            h = (h ^ lane) * FNV_PRIME
    return int(h)


def fnv1a_pages(pages_matrix: np.ndarray) -> np.ndarray:
    """Vectorized FNV-1a per page row. pages_matrix: uint8[N, PAGE_SIZE]."""
    lanes = pages_matrix.view(np.uint64).reshape(pages_matrix.shape[0], -1)
    h = np.full(pages_matrix.shape[0], FNV_OFFSET, dtype=np.uint64)
    with np.errstate(over="ignore"):
        for j in range(lanes.shape[1]):
            h = (h ^ lanes[:, j]) * FNV_PRIME
    return h


def pallas_hash_fn(pages_matrix: np.ndarray) -> np.ndarray:
    """The TPU-shaped alternative: the ``page_checksum`` polynomial rolling
    hash (Pallas kernel on TPU, jnp oracle elsewhere), adapted to the
    ``HashFn`` signature.  Weaker (32-bit) than FNV-1a-64, which is fine —
    the store byte-verifies every hash match before sharing."""
    from ..kernels.page_checksum.ops import page_checksum

    return np.asarray(page_checksum(pages_matrix))


# Marker consumed by the fused publish path (core/snapshot.py): the fused
# sweep's checksum column IS this hash, so a store hashing with it can be
# handed the precomputed values (put_pages(..., hashes=...)) and skip its
# own streaming pass over the batch.
pallas_hash_fn.is_poly32 = True


class DedupStore:
    """Content-addressed, refcounted page store inside one tier.

    The store owns its pages' tier allocations: callers never ``tier.free``
    a deduped page directly — they :meth:`release` their reference and the
    store frees the byte range when the last reference drops.
    """

    def __init__(self, tier: MemoryTier, hash_fn: Optional[HashFn] = None):
        self.tier = tier
        tier.dedup_store = self   # checksum repair resolves store from tier
        self.hash_fn = hash_fn or fnv1a_pages
        # hash -> [offset, ...]: collisions coexist in one bucket, each
        # offset holding distinct bytes (verified before every share)
        self._buckets: Dict[int, List[int]] = {}
        self._refs: Dict[int, int] = {}          # offset -> refcount
        self._hash_of: Dict[int, int] = {}       # offset -> hash (for release)
        self._quarantined: set = set()           # offsets barred from sharing
        self._lock = threading.RLock()
        self.stats = {"unique": 0, "dedup_hits": 0, "collisions": 0,
                      "released": 0, "freed": 0, "quarantined": 0,
                      "rematerialized": 0}

    # -- internal (lock held) -------------------------------------------------
    def _match(self, h: int, page_row: np.ndarray) -> Optional[int]:
        """Offset of a stored page with hash `h` AND equal bytes, else None."""
        for off in self._buckets.get(h, ()):
            if np.array_equal(self.tier.buf[off : off + PAGE_SIZE], page_row):
                return off
        return None

    def _store_new(self, h: int, page_row: np.ndarray) -> int:
        off = self.tier.alloc(PAGE_SIZE)
        self.tier.write(off, page_row)
        bucket = self._buckets.setdefault(h, [])
        if bucket:
            self.stats["collisions"] += 1
        bucket.append(off)
        self._refs[off] = 1
        self._hash_of[off] = h
        self.stats["unique"] += 1
        return off

    # -- write side -----------------------------------------------------------
    def put_pages(self, pages_matrix: np.ndarray,
                  hashes: Optional[np.ndarray] = None) -> np.ndarray:
        """Store (or reference) every row; returns int64 tier byte offsets.

        Hashing is vectorized over the whole batch; per-row work is dict
        lookups plus a byte-compare only on hash match.  On a mid-batch
        tier ``AllocError`` the rows already referenced by THIS call are
        released again, so a failed put leaves the store unchanged.

        ``hashes`` MUST be this store's own ``hash_fn`` outputs for exactly
        these rows (the fused publish sweep precomputes them in the same
        pass that compacts the pages); passing foreign hashes would split
        identical content across buckets and silently disable sharing.
        """
        mat = np.ascontiguousarray(pages_matrix).view(np.uint8)
        mat = mat.reshape(-1, PAGE_SIZE)
        if mat.shape[0] == 0:
            return np.zeros(0, dtype=np.int64)
        if hashes is None:
            hashes = np.asarray(self.hash_fn(mat))
        else:
            hashes = np.asarray(hashes)
            assert hashes.shape[0] == mat.shape[0], \
                f"precomputed hashes: {hashes.shape[0]} != {mat.shape[0]} rows"
        offs = np.empty(mat.shape[0], dtype=np.int64)
        with self._lock:
            done = 0
            try:
                for i in range(mat.shape[0]):
                    h = int(hashes[i])
                    off = self._match(h, mat[i])
                    if off is not None:
                        self._refs[off] += 1
                        self.stats["dedup_hits"] += 1
                    else:
                        off = self._store_new(h, mat[i])
                    offs[i] = off
                    done = i + 1
            except Exception:
                for off in offs[:done]:
                    self._release_locked(int(off))
                raise
        return offs

    def put(self, page: np.ndarray) -> int:
        """Store (or reference) a single page; returns its tier byte offset."""
        return int(self.put_pages(page.reshape(1, -1))[0])

    def probe_new_bytes(self, pages_matrix: np.ndarray) -> int:
        """Tier bytes :meth:`put_pages` would NEWLY allocate for this batch —
        distinct page contents not already stored — without storing anything.
        The capacity manager admits dedup publishes on this marginal size."""
        mat = np.ascontiguousarray(pages_matrix).view(np.uint8)
        mat = mat.reshape(-1, PAGE_SIZE)
        if mat.shape[0] == 0:
            return 0
        hashes = np.asarray(self.hash_fn(mat))
        new_pages = 0
        batch_seen: Dict[int, List[int]] = {}   # hash -> row indices counted new
        with self._lock:
            for i in range(mat.shape[0]):
                h = int(hashes[i])
                if self._match(h, mat[i]) is not None:
                    continue
                dup_in_batch = any(np.array_equal(mat[j], mat[i])
                                   for j in batch_seen.get(h, ()))
                if not dup_in_batch:
                    batch_seen.setdefault(h, []).append(i)
                    new_pages += 1
        return new_pages * PAGE_SIZE

    # -- release side ---------------------------------------------------------
    def _release_locked(self, offset: int) -> None:
        rc = self._refs.get(offset)
        if rc is None:
            raise ValueError(f"release of unknown dedup offset {offset}")
        self.stats["released"] += 1
        if rc > 1:
            self._refs[offset] = rc - 1
            return
        h = self._hash_of.pop(offset)
        del self._refs[offset]
        bucket = self._buckets.get(h, [])
        if offset in bucket:          # a quarantined offset left its bucket
            bucket.remove(offset)
        if not bucket:
            self._buckets.pop(h, None)
        self._quarantined.discard(offset)
        self.tier.free(offset, PAGE_SIZE)
        self.stats["freed"] += 1

    def release(self, offset: int) -> None:
        """Drop one reference; frees the tier page at refcount zero."""
        with self._lock:
            self._release_locked(int(offset))

    def release_offsets(self, offsets: np.ndarray) -> None:
        """Batch :meth:`release` (an offset array being retired: each slot
        is one reference, so duplicates decrement once per occurrence)."""
        with self._lock:
            for off in np.asarray(offsets, dtype=np.int64):
                self._release_locked(int(off))

    def drop(self, page: np.ndarray) -> None:
        """Release one reference by CONTENT (hash + byte-match); unknown
        pages are ignored.  Offset-based :meth:`release` is the protocol
        path — this form serves callers that never kept the offset."""
        mat = np.ascontiguousarray(page).view(np.uint8).reshape(1, PAGE_SIZE)
        h = int(np.asarray(self.hash_fn(mat))[0])
        with self._lock:
            off = self._match(h, mat[0])
            if off is not None:
                self._release_locked(off)

    # -- checksum repair (DESIGN.md §15) --------------------------------------
    def quarantine(self, offset: int) -> bool:
        """Bar a suspect offset from NEW sharing: its hash-bucket entry is
        removed so no future publish matches it, while existing references
        stay (I6 refcount conservation is untouched — live offset arrays
        still point here and release normally).  Returns False for offsets
        the store does not own or that are already quarantined."""
        offset = int(offset)
        with self._lock:
            h = self._hash_of.get(offset)
            if h is None or offset in self._quarantined:
                return False
            self._quarantined.add(offset)
            bucket = self._buckets.get(h, [])
            if offset in bucket:
                bucket.remove(offset)
            if not bucket:
                self._buckets.pop(h, None)
            self.stats["quarantined"] += 1
            return True

    def rematerialize(self, offset: int, page_row: np.ndarray) -> None:
        """Scrub a quarantined offset with verified-clean bytes (the owner's
        ``reconstruct_image``-style re-read) and restore its bucket entry so
        the content is shareable again.  The bytes MUST hash to the offset's
        recorded hash — re-materializing different content would corrupt
        every snapshot referencing it."""
        offset = int(offset)
        mat = np.ascontiguousarray(page_row).view(np.uint8).reshape(1, PAGE_SIZE)
        h = int(np.asarray(self.hash_fn(mat))[0])
        with self._lock:
            if offset not in self._quarantined:
                raise ValueError(f"offset {offset} is not quarantined")
            if h != self._hash_of[offset]:
                raise ValueError(
                    f"rematerialize hash mismatch at offset {offset}: "
                    f"{h:#x} != recorded {self._hash_of[offset]:#x}")
            self.tier.write(offset, mat[0])
            self._quarantined.discard(offset)
            self._buckets.setdefault(h, []).append(offset)
            self.stats["rematerialized"] += 1

    def quarantined_offsets(self) -> List[int]:
        with self._lock:
            return sorted(self._quarantined)

    # -- introspection --------------------------------------------------------
    def refcounts(self) -> Dict[int, int]:
        """offset -> refcount snapshot (the I6 checker's ground truth)."""
        with self._lock:
            return dict(self._refs)

    def unique_pages(self) -> int:
        with self._lock:
            return len(self._refs)

    def unique_bytes(self) -> int:
        """Physical tier bytes currently owned by the store."""
        return self.unique_pages() * PAGE_SIZE

    def logical_pages(self) -> int:
        """Sum of refcounts == pages the catalog believes it stores."""
        with self._lock:
            return sum(self._refs.values())

    def dedup_ratio(self) -> float:
        total = self.stats["unique"] + self.stats["dedup_hits"]
        return self.stats["dedup_hits"] / total if total else 0.0

    def report(self) -> Dict[str, float]:
        with self._lock:
            unique = len(self._refs)
            logical = sum(self._refs.values())
        return {
            "unique_pages": unique,
            "logical_pages": logical,
            "unique_bytes": unique * PAGE_SIZE,
            "logical_bytes": logical * PAGE_SIZE,
            "dedup_ratio": self.dedup_ratio(),
            **self.stats,
        }
