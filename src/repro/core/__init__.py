"""Aquifer core: hierarchical CXL+RDMA memory pooling for state snapshots.

The paper's contribution as a composable library:

- :mod:`pagestore`  — paged flat address space over model/server state
- :mod:`pool`       — two-tier pool, cost models, incoherent host views
- :mod:`snapshot`   — hotness-based compact snapshot format (§3.2)
- :mod:`coherence`  — ownership-based coherence protocol (§3.3)
- :mod:`serving`    — copy-based page serving, async RDMA demand paging (§3.4)
- :mod:`profiler`   — offline hotness profiling + online TouchEvent
  telemetry with first-touch sequences (§3.2, DESIGN.md §17)
- :mod:`prefetch_model` — learned first-touch ordering: Markov model over
  page runs + the PrefetchPolicy seam (DESIGN.md §17)
- :mod:`master`     — pool master: publish/update/delete, eviction (§3.6)
- :mod:`nodeserver` — host-wide page-serving runtime: shared RDMA engine,
  cross-instance DRR prefetch + doorbell batching, hot-chunk fan-out (§3.5)
- :mod:`orchestrator` — node agent: borrow → flush → pre-install → resume
- :mod:`dedup`      — content-hash snapshot deduplication (§3.6)
- :mod:`faults`     — deterministic fault injection, retry policy, tier
  health circuit breakers (DESIGN.md §15)
"""
from .clock import Clock, RealClock, REAL_CLOCK
from .faults import (
    DEFAULT_RETRY_POLICY,
    FaultInjector,
    RetryPolicy,
    TierFaultError,
    TierHealth,
    call_with_retries,
)
from .pagestore import PAGE_SIZE, ArrayExtent, Manifest, StateImage, runs_from_pages
from .pool import (
    CXL_COST,
    RDMA_COST,
    TIER_CXL,
    TIER_RDMA,
    CostModel,
    CXLBudget,
    HierarchicalPool,
    HostView,
    LinkArbiter,
    MemoryTier,
    TimeLedger,
)
from .snapshot import (
    ZERO_SENTINEL,
    PageClasses,
    RecurationPlan,
    SnapshotReader,
    SnapshotRegions,
    build_snapshot,
    classify_pages,
    decode_dedup_offsets,
    decode_slot,
    encode_slot,
    estimate_snapshot_cxl_size,
    exclusive_cxl_bytes,
    free_snapshot,
    plan_recuration,
    reconstruct_image,
    runs_of_indices,
)
from .coherence import (
    STATE_FREE,
    STATE_PUBLISHED,
    STATE_TOMBSTONE,
    AtomicU64,
    Borrow,
    Catalog,
    CatalogEntry,
    LeaseFallback,
)
from .serving import (
    AsyncRDMAEngine,
    BufferPool,
    Instance,
    RestoreEngine,
    RestoreSession,
    mmap_install_cost,
)
from .profiler import (
    RUN_PAGES,
    START_RUN,
    AccessRecorder,
    HeatMap,
    HeatRegistry,
    TouchEvent,
    WorkloadProfile,
    profile_invocations,
)
from .prefetch_model import (
    LayoutOrderPolicy,
    PredictedOrderPolicy,
    PrefetchModel,
    PrefetchPolicy,
    fit_prefetch_model,
)
from .master import CXLCapacityManager, PoolMaster
from .nodeserver import FanoutGroup, HotChunkCache, NodePageServer
from .orchestrator import Orchestrator, RestoredInstance
from .dedup import DedupStore, fnv1a_page, fnv1a_pages, pallas_hash_fn

__all__ = [k for k in dir() if not k.startswith("_")]
