"""Hotness-based snapshot format (§3.2).

A snapshot of a paged ``StateImage`` is stored as:

* **offset array** — one ``uint64`` slot per guest page.
    - sentinel ``0xFFFF_FFFF_FFFF_FFFF`` → zero page (not stored at all);
    - top 2 bits → memory-backend tag (``TIER_CXL`` / ``TIER_RDMA``);
    - low 62 bits → byte offset of the page *within that tier's data region*.
* **hot data region** (CXL tier) — compacted content of hot pages.
* **cold data region** (RDMA tier) — compacted content of cold pages.
* **machine state** (CXL tier) — serialized manifest + metadata (the vCPU /
  devices analogue), needed to resume without touching the RDMA tier.

The offset array and machine state live in CXL next to the hot data, so the
restore index is reachable via load/store without RDMA round trips (§3.2).

CXL-region layout (all sections page-aligned):
    [ machine_state | offset_array | hot page data ]
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

from .faults import TierFaultError
from .pagestore import PAGE_SIZE, Manifest, StateImage, num_pages
from .pool import TIER_CXL, TIER_RDMA, HierarchicalPool, HostView, MemoryTier

ZERO_SENTINEL = np.uint64(0xFFFF_FFFF_FFFF_FFFF)
TIER_SHIFT = np.uint64(62)
OFFSET_MASK = np.uint64((1 << 62) - 1)


def encode_slot(tier: int, offset: int) -> np.uint64:
    return (np.uint64(tier) << TIER_SHIFT) | np.uint64(offset)


def decode_slot(slot: np.uint64) -> Tuple[int, int]:
    return int(slot >> TIER_SHIFT), int(slot & OFFSET_MASK)


def _align_pages(nbytes: int) -> int:
    return num_pages(nbytes) * PAGE_SIZE


def runs_of_indices(idx: np.ndarray) -> np.ndarray:
    """Vectorized run-length encoding of a sorted index array.

    Returns an ``int64 (R, 2)`` array of ``[start, length]`` rows covering
    exactly the input set.  This is the vectorized counterpart of
    :func:`repro.core.pagestore.runs_from_pages` (asserted equal in tests).
    """
    idx = np.asarray(idx, dtype=np.int64)
    if idx.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    brk = np.nonzero(np.diff(idx) != 1)[0]
    starts = np.concatenate([[0], brk + 1])
    ends = np.concatenate([brk, [idx.size - 1]])
    return np.stack([idx[starts], ends - starts + 1], axis=1)


def _offset_subruns(offsets: np.ndarray, max_run: Optional[int] = None):
    """Yield ``(start_index, length)`` over positions of ``offsets`` such
    that each run's byte offsets are PAGE_SIZE-adjacent (optionally capped
    at ``max_run`` elements) — the dedup extent-splitting primitive."""
    n = int(offsets.size)
    if n == 0:
        return
    brk = np.nonzero(np.diff(offsets) != PAGE_SIZE)[0]
    starts = np.concatenate([[0], brk + 1]).astype(np.int64)
    ends = np.concatenate([brk + 1, [n]]).astype(np.int64)
    for a, b in zip(starts, ends):
        a, b = int(a), int(b)
        if max_run is None:
            yield a, b - a
        else:
            for s in range(a, b, max_run):
                yield s, min(max_run, b - s)


def _offset_runs(sorted_offsets: np.ndarray):
    """Yield ``(byte_offset, n_pages)`` maximal adjacent runs of SORTED
    absolute page offsets (dedup flush/read coalescing)."""
    for a, k in _offset_subruns(sorted_offsets):
        yield int(sorted_offsets[a]), k


# --------------------------------------------------------------------------
# Page classification (§2.3.3 semantics)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PageClasses:
    """Partition of an image's pages into zero / hot / cold classes."""

    zero_bitmap: np.ndarray       # bool[total_pages]
    hot_pages: np.ndarray         # sorted int64 page indices (non-zero ∩ working set)
    cold_pages: np.ndarray        # sorted int64 page indices (non-zero ∖ working set)

    @property
    def n_zero(self) -> int:
        return int(self.zero_bitmap.sum())

    def summary(self) -> Dict[str, int]:
        return {
            "total": int(self.zero_bitmap.size),
            "zero": self.n_zero,
            "hot": int(self.hot_pages.size),
            "cold": int(self.cold_pages.size),
        }


def _ws_bool(image: StateImage, working_set: Sequence[int]) -> np.ndarray:
    ws = np.zeros(image.total_pages, dtype=bool)
    if len(working_set):
        ws[np.asarray(sorted(set(working_set)), dtype=np.int64)] = True
    return ws


def classify_pages(
    image: StateImage,
    working_set: Sequence[int],
    zero_bitmap: Optional[np.ndarray] = None,
) -> PageClasses:
    """Partition the image's pages into zero / hot / cold (§3.2).

    hot  = recorded working set, minus pages whose content is zero
    cold = non-zero pages not in the working set
    """
    if zero_bitmap is None:
        zero_bitmap = image.zero_page_bitmap()
    ws = _ws_bool(image, working_set)
    nonzero = ~zero_bitmap
    hot = np.nonzero(nonzero & ws)[0].astype(np.int64)
    cold = np.nonzero(nonzero & ~ws)[0].astype(np.int64)
    return PageClasses(zero_bitmap, hot, cold)


# --------------------------------------------------------------------------
# Stored snapshot
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SnapshotRegions:
    """Where one snapshot's sections live inside the pool tiers."""

    name: str
    version: int
    # CXL region
    cxl_off: int
    cxl_size: int
    ms_size: int                  # machine-state section bytes (aligned)
    oa_size: int                  # offset-array section bytes (aligned)
    hot_bytes: int                # hot data payload bytes
    # RDMA region
    rdma_off: int
    rdma_size: int
    cold_bytes: int
    total_pages: int
    n_hot: int
    n_cold: int
    n_zero: int
    # beyond-paper: zstd-compressed cold tier (Snapipeline/Sabre-inspired).
    # When set, cold offset-array slots hold the page RANK (not a byte
    # offset) and a uint32 per-cold-page length table lives in CXL after
    # the offset array (ci_size bytes, page-aligned).
    cold_compressed: bool = False
    ci_size: int = 0
    cold_raw_bytes: int = 0       # uncompressed cold payload (for ratio)
    # content-addressed layout (core/dedup.py): page payloads live in the
    # per-tier DedupStores and offset-array slots hold ABSOLUTE tier byte
    # offsets (refcounted, possibly shared across snapshots).  The private
    # CXL region then holds only machine state + offset array, and there is
    # no private RDMA region at all (rdma_size == 0).  Mutually exclusive
    # with cold_compressed.
    dedup: bool = False

    @property
    def ms_off(self) -> int:
        return self.cxl_off

    @property
    def oa_off(self) -> int:
        return self.cxl_off + self.ms_size

    @property
    def ci_off(self) -> int:
        return self.cxl_off + self.ms_size + self.oa_size

    @property
    def hot_off(self) -> int:
        return self.cxl_off + self.ms_size + self.oa_size + self.ci_size

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "SnapshotRegions":
        return SnapshotRegions(**d)


def _serialize_machine_state(manifest: Manifest, metadata: dict) -> bytes:
    blob = json.dumps({"manifest": manifest.to_dict(), "metadata": metadata}).encode()
    return len(blob).to_bytes(8, "little") + blob


def _deserialize_machine_state(raw: np.ndarray) -> Tuple[Manifest, dict]:
    n = int.from_bytes(raw[:8].tobytes(), "little")
    d = json.loads(raw[8 : 8 + n].tobytes().decode())
    return Manifest.from_dict(d["manifest"]), d["metadata"]


def _compress_cold(cold_pages: np.ndarray):
    """Per-page zstd: (blob, lengths uint32). Pages that don't shrink are
    stored raw with the high bit of their length set."""
    cctx = _zstd.ZstdCompressor(level=3)
    chunks: List[bytes] = []
    lengths = np.zeros(cold_pages.shape[0], dtype=np.uint32)
    RAW = np.uint32(0x8000_0000)
    for i in range(cold_pages.shape[0]):
        raw = cold_pages[i].tobytes()
        z = cctx.compress(raw)
        if len(z) < PAGE_SIZE:
            chunks.append(z)
            lengths[i] = len(z)
        else:
            chunks.append(raw)
            lengths[i] = RAW | PAGE_SIZE
    return b"".join(chunks), lengths


def _run_publish_fn(publish_fn, image: StateImage, working_set: Sequence[int]):
    """One fused sweep (kernels/snapshot_fuse) in place of the piecemeal
    zero-scan → hash → gather×2 pipeline: returns ``(classes, hot_mat u8,
    cold_mat u8, checksums uint32[total_pages])``.  The compacted matrices
    come out of the sweep in ascending page order — exactly ``mat[hot]`` /
    ``mat[cold]`` — so downstream layout logic is unchanged."""
    ws = _ws_bool(image, working_set)
    res = publish_fn(image.pages_matrix(), ws)
    zero_bitmap = np.asarray(res.zero_bitmap, dtype=bool)
    nonzero = ~zero_bitmap
    hot = np.nonzero(nonzero & ws)[0].astype(np.int64)
    cold = np.nonzero(nonzero & ~ws)[0].astype(np.int64)
    classes = PageClasses(zero_bitmap, hot, cold)
    return classes, res.hot, res.cold, np.asarray(res.checksums, np.uint32)


def build_snapshot(
    pool: HierarchicalPool,
    image: StateImage,
    working_set: Sequence[int],
    name: str,
    version: int = 0,
    metadata: Optional[dict] = None,
    zero_bitmap: Optional[np.ndarray] = None,
    gather_fn=None,
    compress_cold: bool = False,
    dedup: bool = False,
    publish_fn=None,
) -> SnapshotRegions:
    """Write one snapshot into the pool tiers; returns its region record.

    ``gather_fn(pages_matrix, page_indices) -> compact`` lets callers swap in
    the Pallas ``page_gather`` kernel; default is the numpy oracle.
    ``publish_fn(pages_matrix, ws_bool) -> FusedPublishResult`` goes further:
    the fused single-sweep kernel (``kernels/snapshot_fuse``) replaces the
    zero scan, the dedup hash AND both gathers in one pass; its per-page
    checksum column is recorded on the returned regions (in-memory
    ``page_checksums`` attribute, guest-page-indexed) so restores can verify
    installed pages against publish-time content.  When set it supersedes
    ``zero_bitmap``/``gather_fn``.
    ``compress_cold`` stores the RDMA tier zstd-compressed per page.
    ``dedup`` routes page payloads through the pool's content-addressed
    stores instead of private data regions (offset-array slots then hold
    refcounted absolute tier offsets); it disables ``compress_cold``.
    """
    if dedup:
        return _build_snapshot_dedup(pool, image, working_set, name,
                                     version=version, metadata=metadata,
                                     zero_bitmap=zero_bitmap,
                                     gather_fn=gather_fn,
                                     publish_fn=publish_fn)
    compress_cold = compress_cold and _zstd is not None
    checksums = None
    if publish_fn is not None:
        classes, hot_mat, cold_mat, checksums = _run_publish_fn(
            publish_fn, image, working_set)
        hot, cold = classes.hot_pages, classes.cold_pages
        hot_data = (hot_mat.reshape(-1).view(np.uint8)
                    if hot.size else np.zeros(0, np.uint8))
        cold_mat = (cold_mat if cold.size
                    else np.zeros((0, PAGE_SIZE), np.uint8))
    else:
        classes = classify_pages(image, working_set, zero_bitmap)
        hot, cold = classes.hot_pages, classes.cold_pages
        gather = gather_fn or (lambda mat, idx: mat[idx])
        mat = image.pages_matrix()
        hot_data = (gather(mat, hot).reshape(-1).view(np.uint8)
                    if hot.size else np.zeros(0, np.uint8))
        cold_mat = (np.asarray(gather(mat, cold))
                    if cold.size else np.zeros((0, PAGE_SIZE), np.uint8))
    cold_raw_bytes = cold_mat.size

    ci = np.zeros(0, dtype=np.uint32)
    if compress_cold and cold.size:
        blob, ci = _compress_cold(cold_mat)
        cold_data = np.frombuffer(blob, dtype=np.uint8)
    else:
        compress_cold = False
        cold_data = cold_mat.reshape(-1).view(np.uint8) if cold.size else np.zeros(0, np.uint8)

    # Offset array: slot per guest page (cold slots: byte offset, or rank
    # when the cold tier is compressed).
    oa = np.full(image.total_pages, ZERO_SENTINEL, dtype=np.uint64)
    if hot.size:
        oa[hot] = (np.uint64(TIER_CXL) << TIER_SHIFT) | (
            np.arange(hot.size, dtype=np.uint64) * np.uint64(PAGE_SIZE)
        )
    if cold.size:
        stride = np.uint64(1) if compress_cold else np.uint64(PAGE_SIZE)
        oa[cold] = (np.uint64(TIER_RDMA) << TIER_SHIFT) | (
            np.arange(cold.size, dtype=np.uint64) * stride
        )

    ms = _serialize_machine_state(image.manifest, metadata or {})
    ms_size = _align_pages(len(ms))
    oa_size = _align_pages(oa.nbytes)
    ci_size = _align_pages(ci.nbytes) if compress_cold else 0
    hot_size = _align_pages(hot_data.nbytes) if hot_data.nbytes else 0
    cxl_size = ms_size + oa_size + ci_size + hot_size
    cold_size = _align_pages(cold_data.nbytes) if cold_data.nbytes else 0

    cxl_off = pool.cxl.alloc(cxl_size)
    try:
        rdma_off = pool.rdma.alloc(max(cold_size, PAGE_SIZE))
    except Exception:
        # don't leak the CXL region when the cold alloc fails — callers
        # (e.g. the capacity manager's degrade path) may catch and rebuild
        pool.cxl.free(cxl_off, cxl_size)
        raise

    regions = SnapshotRegions(
        name=name, version=version,
        cxl_off=cxl_off, cxl_size=cxl_size,
        ms_size=ms_size, oa_size=oa_size, hot_bytes=hot_data.nbytes,
        rdma_off=rdma_off, rdma_size=max(cold_size, PAGE_SIZE),
        cold_bytes=cold_data.nbytes,
        total_pages=image.total_pages,
        n_hot=int(hot.size), n_cold=int(cold.size), n_zero=classes.n_zero,
        cold_compressed=compress_cold, ci_size=ci_size,
        cold_raw_bytes=int(cold_raw_bytes),
    )

    pool.cxl.write(regions.ms_off, np.frombuffer(ms, dtype=np.uint8))
    pool.cxl.write(regions.oa_off, oa.view(np.uint8))
    if compress_cold and ci.size:
        pool.cxl.write(regions.ci_off, ci.view(np.uint8))
    if hot_data.nbytes:
        pool.cxl.write(regions.hot_off, hot_data)
    if cold_data.nbytes:
        pool.rdma.write(rdma_off, cold_data)
    if checksums is not None:
        # advisory in-memory integrity record (NOT serialized — to_dict /
        # from_dict round-trips drop it): restores holding the same regions
        # object verify installed pages against publish-time content
        regions.page_checksums = checksums
    return regions


def _build_snapshot_dedup(
    pool: HierarchicalPool,
    image: StateImage,
    working_set: Sequence[int],
    name: str,
    version: int = 0,
    metadata: Optional[dict] = None,
    zero_bitmap: Optional[np.ndarray] = None,
    gather_fn=None,
    publish_fn=None,
) -> SnapshotRegions:
    """Content-addressed build: page payloads go through the per-tier
    DedupStores (one refcount per offset-array slot); only machine state and
    the offset array occupy a private, contiguous CXL region.  A mid-build
    ``AllocError`` rolls every reference taken by this build back, so a
    failed publish leaves both stores and the tiers unchanged.

    With ``publish_fn`` the fused sweep's checksum column feeds the stores
    through the ``hash_fn`` seam: when a store's hash_fn is the polynomial
    checksum (``is_poly32``), ``put_pages`` receives the precomputed hashes
    and skips its own hashing pass entirely."""
    checksums = None
    if publish_fn is not None:
        classes, hot_mat, cold_mat, checksums = _run_publish_fn(
            publish_fn, image, working_set)
        hot, cold = classes.hot_pages, classes.cold_pages
    else:
        classes = classify_pages(image, working_set, zero_bitmap)
        hot, cold = classes.hot_pages, classes.cold_pages
        gather = gather_fn or (lambda mat, idx: mat[idx])
        mat = image.pages_matrix()
        hot_mat = (np.asarray(gather(mat, hot)).view(np.uint8).reshape(-1, PAGE_SIZE)
                   if hot.size else np.zeros((0, PAGE_SIZE), np.uint8))
        cold_mat = (np.asarray(gather(mat, cold)).view(np.uint8).reshape(-1, PAGE_SIZE)
                    if cold.size else np.zeros((0, PAGE_SIZE), np.uint8))

    ms = _serialize_machine_state(image.manifest, metadata or {})
    ms_size = _align_pages(len(ms))
    oa_size = _align_pages(image.total_pages * 8)
    cxl_size = ms_size + oa_size

    def _hashes_for(store, idx):
        """Fused checksums reused as the store's hash input — only when the
        store itself hashes with the same 32-bit polynomial checksum."""
        if checksums is None or not getattr(store.hash_fn, "is_poly32", False):
            return None
        return checksums[idx]

    cxl_off = pool.cxl.alloc(cxl_size)
    hot_offs = np.zeros(0, dtype=np.int64)
    try:
        hot_offs = pool.dedup_cxl.put_pages(
            hot_mat, hashes=_hashes_for(pool.dedup_cxl, hot))
        cold_offs = pool.dedup_rdma.put_pages(
            cold_mat, hashes=_hashes_for(pool.dedup_rdma, cold))
    except Exception:
        if hot_offs.size:
            pool.dedup_cxl.release_offsets(hot_offs)
        pool.cxl.free(cxl_off, cxl_size)
        raise

    oa = np.full(image.total_pages, ZERO_SENTINEL, dtype=np.uint64)
    if hot.size:
        oa[hot] = (np.uint64(TIER_CXL) << TIER_SHIFT) | hot_offs.astype(np.uint64)
    if cold.size:
        oa[cold] = (np.uint64(TIER_RDMA) << TIER_SHIFT) | cold_offs.astype(np.uint64)

    regions = SnapshotRegions(
        name=name, version=version,
        cxl_off=cxl_off, cxl_size=cxl_size,
        ms_size=ms_size, oa_size=oa_size,
        hot_bytes=int(hot.size) * PAGE_SIZE,
        rdma_off=0, rdma_size=0,
        cold_bytes=int(cold.size) * PAGE_SIZE,
        total_pages=image.total_pages,
        n_hot=int(hot.size), n_cold=int(cold.size), n_zero=classes.n_zero,
        cold_raw_bytes=int(cold.size) * PAGE_SIZE,
        dedup=True,
    )
    pool.cxl.write(regions.ms_off, np.frombuffer(ms, dtype=np.uint8))
    pool.cxl.write(regions.oa_off, oa.view(np.uint8))
    if checksums is not None:
        regions.page_checksums = checksums
    return regions


def decode_dedup_offsets(pool: HierarchicalPool, regions: SnapshotRegions,
                         tier_tag: int) -> np.ndarray:
    """Absolute store offsets a dedup snapshot's offset array holds for one
    tier (owner-side direct read of the stored offset array)."""
    oa = pool.cxl.read(regions.oa_off, regions.total_pages * 8).view(np.uint64)
    nonzero = oa != ZERO_SENTINEL
    sel = nonzero & ((oa >> TIER_SHIFT) == np.uint64(tier_tag))
    return (oa[sel] & OFFSET_MASK).astype(np.int64)


def free_snapshot(pool: HierarchicalPool, regions: SnapshotRegions) -> None:
    """Return a snapshot's storage.  For dedup snapshots this DECREMENTS the
    per-page references (one per offset-array slot); the stores free tier
    bytes only for pages whose last reference this was."""
    if regions.dedup:
        # read the offset array BEFORE freeing the metadata region that
        # holds it — it is the authoritative list of held references
        pool.dedup_cxl.release_offsets(
            decode_dedup_offsets(pool, regions, TIER_CXL))
        pool.dedup_rdma.release_offsets(
            decode_dedup_offsets(pool, regions, TIER_RDMA))
        pool.cxl.free(regions.cxl_off, regions.cxl_size)
        return
    pool.cxl.free(regions.cxl_off, regions.cxl_size)
    pool.rdma.free(regions.rdma_off, regions.rdma_size)


def exclusive_cxl_bytes(pool: HierarchicalPool, regions: SnapshotRegions) -> int:
    """CXL bytes demoting/deleting this snapshot's hot set would actually
    reclaim.  For a private layout that is the whole hot section; for a
    dedup layout only pages whose store refcount equals THIS snapshot's own
    reference count free on release — a mostly-shared snapshot reclaims
    ~nothing, and the eviction clock (master.CXLCapacityManager) skips it."""
    if not regions.dedup:
        return regions.cxl_size - regions.ms_size - regions.oa_size - regions.ci_size
    offs = decode_dedup_offsets(pool, regions, TIER_CXL)
    if offs.size == 0:
        return 0
    refs = pool.dedup_cxl.refcounts()
    uniq, counts = np.unique(offs, return_counts=True)
    exclusive = sum(1 for off, mine in zip(uniq, counts)
                    if refs.get(int(off), 0) == int(mine))
    return exclusive * PAGE_SIZE


def estimate_snapshot_cxl_size(
    image: StateImage,
    working_set: Sequence[int],
    zero_bitmap: Optional[np.ndarray] = None,
    metadata: Optional[dict] = None,
    compress_cold: bool = False,
    dedup: bool = False,
    pool: Optional[HierarchicalPool] = None,
) -> int:
    """CXL bytes :func:`build_snapshot` would allocate for this publish —
    machine state + offset array + cold-length index (compressed cold
    tier) + hot data — WITHOUT building anything.  The capacity manager
    admits/degrades on this estimate before the build; it must match the
    build's own arithmetic exactly (asserted in tests).

    With ``dedup`` (requires ``pool``) the hot-data term is the MARGINAL
    size: only page contents the CXL store does not already hold count,
    so a variant snapshot sharing a published base admits almost for free.
    """
    compress_cold = compress_cold and _zstd is not None and not dedup
    classes = classify_pages(image, working_set, zero_bitmap)
    ms = _serialize_machine_state(image.manifest, metadata or {})
    ms_size = _align_pages(len(ms))
    oa_size = _align_pages(image.total_pages * 8)
    if dedup:
        assert pool is not None, "dedup estimate needs the pool's stores"
        hot = classes.hot_pages
        hot_new = (pool.dedup_cxl.probe_new_bytes(
            image.pages_matrix()[hot]) if hot.size else 0)
        return ms_size + oa_size + hot_new
    ci_size = (_align_pages(int(classes.cold_pages.size) * 4)
               if compress_cold and classes.cold_pages.size else 0)
    hot_size = (_align_pages(int(classes.hot_pages.size) * PAGE_SIZE)
                if classes.hot_pages.size else 0)
    return ms_size + oa_size + ci_size + hot_size


def reconstruct_image(pool: HierarchicalPool, regions: SnapshotRegions) -> StateImage:
    """Owner-side full materialization of a stored snapshot.

    Reads the tiers directly (the owner wrote these bytes; no incoherent
    HostView cache in the path) and reassembles the exact ``StateImage`` the
    snapshot was built from: hot pages from the CXL data region, cold pages
    from RDMA (decompressed when the cold tier is zstd'd), zero pages left
    zero.  Re-curation rebuilds snapshots from this image, so restores of
    the re-curated version stay bit-identical to the original publish.
    """
    ms_raw = pool.cxl.read(regions.ms_off, regions.ms_size)
    manifest, _meta = _deserialize_machine_state(ms_raw)
    oa = pool.cxl.read(regions.oa_off, regions.total_pages * 8).view(np.uint64)
    image = StateImage.empty_like(manifest)
    mat = image.pages_matrix()
    nonzero = oa != ZERO_SENTINEL
    tiers = (oa >> TIER_SHIFT).astype(np.int64)
    offs = (oa & OFFSET_MASK).astype(np.int64)
    hot = np.nonzero(nonzero & (tiers == TIER_CXL))[0]
    cold = np.nonzero(nonzero & (tiers == TIER_RDMA))[0]
    if regions.dedup:
        # content-addressed layout: slots hold absolute tier offsets (pages
        # may be shared, non-contiguous) — coalesce adjacent store offsets
        # so each maximal run costs one tier read (the demotion/re-curation
        # path materializes whole snapshots through here)
        for pages_sel, tier in ((hot, pool.cxl), (cold, pool.rdma)):
            if not pages_sel.size:
                continue
            po = offs[pages_sel]
            order = np.argsort(po, kind="stable")
            pages_o, offs_o = pages_sel[order], po[order]
            for a, k in _offset_subruns(offs_o):
                raw = tier.read(int(offs_o[a]), k * PAGE_SIZE)
                mat[pages_o[a : a + k]] = raw.reshape(k, PAGE_SIZE)
        return image
    if hot.size:
        # hot data is rank-compacted: ranks are ordered by guest page index
        raw = pool.cxl.read(regions.hot_off, int(hot.size) * PAGE_SIZE)
        mat[hot] = raw.reshape(int(hot.size), PAGE_SIZE)
    if cold.size:
        if regions.cold_compressed:
            ci = pool.cxl.read(regions.ci_off, regions.n_cold * 4).view(np.uint32)
            lens = (ci & np.uint32(0x7FFF_FFFF)).astype(np.int64)
            starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
            dctx = _zstd.ZstdDecompressor()
            for p in cold:
                rank = int(offs[p])
                payload = pool.rdma.read(regions.rdma_off + int(starts[rank]),
                                         int(lens[rank]))
                if ci[rank] & np.uint32(0x8000_0000):
                    mat[p] = payload[:PAGE_SIZE]
                else:
                    out = dctx.decompress(payload.tobytes(),
                                          max_output_size=PAGE_SIZE)
                    mat[p] = np.frombuffer(out, dtype=np.uint8)
        else:
            raw = pool.rdma.read(regions.rdma_off, int(cold.size) * PAGE_SIZE)
            mat[cold] = raw.reshape(int(cold.size), PAGE_SIZE)
    return image


# --------------------------------------------------------------------------
# Online re-curation (heat-feedback snapshot rebuild)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RecurationPlan:
    """What a heat-driven rebuild of one snapshot would change.

    ``promote`` — currently-cold pages whose decayed heat says they belong
    in the CXL hot region; ``demote`` — currently-hot pages never touched
    across enough restores; ``new_working_set`` — the hot set the rebuilt
    snapshot will pre-install.
    """

    name: str
    version: int
    promote: np.ndarray
    demote: np.ndarray
    new_working_set: np.ndarray
    n_hot_before: int
    n_hot_after: int
    # promote set ordered by the predicted-first-touch model (DESIGN.md §17)
    model_ordered: bool = False

    @property
    def changed(self) -> bool:
        return bool(self.promote.size or self.demote.size)

    def summary(self) -> Dict[str, int]:
        return {
            "promote": int(self.promote.size),
            "demote": int(self.demote.size),
            "hot_before": self.n_hot_before,
            "hot_after": self.n_hot_after,
            "model_ordered": int(self.model_ordered),
        }


def plan_recuration(
    pool: HierarchicalPool,
    regions: SnapshotRegions,
    heat,
    min_promote_heat: float = 1.0,
    demote_max_heat: float = 1e-3,
    min_restores: int = 2,
    model=None,
    max_promote: Optional[int] = None,
) -> RecurationPlan:
    """Derive promote/demote sets for one snapshot from its heat map.

    Owner-side: the offset array is read directly from the tier (the owner
    wrote it; no HostView cache in the path).  ``heat`` is the snapshot's
    :class:`~repro.core.profiler.HeatMap`.

    ``model`` (a :class:`~repro.core.prefetch_model.PrefetchModel`, usually
    fitted from the same heat map) re-ranks the promote set by predicted
    first-touch order so the rebuilt hot set tracks *observed touch order*,
    not just decayed heat — under a ``max_promote`` budget the model decides
    which drifted pages make the cut (earliest-touched first).
    """
    oa = pool.cxl.read(regions.oa_off, regions.total_pages * 8).view(np.uint64)
    nonzero = oa != ZERO_SENTINEL
    tiers = oa >> TIER_SHIFT
    hot = np.nonzero(nonzero & (tiers == np.uint64(TIER_CXL)))[0].astype(np.int64)
    cold = np.nonzero(nonzero & (tiers == np.uint64(TIER_RDMA)))[0].astype(np.int64)
    promote = heat.promotion_candidates(cold, min_heat=min_promote_heat)
    model_ordered = False
    if model is not None and promote.size:
        promote = model.page_order(promote)
        model_ordered = True
    if max_promote is not None:
        promote = promote[:int(max_promote)]
    demote = heat.demotion_candidates(hot, max_heat=demote_max_heat,
                                      min_restores=min_restores)
    keep = hot[~np.isin(hot, demote)] if demote.size else hot
    new_ws = np.union1d(keep, promote).astype(np.int64)
    return RecurationPlan(
        name=regions.name, version=regions.version,
        promote=promote, demote=demote, new_working_set=new_ws,
        n_hot_before=int(hot.size), n_hot_after=int(new_ws.size),
        model_ordered=model_ordered,
    )


class SnapshotReader:
    """Borrower-side reader over a published snapshot (read-only!).

    CXL sections are read through the host's (incoherent) ``HostView``; the
    caller must have run the borrow protocol, which invalidates the relevant
    cache lines first (§3.3).  RDMA reads go to the tier directly (one-sided
    reads are uncached).
    """

    def __init__(self, regions: SnapshotRegions, cxl_view: HostView, rdma: MemoryTier):
        self.regions = regions
        self.view = cxl_view
        self.rdma = rdma
        self._oa: Optional[np.ndarray] = None
        self._manifest: Optional[Manifest] = None
        self._metadata: Optional[dict] = None
        self._ci: Optional[np.ndarray] = None       # cold lengths (compressed tier)
        self._ci_starts: Optional[np.ndarray] = None
        self._dctx = _zstd.ZstdDecompressor() if _zstd is not None else None
        self._hot_runs: Optional[np.ndarray] = None
        self._cold_runs: Optional[np.ndarray] = None
        self._zero_runs: Optional[np.ndarray] = None

    def page_checksums(self) -> Optional[np.ndarray]:
        """Publish-time per-page checksum table (guest-page-indexed uint32)
        when the snapshot was built through the fused publish sweep; None
        otherwise.  Advisory and in-memory only — a rehydrated regions
        record (from_dict) has no table and restores skip verification."""
        cs = getattr(self.regions, "page_checksums", None)
        return None if cs is None else np.asarray(cs, dtype=np.uint32)

    # -- resilient CXL access (DESIGN.md §15) --------------------------------
    def cxl_health(self):
        """The CXL tier's circuit breaker (None for a bare MemoryTier)."""
        return getattr(self.view.tier, "health", None)

    def degraded_cxl_read(self, off: int, nbytes: int) -> np.ndarray:
        """Serve CXL-resident bytes while the host's CXL link is browned
        out: the pool ships the same bytes over the RDMA transport (a
        one-sided read of the MHD region), so the restore completes
        bit-identically at the all-cold cost instead of failing.  The
        HostView line cache is bypassed — nothing crossed the CXL link."""
        data = self.view.tier.buf[off : off + nbytes].copy()
        arb = self.rdma.arbiter_for(self.view.host)
        self.view.ledger.add("rdma_read", arb.charge(nbytes))
        self.view.stats["degraded_reads"] = (
            self.view.stats.get("degraded_reads", 0) + 1)
        return data

    def cxl_read(self, off: int, nbytes: int) -> np.ndarray:
        """A HostView read that survives link faults: transient faults are
        surfaced to the caller's retry policy, but once the breaker is OPEN
        (brownout, or repeated failures) the read degrades to
        :meth:`degraded_cxl_read` instead of failing the restore."""
        ht = self.cxl_health()
        if ht is not None and not ht.allow():
            return self.degraded_cxl_read(off, nbytes)
        try:
            data = self.view.read(off, nbytes)
        except TierFaultError as e:
            if ht is None:
                raise
            ht.record_failure(hard=(e.kind == "brownout"))
            if not ht.allow():
                return self.degraded_cxl_read(off, nbytes)
            raise
        if ht is not None:
            ht.record_success()
        return data

    # -- protocol hook ------------------------------------------------------
    def invalidate_cxl(self) -> None:
        """clflushopt over machine state + offset array + hot data (§3.3).

        A dedup snapshot has no contiguous hot section: the metadata region
        is flushed first, then the (now-fresh) offset array is decoded and
        each maximal run of ADJACENT store offsets flushed separately —
        the per-page flush path §3.6 charges dedup for."""
        r = self.regions
        if not r.dedup:
            self.view.invalidate(r.cxl_off, r.ms_size + r.oa_size + max(r.hot_bytes, 0))
            return
        self.view.invalidate(r.cxl_off, r.ms_size + r.oa_size)
        oa = self.offset_array()
        sel = (oa != ZERO_SENTINEL) & ((oa >> TIER_SHIFT) == np.uint64(TIER_CXL))
        offs = np.sort((oa[sel] & OFFSET_MASK).astype(np.int64))
        for off, n in _offset_runs(offs):
            self.view.invalidate(int(off), int(n) * PAGE_SIZE)

    # -- index + machine state ----------------------------------------------
    def machine_state(self) -> Tuple[Manifest, dict]:
        if self._manifest is None:
            raw = self.cxl_read(self.regions.ms_off, self.regions.ms_size)
            self._manifest, self._metadata = _deserialize_machine_state(raw)
        return self._manifest, self._metadata

    def offset_array(self) -> np.ndarray:
        if self._oa is None:
            raw = self.cxl_read(self.regions.oa_off, self.regions.total_pages * 8)
            self._oa = raw.view(np.uint64)
        return self._oa

    def cold_index(self):
        """(starts, lengths) for the compressed cold tier (cached)."""
        if self._ci is None:
            raw = self.cxl_read(self.regions.ci_off, self.regions.n_cold * 4)
            self._ci = raw.view(np.uint32)
            lens = (self._ci & np.uint32(0x7FFF_FFFF)).astype(np.int64)
            self._ci_starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
        return self._ci_starts, self._ci

    # -- page lookup ----------------------------------------------------------
    def lookup(self, page: int) -> Tuple[str, int]:
        """-> ("zero", 0) | ("cxl", pool_byte_offset) | ("rdma", pool_byte_offset)
        | ("rdma_z", cold_rank) when the cold tier is compressed.  Dedup
        slots already hold absolute tier offsets (no region base to add)."""
        slot = self.offset_array()[page]
        if slot == ZERO_SENTINEL:
            return "zero", 0
        tier, off = decode_slot(slot)
        if self.regions.dedup:
            return ("cxl" if tier == TIER_CXL else "rdma"), off
        if tier == TIER_CXL:
            return "cxl", self.regions.hot_off + off
        if self.regions.cold_compressed:
            return "rdma_z", off          # off == cold rank
        return "rdma", self.regions.rdma_off + off

    def cold_extent(self, rank: int) -> Tuple[int, int, bool]:
        """-> (pool_byte_offset, length, is_raw) for compressed cold page."""
        starts, lens = self.cold_index()
        raw = bool(lens[rank] & np.uint32(0x8000_0000))
        n = int(lens[rank] & np.uint32(0x7FFF_FFFF))
        return self.regions.rdma_off + int(starts[rank]), n, raw

    def decompress_page(self, payload: np.ndarray, is_raw: bool) -> np.ndarray:
        if is_raw:
            return payload[:PAGE_SIZE]
        out = self._dctx.decompress(payload.tobytes(), max_output_size=PAGE_SIZE)
        return np.frombuffer(out, dtype=np.uint8)

    def read_page(self, page: int) -> np.ndarray:
        kind, off = self.lookup(page)
        if kind == "zero":
            return np.zeros(PAGE_SIZE, np.uint8)
        if kind == "cxl":
            return self.cxl_read(off, PAGE_SIZE)
        if kind == "rdma_z":
            pool_off, n, raw = self.cold_extent(off)
            return self.decompress_page(self.rdma.read(pool_off, n), raw)
        return self.rdma.read(off, PAGE_SIZE)

    def hot_page_indices(self) -> np.ndarray:
        oa = self.offset_array()
        return np.nonzero((oa != ZERO_SENTINEL) & ((oa >> TIER_SHIFT) == TIER_CXL))[0]

    def cold_page_indices(self) -> np.ndarray:
        oa = self.offset_array()
        return np.nonzero((oa != ZERO_SENTINEL) & ((oa >> TIER_SHIFT) == TIER_RDMA))[0]

    def zero_page_indices(self) -> np.ndarray:
        return np.nonzero(self.offset_array() == ZERO_SENTINEL)[0]

    # -- run index (batched serving, §3.4) -----------------------------------
    # build_snapshot assigns tier offsets rank-by-rank over the *sorted* page
    # set, so guest-contiguous pages of one class are also contiguous in their
    # tier's data region (byte offsets for raw tiers, ranks for the compressed
    # cold tier).  A run can therefore be served by ONE tier read.

    def hot_runs(self) -> np.ndarray:
        """int64 (R, 2) [start_page, n_pages] runs of the hot set (cached)."""
        if self._hot_runs is None:
            self._hot_runs = runs_of_indices(self.hot_page_indices())
        return self._hot_runs

    def cold_runs(self) -> np.ndarray:
        """int64 (R, 2) [start_page, n_pages] runs of the cold set (cached)."""
        if self._cold_runs is None:
            self._cold_runs = runs_of_indices(self.cold_page_indices())
        return self._cold_runs

    def zero_runs(self) -> np.ndarray:
        """int64 (R, 2) [start_page, n_pages] runs of zero pages (cached)."""
        if self._zero_runs is None:
            self._zero_runs = runs_of_indices(self.zero_page_indices())
        return self._zero_runs

    def iter_cold_extents(self, max_extent_pages: int = 64,
                          largest_first: bool = True):
        """Yield ``(es, en, rank0, pool_off, nbytes)`` extents covering the
        cold runs (largest-first by default), each readable with ONE
        one-sided read.  This is THE extent-splitting arithmetic: the
        per-instance prefetcher, the node server's pump, and the analytic
        restore model all consume it, so they can never drift apart.

        Dedup snapshots additionally split each guest run wherever the
        stored tier offsets stop being adjacent (shared pages can point
        anywhere), so every yielded extent is contiguous in BOTH the guest
        address space and the tier — the invariant the scatter paths rely
        on."""
        runs = self.cold_runs()
        if runs.size == 0:
            return
        dedup = self.regions.dedup
        oa = self.offset_array() if dedup else None
        order = (np.argsort(-runs[:, 1], kind="stable") if largest_first
                 else range(runs.shape[0]))
        for ri in order:
            start, n = int(runs[ri, 0]), int(runs[ri, 1])
            for es in range(start, start + n, max_extent_pages):
                en = min(max_extent_pages, start + n - es)
                if not dedup:
                    rank0 = self.cold_rank(es)
                    pool_off, nbytes = self.cold_extent_span(rank0, en)
                    yield es, en, rank0, pool_off, nbytes
                    continue
                offs = (oa[es : es + en] & OFFSET_MASK).astype(np.int64)
                for a, k in _offset_subruns(offs):
                    yield (es + a, k, int(offs[a]) // PAGE_SIZE,
                           int(offs[a]), k * PAGE_SIZE)

    def iter_hot_extents(self, chunk_pages: int = 256):
        """Yield ``(pages, pool_off, nbytes)`` CXL extents covering the hot
        set, each readable with ONE sequential CXL read of ``nbytes`` at
        ``pool_off`` whose i-th page belongs to guest page ``pages[i]``.

        Private layout: the hot region is rank-compacted, so this is simply
        the region streamed in ``chunk_pages`` chunks (``pages`` ascending).
        Dedup layout: hot pages are visited in STORE-OFFSET order and split
        wherever offsets stop being adjacent — ``pages`` is then generally
        unsorted; installers sort it (and permute the payload) before the
        uffd scatter."""
        hot = self.hot_page_indices()
        if hot.size == 0:
            return
        if not self.regions.dedup:
            hot_off = self.regions.hot_off
            for r0 in range(0, int(hot.size), chunk_pages):
                r1 = min(int(hot.size), r0 + chunk_pages)
                yield (hot[r0:r1], hot_off + r0 * PAGE_SIZE,
                       (r1 - r0) * PAGE_SIZE)
            return
        oa = self.offset_array()
        offs = (oa[hot] & OFFSET_MASK).astype(np.int64)
        order = np.argsort(offs, kind="stable")
        hot_o, offs_o = hot[order], offs[order]
        chunk_bytes = chunk_pages * PAGE_SIZE
        for a, k in _offset_subruns(offs_o):
            # split at ABSOLUTE tier-grid boundaries (not run-relative): two
            # snapshots sharing a run of store pages then emit bit-identical
            # (pool_off, nbytes) chunks for the overlap, which is what lets
            # the content-keyed HotChunkCache fan one physical read out
            # across different variants
            s = a
            while s < a + k:
                off_s = int(offs_o[s])
                to_boundary = (chunk_bytes - off_s % chunk_bytes) // PAGE_SIZE
                n = min(a + k - s, max(1, to_boundary))
                yield hot_o[s : s + n], off_s, n * PAGE_SIZE
                s += n

    def cold_rank(self, page: int) -> int:
        """Rank (position in the sorted cold set) of a cold page.  For a
        dedup snapshot there is no compacted rank space; the "rank" is the
        absolute tier page number (offset / PAGE_SIZE), which keeps the
        ``(rank0, n)`` extent arithmetic working unchanged."""
        _tier, off = decode_slot(self.offset_array()[page])
        return off if self.regions.cold_compressed else off // PAGE_SIZE

    def cold_extent_span(self, rank: int, n: int) -> Tuple[int, int]:
        """Byte span of `n` consecutive cold ranks in the RDMA tier.

        -> (pool_byte_offset, nbytes).  For the compressed cold tier the
        per-rank chunks are stored back-to-back, so consecutive ranks always
        form one contiguous byte extent readable with a single one-sided read.
        Dedup ranks are absolute tier page numbers, so no region base is
        added.
        """
        if self.regions.dedup:
            return rank * PAGE_SIZE, n * PAGE_SIZE
        if not self.regions.cold_compressed:
            return self.regions.rdma_off + rank * PAGE_SIZE, n * PAGE_SIZE
        starts, lens = self.cold_index()
        lo = int(starts[rank])
        hi = int(starts[rank + n - 1]) + int(lens[rank + n - 1] & np.uint32(0x7FFF_FFFF))
        return self.regions.rdma_off + lo, hi - lo

    def split_cold_extent(self, rank: int, n: int, payload: np.ndarray) -> np.ndarray:
        """Decode one cold extent's payload into an (n, PAGE_SIZE) matrix."""
        if not self.regions.cold_compressed:
            return payload[: n * PAGE_SIZE].reshape(n, PAGE_SIZE)
        starts, lens = self.cold_index()
        base = int(starts[rank])
        out = np.empty((n, PAGE_SIZE), dtype=np.uint8)
        for i in range(n):
            lo = int(starts[rank + i]) - base
            ln = int(lens[rank + i] & np.uint32(0x7FFF_FFFF))
            raw = bool(lens[rank + i] & np.uint32(0x8000_0000))
            out[i] = self.decompress_page(payload[lo : lo + ln], raw)
        return out
