"""Deterministic fault injection, retry policy, and tier health (DESIGN.md §15).

Production analogue of :class:`repro.sim.faults.FlakyTier`: seeded,
Clock-driven fault schedules over the REAL tiers and the async RDMA engine,
usable under both ``RealClock`` and ``VirtualClock``.  The seam is an
optional :class:`FaultInjector` attribute on :class:`repro.core.pool.MemoryTier`
— when absent (the default) the serving paths pay a single ``is None`` check
and the modeled cost ledger is bit-identical to the fault-free path.

Fault classes:

* **read timeouts** — count-windowed over ``[lo, hi)`` tier offsets, raised
  as :class:`TierFaultError` (``kind="timeout"``) before any bytes move;
* **write faults** — symmetric to reads (``kind="write"``);
* **completion errors** — lost RDMA CQEs (``kind="completion"``), raised
  after the copy so a retry re-transfers the extent;
* **per-page CXL poison** — the bytes *returned* by a read are corrupted,
  the data at rest stays clean (poison is a link-level event), so the
  checksum-repair path's budgeted re-read from the home tier observes clean
  bytes once the schedule drains;
* **brownout windows** — clock intervals during which every *host-link*
  access to a tier fails hard (``kind="brownout"``); owner-side pool-fabric
  access is unaffected.  Brownouts are what the :class:`TierHealth` circuit
  breaker converts into degraded (RDMA-only all-cold) restores.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Callable, Dict, List, Optional, Tuple, TypeVar

import numpy as np

from .clock import REAL_CLOCK, Clock
from .pagestore import PAGE_SIZE

T = TypeVar("T")


class TierFaultError(RuntimeError):
    """An injected (or detected) transient tier fault.

    ``kind``: ``"timeout"`` | ``"write"`` | ``"completion"`` | ``"brownout"``.
    ``repro.sim.faults.SimTimeout`` subclasses this, so one ``except``
    clause covers both the production seam and the sim reference.
    """

    def __init__(self, msg: str, tier: str = "", kind: str = "timeout"):
        super().__init__(msg)
        self.tier = tier
        self.kind = kind


@dataclasses.dataclass
class _Window:
    """Inject for the next ``remaining`` matching ops touching [lo, hi)."""

    remaining: int
    lo: int = 0
    hi: int = 1 << 62


class FaultInjector:
    """Seeded fault schedules, shared by every component holding the pool.

    Builder methods return ``self`` (the ``FlakyTier`` idiom) so schedules
    chain: ``FaultInjector(seed=7).fail_reads("rdma", 2).brownout("cxl",
    0.0, 1e-3)``.  All schedule state is guarded by one lock; window
    consumption is count-based, so a given access sequence observes an
    identical fault pattern on every run regardless of wall-clock timing.
    """

    def __init__(self, clock: Optional[Clock] = None, seed: int = 0):
        self.clock = clock or REAL_CLOCK
        self.seed = int(seed)
        self.rng = random.Random(self.seed ^ 0x5EED5)
        self._t0 = self.clock.monotonic()
        self._lock = threading.Lock()
        self._reads: Dict[str, List[_Window]] = {}
        self._writes: Dict[str, List[_Window]] = {}
        self._poison: Dict[str, List[_Window]] = {}
        self._completions: Dict[str, int] = {}
        self._brownouts: Dict[str, List[Tuple[float, float]]] = {}
        self.stats = {
            "reads": 0, "writes": 0,
            "injected_timeouts": 0, "injected_write_faults": 0,
            "injected_completion_errors": 0, "injected_poison": 0,
            "brownout_rejections": 0,
        }

    # -- schedule builders -------------------------------------------------
    def fail_reads(self, tier: str, n: int = 1, lo: int = 0,
                   hi: int = 1 << 62) -> "FaultInjector":
        self._reads.setdefault(tier, []).append(_Window(n, lo, hi))
        return self

    def fail_writes(self, tier: str, n: int = 1, lo: int = 0,
                    hi: int = 1 << 62) -> "FaultInjector":
        self._writes.setdefault(tier, []).append(_Window(n, lo, hi))
        return self

    def poison_reads(self, tier: str, n: int = 1, lo: int = 0,
                     hi: int = 1 << 62) -> "FaultInjector":
        self._poison.setdefault(tier, []).append(_Window(n, lo, hi))
        return self

    def fail_completions(self, tier: str, n: int = 1) -> "FaultInjector":
        self._completions[tier] = self._completions.get(tier, 0) + int(n)
        return self

    def brownout(self, tier: str, start_s: float = 0.0,
                 duration_s: float = 1e-3) -> "FaultInjector":
        """Host-link brownout during [t0+start_s, t0+start_s+duration_s)."""
        self._brownouts.setdefault(tier, []).append(
            (self._t0 + start_s, self._t0 + start_s + duration_s))
        return self

    # -- checks (called from the tier/engine seams) ------------------------
    def in_brownout(self, tier: str) -> bool:
        now = self.clock.monotonic()
        return any(a <= now < b for a, b in self._brownouts.get(tier, ()))

    @staticmethod
    def _take(windows: Optional[List[_Window]], offset: int, nbytes: int) -> bool:
        if not windows:
            return False
        for w in windows:
            if w.remaining > 0 and offset < w.hi and offset + nbytes > w.lo:
                w.remaining -= 1
                return True
        return False

    def check_read(self, tier: str, offset: int, nbytes: int,
                   host_link: bool = False) -> None:
        with self._lock:
            self.stats["reads"] += 1
            if host_link and self.in_brownout(tier):
                self.stats["brownout_rejections"] += 1
                raise TierFaultError(
                    f"injected {tier} brownout: read({offset}, {nbytes})",
                    tier=tier, kind="brownout")
            if self._take(self._reads.get(tier), offset, nbytes):
                self.stats["injected_timeouts"] += 1
                raise TierFaultError(
                    f"injected {tier} read timeout: read({offset}, {nbytes})",
                    tier=tier, kind="timeout")

    def check_write(self, tier: str, offset: int, nbytes: int) -> None:
        with self._lock:
            self.stats["writes"] += 1
            if self._take(self._writes.get(tier), offset, nbytes):
                self.stats["injected_write_faults"] += 1
                raise TierFaultError(
                    f"injected {tier} write fault: write({offset}, {nbytes})",
                    tier=tier, kind="write")

    def check_completion(self, tier: str) -> None:
        with self._lock:
            n = self._completions.get(tier, 0)
            if n > 0:
                self._completions[tier] = n - 1
                self.stats["injected_completion_errors"] += 1
                raise TierFaultError(
                    f"injected {tier} completion error", tier=tier,
                    kind="completion")

    def filter_read(self, tier: str, offset: int, nbytes: int,
                    data: np.ndarray) -> bool:
        """Apply per-page poison to the bytes a read RETURNED, in place."""
        wins = self._poison.get(tier)
        if not wins:
            return False
        hit = False
        with self._lock:
            for k in range(max(1, -(-nbytes // PAGE_SIZE))):
                a = offset + k * PAGE_SIZE
                b = min(offset + nbytes, a + PAGE_SIZE)
                if b <= a:
                    break
                if self._take(wins, a, b - a):
                    data[k * PAGE_SIZE] ^= 0xFF
                    self.stats["injected_poison"] += 1
                    hit = True
        return hit


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded re-issue with seeded exponential backoff + jitter.

    Demand faults escalate: their backoffs are scaled by ``demand_scale``
    and bounded by the tighter ``demand_deadline_s`` (a blocked guest vCPU
    cannot wait out a prefetch-grade deadline), while background extent
    reads get the full ``extent_deadline_s`` budget.  Deadlines bound the
    cumulative *modeled* backoff per operation, so they behave identically
    under ``RealClock`` and ``VirtualClock``.
    """

    max_retries: int = 4
    base_backoff_s: float = 50e-6
    max_backoff_s: float = 5e-3
    jitter_frac: float = 0.25
    demand_scale: float = 0.25
    extent_deadline_s: float = 0.25
    demand_deadline_s: float = 0.05

    def backoff_s(self, attempt: int, rng: Optional[random.Random] = None,
                  urgent: bool = False) -> float:
        b = min(self.max_backoff_s, self.base_backoff_s * (2.0 ** attempt))
        if urgent:
            b *= self.demand_scale
        if rng is not None:
            b *= 1.0 + self.jitter_frac * rng.random()
        return b

    def deadline_s(self, urgent: bool = False) -> float:
        return self.demand_deadline_s if urgent else self.extent_deadline_s


DEFAULT_RETRY_POLICY = RetryPolicy()


def call_with_retries(fn: Callable[[], T], *,
                      policy: RetryPolicy = DEFAULT_RETRY_POLICY,
                      rng: Optional[random.Random] = None,
                      ledger=None,
                      clock: Optional[Clock] = None,
                      urgent: bool = False,
                      trace: Optional[List[float]] = None) -> T:
    """Run ``fn``, retrying :class:`TierFaultError` under ``policy``.

    Every backoff is charged to ``ledger`` (key ``"retry_backoff"``) and
    slept on ``clock`` so modeled time stays honest under both clocks;
    ``trace`` (when given) records the exact backoff sequence — the
    determinism property tests compare it across runs.  Brownout faults are
    never retried: the caller's circuit breaker degrades instead of
    hammering a browned-out link.
    """
    attempt = 0
    spent = 0.0
    while True:
        try:
            return fn()
        except TierFaultError as e:
            if e.kind == "brownout" or attempt >= policy.max_retries:
                raise
            bk = policy.backoff_s(attempt, rng, urgent)
            if spent + bk > policy.deadline_s(urgent):
                raise
            spent += bk
            if trace is not None:
                trace.append(bk)
            if ledger is not None:
                ledger.add("retry_backoff", bk)
            if clock is not None:
                clock.sleep(bk)
            attempt += 1


class TierHealth:
    """Per-tier host-link circuit breaker: CLOSED → OPEN → HALF_OPEN.

    ``record_failure(hard=True)`` (a brownout) trips immediately; soft
    failures trip after ``failure_threshold``.  An OPEN breaker admits no
    traffic until ``cooldown_s`` of clock time elapses, then transitions to
    HALF_OPEN and admits probe traffic: one success closes it, one failure
    re-opens it.  Serving consults :meth:`allow` before touching the link
    and falls back to the degraded RDMA-only path while the breaker is
    open; :meth:`degraded` feeds the fleet placement score.
    """

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(self, name: str, clock: Optional[Clock] = None,
                 failure_threshold: int = 3, cooldown_s: float = 2e-3):
        self.name = name
        self.clock = clock or REAL_CLOCK
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self.state = self.CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self._lock = threading.Lock()
        self.stats = {"failures": 0, "trips": 0, "probes": 0, "recoveries": 0}

    def allow(self) -> bool:
        """Should a caller attempt the real link right now?"""
        if self.state == self.CLOSED:
            return True
        with self._lock:
            if (self.state == self.OPEN
                    and self.clock.monotonic() - self._opened_at
                    >= self.cooldown_s):
                self.state = self.HALF_OPEN
                self.stats["probes"] += 1
            return self.state != self.OPEN

    def record_failure(self, hard: bool = False) -> None:
        with self._lock:
            self.stats["failures"] += 1
            self._failures += 1
            if (hard or self._failures >= self.failure_threshold
                    or self.state == self.HALF_OPEN):
                if self.state != self.OPEN:
                    self.stats["trips"] += 1
                self.state = self.OPEN
                self._opened_at = self.clock.monotonic()

    def record_success(self) -> None:
        # fast path: a healthy link takes no lock on the hot serving path
        if self.state == self.CLOSED and self._failures == 0:
            return
        with self._lock:
            if self.state == self.HALF_OPEN:
                self.stats["recoveries"] += 1
            self.state = self.CLOSED
            self._failures = 0

    @property
    def degraded(self) -> bool:
        return self.state != self.CLOSED
