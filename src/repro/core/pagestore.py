"""Paged flat address space over model/server state.

The paper treats a MicroVM's guest memory as a flat, page-granular address
space.  Our analogue: a *StateImage* lays out a collection of named arrays
(params, optimizer moments, KV-cache arena, activation workspace, ...) into a
single page-aligned byte address space.  Every Aquifer mechanism (zero-page
elimination, hot/cold partitioning, the offset array, page serving) operates
on page indices of this address space.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

PAGE_SIZE = 4096  # bytes — matches the paper's 4 KiB guest pages

# zero_scan(pages_matrix uint8[N, PAGE_SIZE]) -> bool[N] (True = all-zero).
# Pluggable backend for the publish-path zero scan: the numpy oracle by
# default; ``set_zero_scan_backend`` swaps in kernels/zero_detect (Pallas on
# TPU, interpret elsewhere) — parity-asserted in tests/test_fused_kernels.py.
ZeroScanFn = Callable[[np.ndarray], np.ndarray]

_zero_scan_backend: Optional[ZeroScanFn] = None


def numpy_zero_scan(pages_matrix: np.ndarray) -> np.ndarray:
    """CPU oracle: vectorized any() over each page row."""
    return ~pages_matrix.any(axis=1)


def set_zero_scan_backend(fn: Optional[ZeroScanFn]) -> Optional[ZeroScanFn]:
    """Install a process-wide zero-scan backend (None restores the numpy
    oracle); returns the previous backend so callers can restore it."""
    global _zero_scan_backend
    prev = _zero_scan_backend
    _zero_scan_backend = fn
    return prev


def pallas_zero_scan(pages_matrix: np.ndarray) -> np.ndarray:
    """kernels/zero_detect adapted to the ``ZeroScanFn`` signature (same
    output as the oracle, asserted equal in tests)."""
    from ..kernels.zero_detect.ops import zero_detect

    u32 = pages_matrix.view(np.uint32).reshape(pages_matrix.shape[0], -1)
    return np.asarray(zero_detect(u32, use_pallas=True, interpret=None)) != 0


def num_pages(nbytes: int) -> int:
    return -(-nbytes // PAGE_SIZE)


@dataclasses.dataclass(frozen=True)
class ArrayExtent:
    """Placement of one named array inside the flat address space."""

    name: str
    byte_offset: int          # page-aligned start
    nbytes: int               # payload bytes (may end mid-page; tail is zero)
    shape: Tuple[int, ...]
    dtype: str

    @property
    def first_page(self) -> int:
        return self.byte_offset // PAGE_SIZE

    @property
    def page_count(self) -> int:
        return num_pages(self.nbytes)

    def pages(self) -> range:
        return range(self.first_page, self.first_page + self.page_count)

    def element_pages(self, start_elem: int, stop_elem: int) -> range:
        """Pages covering elements [start, stop) of the flattened array."""
        itemsize = np.dtype(self.dtype).itemsize
        lo = self.byte_offset + start_elem * itemsize
        hi = self.byte_offset + stop_elem * itemsize
        return range(lo // PAGE_SIZE, num_pages(hi) if hi % PAGE_SIZE else hi // PAGE_SIZE)

    def row_pages(self, row: int, row_elems: int) -> range:
        """Pages covering one leading-axis row (e.g. one embedding row)."""
        return self.element_pages(row * row_elems, (row + 1) * row_elems)


@dataclasses.dataclass
class Manifest:
    """Address-space layout: the restore-time 'machine state' index."""

    extents: List[ArrayExtent]
    total_pages: int

    def by_name(self) -> Dict[str, ArrayExtent]:
        return {e.name: e for e in self.extents}

    def to_dict(self) -> dict:
        return {
            "total_pages": self.total_pages,
            "extents": [dataclasses.asdict(e) for e in self.extents],
        }

    @staticmethod
    def from_dict(d: dict) -> "Manifest":
        return Manifest(
            extents=[ArrayExtent(**{**e, "shape": tuple(e["shape"])}) for e in d["extents"]],
            total_pages=d["total_pages"],
        )


class StateImage:
    """A flat, paged byte image of named arrays (the 'guest memory').

    Arrays are laid out back-to-back, each starting on a page boundary so a
    page never spans two arrays (mirrors guest-physical frames owning a
    single mapping).
    """

    def __init__(self, manifest: Manifest, buf: np.ndarray):
        assert buf.dtype == np.uint8 and buf.ndim == 1
        self.manifest = manifest
        self.buf = buf

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(arrays: Mapping[str, np.ndarray]) -> "StateImage":
        extents: List[ArrayExtent] = []
        offset = 0
        for name, arr in arrays.items():
            arr = np.ascontiguousarray(arr)
            extents.append(
                ArrayExtent(name, offset, arr.nbytes, tuple(arr.shape), str(arr.dtype))
            )
            offset += num_pages(arr.nbytes) * PAGE_SIZE
        buf = np.zeros(offset, dtype=np.uint8)
        img = StateImage(Manifest(extents, offset // PAGE_SIZE), buf)
        for name, arr in arrays.items():
            img.write_array(name, arr)
        return img

    @staticmethod
    def empty_like(manifest: Manifest) -> "StateImage":
        return StateImage(manifest, np.zeros(manifest.total_pages * PAGE_SIZE, np.uint8))

    # -- array views ------------------------------------------------------
    def write_array(self, name: str, arr: np.ndarray) -> None:
        e = self.manifest.by_name()[name]
        raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        assert raw.nbytes == e.nbytes, f"{name}: {raw.nbytes} != {e.nbytes}"
        self.buf[e.byte_offset : e.byte_offset + e.nbytes] = raw

    def read_array(self, name: str) -> np.ndarray:
        e = self.manifest.by_name()[name]
        raw = self.buf[e.byte_offset : e.byte_offset + e.nbytes]
        return raw.view(np.dtype(e.dtype)).reshape(e.shape)

    # -- page views -------------------------------------------------------
    @property
    def total_pages(self) -> int:
        return self.manifest.total_pages

    def page(self, idx: int) -> np.ndarray:
        return self.buf[idx * PAGE_SIZE : (idx + 1) * PAGE_SIZE]

    def pages_matrix(self) -> np.ndarray:
        return self.buf.reshape(self.total_pages, PAGE_SIZE)

    def write_page(self, idx: int, data: np.ndarray) -> None:
        assert data.nbytes == PAGE_SIZE
        self.buf[idx * PAGE_SIZE : (idx + 1) * PAGE_SIZE] = data.view(np.uint8).reshape(-1)

    def zero_page_bitmap(self, backend: Optional[ZeroScanFn] = None) -> np.ndarray:
        """bool[total_pages]; True where the page content is all zero.

        ``backend`` (or the process-wide one installed via
        ``set_zero_scan_backend``) swaps the numpy oracle for
        kernels/zero_detect — same output, asserted equal in tests.
        """
        fn = backend or _zero_scan_backend or numpy_zero_scan
        out = np.asarray(fn(self.pages_matrix()), dtype=bool)
        assert out.shape == (self.total_pages,), \
            f"zero-scan backend returned shape {out.shape}"
        return out


def runs_from_pages(pages: Sequence[int]) -> List[Tuple[int, int]]:
    """Collapse a sorted page-index set into (start, length) runs.

    Used for the Fig-4 fragmentation analysis and for batched installs.
    """
    out: List[Tuple[int, int]] = []
    it = iter(sorted(set(pages)))
    try:
        start = prev = next(it)
    except StopIteration:
        return out
    for p in it:
        if p == prev + 1:
            prev = p
            continue
        out.append((start, prev - start + 1))
        start = prev = p
    out.append((start, prev - start + 1))
    return out


def pages_from_runs(runs: Iterable[Tuple[int, int]]) -> List[int]:
    out: List[int] = []
    for s, n in runs:
        out.extend(range(s, s + n))
    return out
