"""Learned first-touch ordering + the PrefetchPolicy seam (DESIGN.md §17).

The prefetch pump historically drained cold extents in snapshot-*layout*
order, so a workload whose first-touch order diverges from the layout pays
residual demand-fault stalls even at full prefetch bandwidth.  This module
closes that gap:

* :func:`fit_prefetch_model` turns a :class:`~repro.core.profiler.HeatMap`'s
  first-touch run-transition counts into a :class:`PrefetchModel` — a
  row-stochastic Markov matrix over page runs plus a START distribution.
  Ordering scores are *discounted multi-step reachability* from a seed run
  (``Σ_{k=1..K} γ^k · v0 Pᵏ``), evaluated vectorized on jax when available
  and falling back to numpy.  Fitting and scoring are deterministic: no RNG,
  stable ``(score desc, position asc)`` tie-breaks.

* :class:`PrefetchPolicy` is the single public ordering seam on
  ``RestoreEngine`` / ``NodePageServer``:
  ``order_extents(session, faulting_page) -> iterator`` of the session
  reader's cold-extent tuples ``(es, en, rank0, pool_off, nbytes)``.
  :class:`LayoutOrderPolicy` reproduces the PR-1..9 behavior exactly
  (default); :class:`PredictedOrderPolicy` re-orders the same extents by
  predicted next-touch and re-seeds from the faulting page at each demand
  miss.  Policies only *re-order* the extent walk — they never change the
  split arithmetic, so installed bytes stay bit-identical either way.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .profiler import START_RUN, HeatMap

try:                                    # model math on jax when present
    import jax.numpy as jnp
    _HAVE_JAX = True
except Exception:                       # pragma: no cover - jax ships in-image
    jnp = None
    _HAVE_JAX = False

#: (es, en, rank0, pool_off, nbytes) — the shape ``iter_cold_extents`` yields.
Extent = Tuple[int, int, int, int, int]


def _discounted_reachability(trans: np.ndarray, v0: np.ndarray,
                             discount: float, horizon: int) -> np.ndarray:
    """``Σ_{k=1..horizon} discount^k · (v0 · transᵏ)`` — probability-mass of
    touching each run within the next ``horizon`` first-touch steps, geared
    toward sooner touches.  One (1×n)·(n×n) matvec per step, vectorized."""
    if _HAVE_JAX:
        t = jnp.asarray(trans)
        v = jnp.asarray(v0)
        acc = jnp.zeros_like(v)
        g = 1.0
        for _ in range(horizon):
            v = v @ t
            g *= discount
            acc = acc + g * v
        return np.asarray(acc, dtype=np.float64)
    v = v0.astype(np.float64, copy=True)
    acc = np.zeros_like(v)
    g = 1.0
    for _ in range(horizon):
        v = v @ trans
        g *= discount
        acc += g * v
    return acc


@dataclasses.dataclass
class PrefetchModel:
    """Markov first-touch model over page runs for one ``(name, version)``.

    ``trans[i, j]`` is the probability that run ``j`` is first-touched right
    after run ``i``; ``start`` is the distribution of the very first run a
    restore touches.  Scores are cached per seed run (the model is frozen
    once fitted — refit through the policy when telemetry grows)."""

    run_pages: int
    n_runs: int
    trans: np.ndarray                   # (n_runs, n_runs) row-stochastic
    start: np.ndarray                   # (n_runs,) START_RUN → run
    discount: float = 0.6
    horizon: int = 16

    def __post_init__(self):
        self._score_cache: dict = {}
        self._lock = threading.Lock()

    def run_scores(self, seed_run: Optional[int] = None) -> np.ndarray:
        """Predicted-next-touch score per run, seeded at ``seed_run`` (the
        faulting page's run) or at the START distribution when ``None`` /
        untrained."""
        key = (int(seed_run) if seed_run is not None
               and 0 <= int(seed_run) < self.n_runs
               and bool(self.trans[int(seed_run)].any()) else None)
        with self._lock:
            cached = self._score_cache.get(key)
        if cached is not None:
            return cached
        if key is None:
            v0 = self.start.astype(np.float64, copy=True)
        else:
            v0 = np.zeros(self.n_runs, dtype=np.float64)
            v0[key] = 1.0
        scores = _discounted_reachability(self.trans, v0,
                                          self.discount, self.horizon)
        if key is None:
            # START seed: v0 itself is the predicted FIRST touch — include
            # it at full weight.  (Seeded at a faulting page the seed run is
            # already being demand-fetched, so only successors score.)
            scores = scores + v0
        with self._lock:
            self._score_cache[key] = scores
        return scores

    def run_order(self, seed_run: Optional[int] = None) -> np.ndarray:
        """All runs ranked by predicted next-touch (score desc, run asc)."""
        s = self.run_scores(seed_run)
        return np.lexsort((np.arange(self.n_runs), -s))

    def page_order(self, pages) -> np.ndarray:
        """``pages`` re-ranked by their run's predicted-first-touch score
        (stable: page index breaks ties) — re-curation uses this so the hot
        set tracks observed touch order."""
        pages = np.asarray(pages, dtype=np.int64)
        if pages.size == 0:
            return pages
        s = self.run_scores(None)
        order = np.lexsort((pages, -s[pages // self.run_pages]))
        return pages[order]


def fit_prefetch_model(heat: Optional[HeatMap], discount: float = 0.6,
                       horizon: int = 16) -> Optional[PrefetchModel]:
    """Fit a :class:`PrefetchModel` from a map's first-touch transition
    counts.  ``None`` when there is no sequence telemetry yet (cold start —
    callers fall back to layout order)."""
    if heat is None:
        return None
    src, dst, cnt = heat.transition_counts()
    if cnt.size == 0:
        return None
    n = int(heat.n_runs)
    trans = np.zeros((n, n), dtype=np.float64)
    start = np.zeros(n, dtype=np.float64)
    from_start = src == START_RUN
    np.add.at(start, dst[from_start], cnt[from_start])
    inner = ~from_start
    np.add.at(trans, (src[inner], dst[inner]), cnt[inner])
    row_sums = trans.sum(axis=1, keepdims=True)
    np.divide(trans, row_sums, out=trans, where=row_sums > 0)
    total = start.sum()
    if total > 0:
        start /= total
    return PrefetchModel(int(heat.run_pages), n, trans, start,
                         float(discount), int(horizon))


# --------------------------------------------------------------------------
# The policy seam
# --------------------------------------------------------------------------

class PrefetchPolicy:
    """Protocol: the single public cold-extent ordering seam.

    ``order_extents(session, faulting_page)`` yields the session reader's
    cold extents ``(es, en, rank0, pool_off, nbytes)`` in fetch order.
    ``session`` is any object with ``.reader`` (and optionally ``.heat``);
    ``faulting_page`` re-seeds prediction at a demand miss (``None`` for the
    initial walk).  ``reseed_on_demand`` tells the pump whether a demand
    miss should re-order the already-queued extents."""

    max_extent_pages: int = 64
    reseed_on_demand: bool = False

    def order_extents(self, session,
                      faulting_page: Optional[int] = None) -> Iterator[Extent]:
        raise NotImplementedError


class LayoutOrderPolicy(PrefetchPolicy):
    """Snapshot-layout order (largest cold runs first) — the PR-1..9
    behavior and the default everywhere."""

    def __init__(self, max_extent_pages: int = 64):
        self.max_extent_pages = int(max_extent_pages)

    def order_extents(self, session,
                      faulting_page: Optional[int] = None) -> Iterator[Extent]:
        return iter(list(
            session.reader.iter_cold_extents(self.max_extent_pages)))

    def __repr__(self):
        return f"LayoutOrderPolicy(max_extent_pages={self.max_extent_pages})"


class PredictedOrderPolicy(PrefetchPolicy):
    """Predicted-next-touch order from the session's HeatMap.

    Lazily fits (and re-fits when telemetry grows) a :class:`PrefetchModel`
    from ``session.heat``; with no telemetry it degrades to exactly
    :class:`LayoutOrderPolicy`'s order.  Extents are scored by the best run
    they cover and re-seeded from the faulting page's run on demand misses.
    """

    reseed_on_demand = True

    def __init__(self, max_extent_pages: int = 64,
                 model: Optional[PrefetchModel] = None,
                 discount: float = 0.6, horizon: int = 16):
        self.max_extent_pages = int(max_extent_pages)
        self.model = model
        self.discount = float(discount)
        self.horizon = int(horizon)
        self._lock = threading.Lock()
        self._fit_key = None
        self._fit_model: Optional[PrefetchModel] = None

    def _resolve_model(self, session) -> Optional[PrefetchModel]:
        if self.model is not None:
            return self.model
        heat = getattr(session, "heat", None)
        if heat is None:
            return None
        # refit only when the sequence telemetry actually grew
        key = (id(heat), heat.stats.get("seq_transitions", 0))
        with self._lock:
            if self._fit_key == key:
                return self._fit_model
        model = fit_prefetch_model(heat, self.discount, self.horizon)
        with self._lock:
            self._fit_key, self._fit_model = key, model
        return model

    def order_extents(self, session,
                      faulting_page: Optional[int] = None) -> Iterator[Extent]:
        base: List[Extent] = list(
            session.reader.iter_cold_extents(self.max_extent_pages))
        model = self._resolve_model(session)
        if model is None or not base:
            return iter(base)           # cold start ⇒ layout order
        seed_run = (int(faulting_page) // model.run_pages
                    if faulting_page is not None else None)
        scores = model.run_scores(seed_run)
        rp = model.run_pages
        ext_scores = np.empty(len(base), dtype=np.float64)
        for i, (es, en, _rank0, _off, _nb) in enumerate(base):
            ext_scores[i] = scores[es // rp:(es + en - 1) // rp + 1].max()
        order = np.lexsort((np.arange(len(base)), -ext_scores))
        return iter([base[i] for i in order])

    def __repr__(self):
        return (f"PredictedOrderPolicy(max_extent_pages="
                f"{self.max_extent_pages}, discount={self.discount})")


def resolve_policy(policy: Optional[PrefetchPolicy],
                   max_extent_pages: Optional[int],
                   caller: str) -> PrefetchPolicy:
    """Deprecation shim: old ``max_extent_pages=N`` call sites become
    ``LayoutOrderPolicy(N)`` with a warning; ``policy`` wins when both are
    given; neither ⇒ the default :class:`LayoutOrderPolicy`."""
    if max_extent_pages is not None:
        warnings.warn(
            f"{caller}: max_extent_pages is deprecated; pass a "
            "PrefetchPolicy (e.g. LayoutOrderPolicy(max_extent_pages))",
            DeprecationWarning, stacklevel=3)
        if policy is None:
            policy = LayoutOrderPolicy(int(max_extent_pages))
    return policy if policy is not None else LayoutOrderPolicy()
