"""Copy-based page serving (§3.4, §4).

Restore = (1) pre-install the hot set from CXL *before* resume, then
(2) demand-page cold pages asynchronously from RDMA while the instance runs.

All installs go through the ``uffd.copy()`` analogue (`Instance.uffd_copy`),
which writes a *private copy* into the instance's address space — the
pool-resident snapshot is never modified, preserving immutability across
concurrent restores without file-backed CoW.  Zero-page faults take the
``uffd.zeropage()`` fast path (§4).

Async RDMA fault handling mirrors the paper: the fault handler grabs a free
buffer page, posts a one-sided read, and returns immediately; a completion
thread drains the CQ (hybrid busy-poll then event wait) and installs fetched
pages.  The fault handler is never blocked on the network.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from .pagestore import PAGE_SIZE, StateImage, runs_from_pages
from .pool import (
    MMAP_PER_RANGE_S,
    UFFD_COPY_PER_PAGE_S,
    UFFD_ZEROPAGE_PER_PAGE_S,
    MemoryTier,
    TimeLedger,
)
from .snapshot import SnapshotReader


class Instance:
    """A restoring/running instance's guest address space + present bitmap."""

    def __init__(self, image: StateImage, ledger: Optional[TimeLedger] = None):
        self.image = image
        self.present = np.zeros(image.total_pages, dtype=bool)
        self.ledger = ledger or TimeLedger()
        self.stats = {
            "pre_installed": 0,
            "fault_zero": 0,
            "fault_cxl": 0,
            "fault_rdma": 0,
            "uffd_copies": 0,
            "uffd_zeropages": 0,
        }
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)

    # -- uffd analogues ------------------------------------------------------
    def uffd_copy(self, page: int, src: np.ndarray) -> None:
        with self._cv:
            if self.present[page]:
                return
            self.image.write_page(page, src)
            self.present[page] = True
            self.stats["uffd_copies"] += 1
            self.ledger.add("uffd_copy", UFFD_COPY_PER_PAGE_S)
            self._cv.notify_all()

    def uffd_zeropage(self, page: int) -> None:
        with self._cv:
            if self.present[page]:
                return
            # image buffers start zeroed; mark present only
            self.present[page] = True
            self.stats["uffd_zeropages"] += 1
            self.ledger.add("uffd_zeropage", UFFD_ZEROPAGE_PER_PAGE_S)
            self._cv.notify_all()

    def wait_present(self, page: int, timeout_s: float = 30.0) -> bool:
        with self._cv:
            return self._cv.wait_for(lambda: self.present[page], timeout=timeout_s)

    def all_present(self) -> bool:
        return bool(self.present.all())


class BufferPool:
    """Local pool of free page buffers for in-flight RDMA reads (§3.4)."""

    def __init__(self, n_pages: int = 256):
        self._q: "queue.Queue[np.ndarray]" = queue.Queue()
        for _ in range(n_pages):
            self._q.put(np.empty(PAGE_SIZE, dtype=np.uint8))

    def acquire(self) -> np.ndarray:
        return self._q.get()

    def release(self, buf: np.ndarray) -> None:
        self._q.put(buf)


class AsyncRDMAEngine:
    """Emulated one-sided RDMA read engine with a completion queue.

    A worker thread performs the actual byte copies (so data paths are real);
    modeled time is charged per-op on the ledger.  The completion handler
    busy-polls up to ``poll_budget`` iterations after each completion before
    falling back to blocking on the CQ (the paper's hybrid strategy, §4).
    """

    def __init__(self, tier: MemoryTier, ledger: TimeLedger, poll_budget: int = 1024):
        self.tier = tier
        self.ledger = ledger
        self.poll_budget = poll_budget
        self._sq: "queue.Queue" = queue.Queue()
        self._cq: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.stats = {"reads": 0, "busy_polls": 0, "event_waits": 0}

    def submit_read(self, pool_off: int, buf: np.ndarray, token) -> None:
        self._sq.put((pool_off, buf, token))

    def poll_completion(self, block: bool, timeout_s: float = 0.05):
        """-> (buf, token) or None. Emulates CQ poll / completion channel."""
        try:
            if block:
                self.stats["event_waits"] += 1
                return self._cq.get(timeout=timeout_s)
            return self._cq.get_nowait()
        except queue.Empty:
            return None

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                pool_off, buf, token = self._sq.get(timeout=0.05)
            except queue.Empty:
                continue
            nbytes = token[1] if isinstance(token, tuple) else PAGE_SIZE
            buf[:nbytes] = self.tier.buf[pool_off : pool_off + nbytes]
            self.stats["reads"] += 1
            self.ledger.add("rdma_read", self.tier.cost.op_latency_s + nbytes / self.tier.cost.bandwidth_Bps)
            self._cq.put((buf, token))

    def close(self) -> None:
        self._stop.set()
        self._worker.join(timeout=1.0)


class RestoreEngine:
    """Per-instance page server: hot pre-install + async cold demand-paging."""

    def __init__(
        self,
        reader: SnapshotReader,
        instance: Instance,
        rdma_engine: Optional[AsyncRDMAEngine] = None,
        buffer_pool: Optional[BufferPool] = None,
    ):
        self.reader = reader
        self.instance = instance
        self.ledger = instance.ledger
        self.rdma_engine = rdma_engine
        self.buffers = buffer_pool or BufferPool()
        self._inflight: Dict[int, bool] = {}
        self._inflight_lock = threading.Lock()
        self._completion_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- phase 1: hot-set pre-installation (§3.4) ------------------------------
    def pre_install_hot(self) -> int:
        """uffd.copy every hot page from CXL before resume. Serialized (§5.2)."""
        hot = self.reader.hot_page_indices()
        for page in hot:
            kind, off = self.reader.lookup(int(page))
            assert kind == "cxl"
            src = self.reader.view.read(off, PAGE_SIZE)
            self.instance.uffd_copy(int(page), src)
            self.instance.stats["pre_installed"] += 1
        return int(hot.size)

    # -- phase 2: demand faults -------------------------------------------------
    def start_completion_handler(self) -> None:
        if self.rdma_engine is None:
            return
        self._completion_thread = threading.Thread(target=self._completion_loop, daemon=True)
        self._completion_thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._completion_thread is not None:
            self._completion_thread.join(timeout=1.0)

    def handle_fault(self, page: int) -> None:
        """userfaultfd fault for `page`; never blocks on RDMA (§3.4)."""
        if self.instance.present[page]:
            return
        kind, off = self.reader.lookup(page)
        if kind == "zero":
            self.instance.stats["fault_zero"] += 1
            self.instance.uffd_zeropage(page)
            return
        if kind == "cxl":
            self.instance.stats["fault_cxl"] += 1
            src = self.reader.view.read(off, PAGE_SIZE)
            self.instance.uffd_copy(page, src)
            return
        # cold page → async RDMA read (optionally zstd per-page)
        self.instance.stats["fault_rdma"] += 1
        if kind == "rdma_z":
            pool_off, nbytes, raw = self.reader.cold_extent(off)
        else:
            pool_off, nbytes, raw = off, PAGE_SIZE, True
        if self.rdma_engine is None:
            payload = self.reader.rdma.read(pool_off, nbytes)
            self.ledger.add(
                "rdma_read",
                self.reader.rdma.cost.op_latency_s + nbytes / self.reader.rdma.cost.bandwidth_Bps,
            )
            self.instance.uffd_copy(page, self.reader.decompress_page(payload, raw)
                                    if kind == "rdma_z" else payload)
            return
        with self._inflight_lock:
            if self._inflight.get(page):
                return
            self._inflight[page] = True
        buf = self.buffers.acquire()
        self.rdma_engine.submit_read(pool_off, buf, (page, nbytes, raw, kind))

    def access(self, page: int, timeout_s: float = 30.0) -> None:
        """Guest touch: fault if needed and wait for install (test/replay API)."""
        if self.instance.present[page]:
            return
        self.handle_fault(page)
        if not self.instance.wait_present(page, timeout_s):
            raise TimeoutError(f"page {page} not installed within {timeout_s}s")

    def _completion_loop(self) -> None:
        eng = self.rdma_engine
        assert eng is not None
        while not self._stop.is_set():
            item = eng.poll_completion(block=True)
            if item is None:
                continue
            while item is not None:
                buf, token = item
                if isinstance(token, tuple):
                    page, nbytes, raw, kind = token
                    data = (self.reader.decompress_page(buf[:nbytes], raw)
                            if kind == "rdma_z" else buf[:PAGE_SIZE])
                else:
                    page, data = token, buf
                self.instance.uffd_copy(int(page), data)
                self.buffers.release(buf)
                with self._inflight_lock:
                    self._inflight.pop(int(page), None)
                # hybrid poll: batch further completions without sleeping
                polled = None
                for _ in range(eng.poll_budget):
                    polled = eng.poll_completion(block=False)
                    if polled is not None:
                        eng.stats["busy_polls"] += 1
                        break
                item = polled

    # -- bulk restore (used by tests / eager baselines) --------------------------
    def install_all_sync(self) -> None:
        for page in range(self.instance.image.total_pages):
            if not self.instance.present[page]:
                kind, off = self.reader.lookup(page)
                if kind == "zero":
                    self.instance.uffd_zeropage(page)
                elif kind == "cxl":
                    self.instance.uffd_copy(page, self.reader.view.read(off, PAGE_SIZE))
                else:
                    self.instance.uffd_copy(page, self.reader.read_page(page))


def mmap_install_cost(pages: Sequence[int]) -> float:
    """Modeled cost of installing `pages` via per-range mmap (the rejected
    alternative, §2.3.4): one mmap per contiguous run, 2.6x uffd.copy per page."""
    runs = runs_from_pages(pages)
    return sum(n * MMAP_PER_RANGE_S for _, n in runs) + len(runs) * 0.0
