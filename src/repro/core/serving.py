"""Copy-based page serving (§3.4, §4) — run-coalesced.

Restore = (1) pre-install the hot set from CXL *before* resume, then
(2) demand-page cold pages asynchronously from RDMA while the instance runs,
optionally with a background extent prefetcher walking the cold runs.

All installs go through the ``uffd.copy()`` analogue (`Instance.uffd_copy` /
`Instance.uffd_copy_batch`), which writes a *private copy* into the
instance's address space — the pool-resident snapshot is never modified,
preserving immutability across concurrent restores without file-backed CoW.
Zero-page faults take the ``uffd.zeropage()`` fast path (§4);
`uffd_zeropage_range` is the range form of the same ioctl.

Hot sets are dominated by long contiguous runs (Fig. 4), so the hot
pre-install walks the snapshot's run index: ONE CXL read per run (one
op-latency amortized over the whole run) and ONE uffd.copy ioctl per run
(the fixed syscall cost amortized the same way).  See DESIGN.md §5.

Async RDMA fault handling mirrors the paper: the fault handler grabs a free
buffer page, posts a one-sided read, and returns immediately; a completion
thread drains the CQ (hybrid busy-poll then event wait) and installs fetched
pages.  The fault handler is never blocked on the network.  Demand reads are
posted at high priority so they overtake queued prefetch extents (§3.4).
"""
from __future__ import annotations

import itertools
import queue
import random
import threading
import time
import weakref
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clock import Clock, REAL_CLOCK
from .faults import (
    DEFAULT_RETRY_POLICY,
    RetryPolicy,
    TierFaultError,
    call_with_retries,
)
from .pagestore import PAGE_SIZE, StateImage, runs_from_pages
from .prefetch_model import LayoutOrderPolicy, PrefetchPolicy, resolve_policy
from .profiler import TouchEvent
from .pool import (
    MMAP_PER_PAGE_S,
    MMAP_SYSCALL_S,
    UFFD_COPY_PER_PAGE_S,
    UFFD_ZEROPAGE_PER_PAGE_S,
    MemoryTier,
    TimeLedger,
    uffd_copy_batch_cost,
    uffd_zeropage_range_cost,
)
from .snapshot import SnapshotReader

# scatter_fn(dest_matrix, compact, indices) -> dest_matrix; the numpy oracle
# is a vectorized fancy-index store, the Pallas `page_scatter` op plugs in
# behind the same signature (kernels/page_scatter), and so does the fused
# gather→checksum→scatter kernel (kernels/snapshot_fuse.FusedScatter —
# RestoreEngine binds it to the snapshot's publish-time checksum table so
# every installed batch is verified inside the installing kernel call).
ScatterFn = Callable[[np.ndarray, np.ndarray, np.ndarray], np.ndarray]


class Instance:
    """A restoring/running instance's guest address space + present bitmap."""

    def __init__(self, image: StateImage, ledger: Optional[TimeLedger] = None,
                 scatter_fn: Optional[ScatterFn] = None,
                 clock: Optional[Clock] = None):
        self.image = image
        self.present = np.zeros(image.total_pages, dtype=bool)
        self.ledger = ledger or TimeLedger()
        self.scatter_fn = scatter_fn
        self.clock = clock or REAL_CLOCK
        self.stats = {
            "pre_installed": 0,
            "fault_zero": 0,
            "fault_cxl": 0,
            "fault_rdma": 0,
            "uffd_copies": 0,
            "uffd_zeropages": 0,
            "uffd_batches": 0,
            "bytes_installed": 0,
        }
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)

    # -- uffd analogues ------------------------------------------------------
    def uffd_copy(self, page: int, src: np.ndarray) -> bool:
        with self._cv:
            if self.present[page]:
                return False
            self.image.write_page(page, src)
            self.present[page] = True
            self.stats["uffd_copies"] += 1
            self.stats["bytes_installed"] += PAGE_SIZE
            self.ledger.add("uffd_copy", UFFD_COPY_PER_PAGE_S)
            self._cv.notify_all()
            return True

    def uffd_copy_batch(self, pages: np.ndarray, mat: np.ndarray) -> int:
        """Install many pages under ONE lock acquisition via a vectorized
        scatter; the ledger is charged per contiguous range (one uffd.copy
        ioctl per range), not per page.  Already-present pages are skipped.
        Returns the number of pages actually installed."""
        pages = np.asarray(pages, dtype=np.int64)
        mat = np.ascontiguousarray(mat).view(np.uint8).reshape(pages.size, PAGE_SIZE)
        with self._cv:
            todo = ~self.present[pages]
            if not todo.any():
                return 0
            sel = pages[todo]
            pm = self.image.pages_matrix()
            if self.scatter_fn is not None:
                out = np.asarray(self.scatter_fn(pm, mat[todo], sel))
                if out is not pm:          # functional (jax) scatter returned a copy
                    pm[sel] = out[sel]
            else:
                pm[sel] = mat[todo]
            self.present[sel] = True
            n = int(sel.size)
            n_ranges = int(1 + np.count_nonzero(np.diff(sel) != 1))
            self.stats["uffd_copies"] += n
            self.stats["uffd_batches"] += 1
            self.stats["bytes_installed"] += n * PAGE_SIZE
            self.ledger.add("uffd_copy", uffd_copy_batch_cost(n, n_ranges))
            self._cv.notify_all()
            return n

    def uffd_zeropage(self, page: int) -> None:
        with self._cv:
            if self.present[page]:
                return
            # image buffers start zeroed; mark present only
            self.present[page] = True
            self.stats["uffd_zeropages"] += 1
            self.ledger.add("uffd_zeropage", UFFD_ZEROPAGE_PER_PAGE_S)
            self._cv.notify_all()

    def uffd_zeropage_range(self, start: int, n: int) -> int:
        """Range form of uffd.zeropage: one lock acquisition, one ioctl per
        contiguous range actually zeroed (present pages split ranges)."""
        with self._cv:
            sl = self.present[start : start + n]
            todo = np.nonzero(~sl)[0]
            k = int(todo.size)
            if k == 0:
                return 0
            sl[:] = True
            n_ranges = int(1 + np.count_nonzero(np.diff(todo) != 1))
            self.stats["uffd_zeropages"] += k
            self.stats["uffd_batches"] += 1
            self.ledger.add("uffd_zeropage", uffd_zeropage_range_cost(k, n_ranges))
            self._cv.notify_all()
            return k

    def wait_present(self, page: int, timeout_s: float = 30.0) -> bool:
        with self._cv:
            return self.clock.cv_wait_for(
                self._cv, lambda: self.present[page], timeout_s)

    def all_present(self) -> bool:
        return bool(self.present.all())


class BufferPool:
    """Local pool of free page buffers for in-flight RDMA reads (§3.4).

    ``outstanding`` counts buffers currently acquired; the test suite's
    conftest asserts buffer-count conservation (outstanding == 0) after
    every test, so a stopped engine may not strand demand-read buffers.
    """

    _all_pools: "weakref.WeakSet[BufferPool]" = weakref.WeakSet()

    def __init__(self, n_pages: int = 256):
        self.capacity = n_pages
        self.outstanding = 0
        self._lock = threading.Lock()
        self._q: "queue.Queue[np.ndarray]" = queue.Queue()
        for _ in range(n_pages):
            self._q.put(np.empty(PAGE_SIZE, dtype=np.uint8))
        BufferPool._all_pools.add(self)

    def acquire(self) -> np.ndarray:
        buf = self._q.get()
        with self._lock:
            self.outstanding += 1
        return buf

    def release(self, buf: np.ndarray) -> None:
        with self._lock:
            self.outstanding -= 1
        self._q.put(buf)


class AsyncRDMAEngine:
    """Emulated one-sided RDMA read engine with a completion queue.

    A worker thread performs the actual byte copies (so data paths are real);
    modeled time is charged per-op on the ledger.  The submit queue is a
    two-level priority queue: demand-fault reads (urgent) overtake queued
    prefetch extents.  The completion handler busy-polls up to
    ``poll_budget`` iterations after each completion before falling back to
    blocking on the CQ (the paper's hybrid strategy, §4).
    """

    def __init__(self, tier: MemoryTier, ledger: TimeLedger, poll_budget: int = 1024,
                 host: str = "", start: bool = True,
                 retry_policy: Optional[RetryPolicy] = None):
        self.tier = tier
        self.ledger = ledger
        self.poll_budget = poll_budget
        self.arbiter = tier.arbiter_for(host)
        self.retry = retry_policy or DEFAULT_RETRY_POLICY
        # fixed engine seed: the injector's schedule decides WHICH ops fault,
        # so the backoff sequence is reproducible run-to-run regardless
        self._retry_rng = random.Random(0xA9E1)
        self._sq: "queue.PriorityQueue" = queue.PriorityQueue()
        self._seq = itertools.count()
        self._cq: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._pending_lock = threading.Lock()
        self._pending_ops = 0            # submitted, completion not yet queued
        self._worker: Optional[threading.Thread] = None
        self.stats = {"reads": 0, "busy_polls": 0, "event_waits": 0,
                      "urgent_reads": 0, "bytes_read": 0,
                      "injected_faults": 0, "retries": 0, "retry_exhausted": 0}
        if start:
            self.start()

    def start(self) -> None:
        """(Re)start the worker thread; a no-op while it is running — a
        host-wide server parks its engine when idle and restarts it here."""
        if self._worker is not None and self._worker.is_alive():
            return
        self._stop.clear()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def submit_read(self, pool_off: int, nbytes: int, buf: np.ndarray, token,
                    urgent: bool = False, charge: bool = True,
                    ledger: Optional[TimeLedger] = None) -> None:
        """Post a one-sided read of `nbytes` at `pool_off` into `buf`.

        ``urgent`` reads (demand faults) are served before queued prefetch
        extents.  ``charge=False`` suppresses the per-op ledger charge for
        callers that account a whole doorbell batch themselves.  ``ledger``
        routes the per-op charge to a specific session's ledger when one
        engine is shared by many sessions (NodePageServer)."""
        prio = 0 if urgent else 1
        with self._pending_lock:
            self._pending_ops += 1
        self._sq.put((prio, next(self._seq),
                      (pool_off, nbytes, buf, token, charge, ledger)))

    def quiesce(self, timeout_s: float = 5.0) -> bool:
        """Wait until every submitted read has executed and its completion
        is queued on the CQ (the CQ itself may still hold entries)."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            with self._pending_lock:
                if self._pending_ops == 0:
                    return True
            time.sleep(0.002)
        return False

    def poll_completion(self, block: bool, timeout_s: float = 0.05):
        """-> (buf, token) or None. Emulates CQ poll / completion channel.

        ``event_waits`` counts only actual blocking waits: a CQ entry that is
        already available is returned immediately without inflating the stat."""
        try:
            return self._cq.get_nowait()
        except queue.Empty:
            if not block:
                return None
        self.stats["event_waits"] += 1
        try:
            return self._cq.get(timeout=timeout_s)
        except queue.Empty:
            return None

    def _execute_read(self, prio: int, pool_off: int, nbytes: int,
                      buf: np.ndarray, ledger: Optional[TimeLedger]) -> None:
        """One wire attempt plus bounded in-place retries (DESIGN.md §15).

        Retrying here is fan-out-aware by construction: a NodePageServer
        group-extent buffer serves every session in the group, so one retry
        covers the whole group instead of k per-session re-issues.  Every
        failed attempt is charged through the arbiter (the timed-out read
        occupied the wire) plus a seeded backoff; demand reads (prio 0) use
        the escalated backoff scale.  The injector's schedules are finite,
        so an exhausted budget escalates to a final blocking read — the
        ledger carries the full cost of every attempt either way."""
        led = ledger or self.ledger
        fi = getattr(self.tier, "fault_injector", None)
        attempt = 0
        while True:
            try:
                if fi is not None:
                    fi.check_read(self.tier.name, pool_off, nbytes,
                                  host_link=True)
                buf[:nbytes] = self.tier.buf[pool_off : pool_off + nbytes]
                if fi is not None:
                    fi.filter_read(self.tier.name, pool_off, nbytes,
                                   buf[:nbytes])
                    fi.check_completion(self.tier.name)
                return
            except TierFaultError:
                self.stats["injected_faults"] += 1
                led.add("rdma_retry", self.arbiter.charge(nbytes))
                if attempt >= self.retry.max_retries:
                    self.stats["retry_exhausted"] += 1
                    buf[:nbytes] = self.tier.buf[pool_off : pool_off + nbytes]
                    return
                self.stats["retries"] += 1
                led.add("retry_backoff",
                        self.retry.backoff_s(attempt, self._retry_rng,
                                             urgent=(prio == 0)))
                attempt += 1

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                prio, _seq, (pool_off, nbytes, buf, token, charge, ledger) = (
                    self._sq.get(timeout=0.05))
            except queue.Empty:
                continue
            self._execute_read(prio, pool_off, nbytes, buf, ledger)
            self.stats["reads"] += 1
            self.stats["bytes_read"] += nbytes
            if prio == 0:
                self.stats["urgent_reads"] += 1
            if charge:
                (ledger or self.ledger).add("rdma_read", self.arbiter.charge(nbytes))
            self._cq.put((buf, token))
            with self._pending_lock:
                self._pending_ops -= 1

    def close(self) -> None:
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=1.0)


class RestoreEngine:
    """Per-instance page server: run-coalesced hot pre-install + async cold
    demand-paging + optional background extent prefetch over the cold runs."""

    def __init__(
        self,
        reader: SnapshotReader,
        instance: Instance,
        rdma_engine: Optional[AsyncRDMAEngine] = None,
        buffer_pool: Optional[BufferPool] = None,
        scatter_fn: Optional[ScatterFn] = None,
        clock: Optional[Clock] = None,
        server=None,
        retry_policy: Optional[RetryPolicy] = None,
        retry_seed: int = 0,
        policy: Optional[PrefetchPolicy] = None,
    ):
        self.reader = reader
        self.instance = instance
        if scatter_fn is not None:
            # fused restore (kernels/snapshot_fuse): bind the snapshot's
            # publish-time checksum table (when the publish recorded one) so
            # the scatter that installs each batch also verifies it —
            # covers pre_install_hot, install_all_sync, demand/prefetch
            # installs AND the NodePageServer hot-chunk fan-out path, all of
            # which land in Instance.uffd_copy_batch
            table = (reader.page_checksums()
                     if hasattr(scatter_fn, "bind_checksums") else None)
            if table is not None:
                scatter_fn = scatter_fn.bind_checksums(table)
            self.instance.scatter_fn = scatter_fn
        if clock is not None:
            # route the engine's clock to the instance too: page waits
            # (wait_present) are the engine's only timed behaviour
            self.instance.clock = clock
        self.clock = clock or instance.clock
        self.ledger = instance.ledger
        self.rdma_engine = rdma_engine
        # host-wide page-serving runtime (repro.core.nodeserver): when set,
        # demand reads / prefetch / completions are multiplexed through the
        # shared per-host engine instead of private threads
        self.server = server
        self._group = None          # FanoutGroup, set by NodePageServer.attach
        # online hotness feedback: when set (NodePageServer.attach or the
        # Orchestrator's per-instance path), demand faults / prefetch hits /
        # guest touches are recorded into the snapshot's HeatMap as
        # TouchEvents carrying this engine as the sequence stream
        self.heat = None
        # cold-extent ordering seam (DESIGN.md §17): default policy for
        # start_prefetcher when the caller passes none
        self.policy = policy
        self.buffers = buffer_pool or BufferPool()
        self._rdma_arbiter = reader.rdma.arbiter_for(reader.view.host)
        self.link_keys: List[Tuple[object, object]] = []   # (arbiter, key)
        self._inflight: Dict[int, bool] = {}
        self._inflight_lock = threading.Lock()
        self._completion_thread: Optional[threading.Thread] = None
        self._prefetch_thread: Optional[threading.Thread] = None
        self._prefetch_sem: Optional[threading.Semaphore] = None
        self._stop = threading.Event()
        self.prefetch_stats = {"extents_posted": 0, "pages_installed": 0,
                               "doorbells": 0, "extents_skipped": 0}
        # fault handling (DESIGN.md §15): bounded retries with seeded
        # backoff, budgeted checksum repair, and breaker-driven degradation
        self.retry = retry_policy or DEFAULT_RETRY_POLICY
        self._retry_rng = random.Random(0x9E37 ^ int(retry_seed))
        self.retry_trace: List[float] = []
        self.repair_budget = 3
        self.repair_stats = {"checksum_mismatches": 0, "checksum_repairs": 0,
                             "quarantined": 0, "rematerialized": 0,
                             "repair_failures": 0,
                             "degraded_preinstalls": 0, "degraded_faults": 0}
        self.degraded_cxl = False
        self.repair_error: Optional[Exception] = None

    def _record_heat(self, pages, kind: str) -> None:
        """Typed telemetry: pages in touch order, this restore as the
        sequence stream (feeds the first-touch Markov model)."""
        if self.heat is not None:
            self.heat.record(TouchEvent(pages=pages, kind=kind,
                                        stream=id(self)))

    # -- phase 1: hot-set pre-installation (§3.4) ------------------------------
    HOT_CHUNK_PAGES = 256   # 1 MiB sequential CXL reads over the compact region

    def pre_install_hot(self, use_batch: bool = True,
                        chunk_pages: Optional[int] = None) -> int:
        """uffd.copy the hot set from CXL before resume. Serialized (§5.2).

        Batched mode (default) exploits the snapshot layout: the hot data
        region is *compacted by rank*, so it is one contiguous CXL byte range
        regardless of guest fragmentation.  We stream it in `chunk_pages`
        sequential reads (one CXL op-latency per chunk, not per page — and
        never worse than one per run) and scatter each chunk into the guest
        address space with one vectorized `uffd_copy_batch`, which charges
        one uffd.copy ioctl per guest-contiguous run.  ``use_batch=False``
        keeps the strictly page-at-a-time path for modeled-time comparison.

        With a fused scatter_fn (kernels/snapshot_fuse) each chunk install
        is one gather→checksum→scatter kernel whose input stream pipelines
        against the previous chunk's scatter (double-buffered grid), and is
        verified against publish-time checksums when the reader carries them.
        """
        if not use_batch:
            hot = self.reader.hot_page_indices()
            for page in hot:
                kind, off = self.reader.lookup(int(page))
                assert kind == "cxl"
                src = self.reader.cxl_read(off, PAGE_SIZE)
                if self.instance.uffd_copy(int(page), src):
                    self.instance.stats["pre_installed"] += 1
            return int(hot.size)
        ht = self.reader.cxl_health()
        if ht is not None and not ht.allow():
            # CXL host link browned out (§15): skip the bulk pre-install
            # entirely — hot pages demand-fault through the degraded
            # RDMA-only path (drain_degraded_hot), matching the modeled
            # all-cold restore shape instead of failing the restore
            self.degraded_cxl = True
            self.repair_stats["degraded_preinstalls"] += 1
            return 0
        chunk = chunk_pages or self.HOT_CHUNK_PAGES
        n_hot = 0
        # extent walk (snapshot.iter_hot_extents): contiguous-region chunks
        # for the private layout, adjacent-store-offset runs for dedup —
        # either way each extent is ONE sequential CXL read
        for pages, pool_off, nbytes in self.reader.iter_hot_extents(chunk):
            if self.instance.present[pages].all():
                n_hot += int(pages.size)
                continue    # already installed (e.g. repeated pre-install)
            try:
                if self.server is not None:
                    # hot-chunk fan-out: co-located same-snapshot restores
                    # share one physical chunk read (one CXL read, k
                    # scatters); dedup chunks are content-keyed, so
                    # different VARIANTS share too
                    raw = self.server.hot_chunk(self, pool_off, nbytes)
                else:
                    raw = call_with_retries(
                        lambda o=pool_off, n=nbytes: self.reader.view.read(o, n),
                        policy=self.retry, rng=self._retry_rng,
                        ledger=self.ledger, clock=self.clock,
                        trace=self.retry_trace)
            except TierFaultError as e:
                if ht is None:
                    raise
                ht.record_failure(hard=(e.kind == "brownout"))
                if not ht.allow():
                    # breaker tripped mid-walk: remaining hot pages take
                    # the degraded demand path instead of failing
                    self.degraded_cxl = True
                    self.repair_stats["degraded_preinstalls"] += 1
                    return n_hot
                raise
            if ht is not None:
                ht.record_success()
            n_hot += int(pages.size)
            mat = raw.reshape(-1, PAGE_SIZE)
            if pages.size > 1 and np.any(np.diff(pages) < 0):
                # dedup extents visit pages in store-offset order: scatter
                # wants them guest-sorted (one uffd range per guest run)
                order = np.argsort(pages, kind="stable")
                pages, mat = pages[order], mat[order]
            installed = self._install_verified(pages, mat)
            self.instance.stats["pre_installed"] += installed
        return n_hot

    def drain_degraded_hot(self) -> int:
        """Demand-install the hot pages a degraded pre-install skipped (the
        RDMA-only all-cold path); no-op when the restore was not degraded."""
        if not self.degraded_cxl:
            return 0
        n = 0
        for page in self.reader.hot_page_indices():
            if not self.instance.present[page]:
                self.handle_fault(int(page))
                n += 1
        return n

    # -- checksum repair (DESIGN.md §15) -----------------------------------
    @staticmethod
    def _is_fault(err: BaseException) -> bool:
        """A recoverable serving fault: injected tier fault or a checksum
        mismatch (any error carrying a structured ``bad_pages`` array)."""
        return (isinstance(err, TierFaultError)
                or getattr(err, "bad_pages", None) is not None)

    def _install_verified(self, pages: np.ndarray, mat: np.ndarray) -> int:
        """Install a batch; on checksum mismatch, repair instead of abort.

        The bound scatter kernel raises with the guest indices of the bad
        pages; the good subset re-installs immediately and each bad page is
        re-read from its home tier under :attr:`repair_budget`.  Only an
        exhausted budget surfaces the error."""
        pages = np.asarray(pages, dtype=np.int64).reshape(-1)
        try:
            return self.instance.uffd_copy_batch(pages, mat)
        except RuntimeError as err:
            bad = getattr(err, "bad_pages", None)
            if bad is None:
                raise
            return self._repair_batch(pages, mat, bad)

    def _repair_batch(self, pages: np.ndarray, mat: np.ndarray,
                      bad_pages) -> int:
        mat = np.ascontiguousarray(mat).view(np.uint8).reshape(
            pages.size, PAGE_SIZE)
        bad = {int(p) for p in np.atleast_1d(np.asarray(bad_pages))}
        self.repair_stats["checksum_mismatches"] += len(bad)
        good = np.array([i for i, p in enumerate(pages) if int(p) not in bad],
                        dtype=np.int64)
        n = 0
        if good.size:
            n += self.instance.uffd_copy_batch(pages[good], mat[good])
        for p in sorted(bad):
            n += self._repair_page(int(p))
        return n

    def _reread_home(self, page: int, kind: str, off: int) -> np.ndarray:
        """Budgeted re-read from the page's home tier, charged like a fresh
        demand read (repair is not free).  The CXL re-read goes through the
        owner-path tier read, bypassing the host line cache (which may hold
        the poisoned line)."""
        if kind == "cxl":
            row = self.reader.view.tier.read(off, PAGE_SIZE)
            self.ledger.add("cxl_read",
                            self.reader.view.arbiter.charge(PAGE_SIZE))
            return row
        if kind == "rdma_z":
            pool_off, nbytes, raw = self.reader.cold_extent(off)
            payload = self.reader.rdma.read(pool_off, nbytes)
            self.ledger.add("rdma_read", self._rdma_arbiter.charge(nbytes))
            return self.reader.decompress_page(payload, raw)
        row = self.reader.rdma.read(off, PAGE_SIZE)
        self.ledger.add("rdma_read", self._rdma_arbiter.charge(PAGE_SIZE))
        return row

    def _repair_page(self, page: int) -> int:
        """Re-read one checksum-bad page from its home tier until it
        verifies, quarantining a persistently-bad shared dedup offset so no
        new snapshot rides it, then re-materializing it once a clean copy is
        in hand (the single-page analogue of ``reconstruct_image``)."""
        kind, off = self.reader.lookup(page)
        store = None
        if self.reader.regions.dedup and kind in ("cxl", "rdma"):
            tier = self.reader.view.tier if kind == "cxl" else self.reader.rdma
            store = getattr(tier, "dedup_store", None)
        quarantined = False
        last_err: Optional[Exception] = None
        for _attempt in range(self.repair_budget):
            try:
                row = self._reread_home(page, kind, off)
            except TierFaultError as e:
                last_err = e
                continue
            try:
                n = self.instance.uffd_copy_batch(
                    np.array([page], dtype=np.int64), row)
            except RuntimeError as err:
                if getattr(err, "bad_pages", None) is None:
                    raise
                last_err = err
                if store is not None and not quarantined:
                    # the shared store offset itself is corrupt: bar it from
                    # new sharing before anyone else rides it (refcounts are
                    # untouched, so invariant I6 holds)
                    quarantined = store.quarantine(off)
                    if quarantined:
                        self.repair_stats["quarantined"] += 1
                continue
            self.repair_stats["checksum_repairs"] += 1
            if quarantined:
                # this re-read verified clean: scrub the store offset and
                # put it back into circulation
                store.rematerialize(off, row)
                self.repair_stats["rematerialized"] += 1
            return n
        self.repair_stats["repair_failures"] += 1
        self.repair_error = last_err
        raise last_err  # exhausted repair budget: surface the error

    def _degraded_cxl_fault(self, page: int, off: int) -> None:
        """Serve a hot-page demand fault while the CXL breaker is open: the
        bytes come over the RDMA fabric (charged at the RDMA demand shape by
        ``SnapshotReader.degraded_cxl_read``) instead of failing."""
        data = self.reader.degraded_cxl_read(off, PAGE_SIZE)
        self.repair_stats["degraded_faults"] += 1
        self._install_verified(np.array([page], dtype=np.int64), data)

    def install_zero_runs(self) -> int:
        """uffd.zeropage the zero runs (one ioctl per run); full-restore
        helper used by benchmarks and the node-server restore flow."""
        k = 0
        for start, n in self.reader.zero_runs():
            k += self.instance.uffd_zeropage_range(int(start), int(n))
        return k

    # -- phase 2: demand faults -------------------------------------------------
    def start_completion_handler(self) -> None:
        if self.rdma_engine is None:
            return
        self._completion_thread = threading.Thread(target=self._completion_loop, daemon=True)
        self._completion_thread.start()

    def start_prefetcher(self, max_extent_pages: Optional[int] = None,
                         policy: Optional[PrefetchPolicy] = None) -> None:
        """Background cold-extent prefetch in ``policy`` order.

        The :class:`~repro.core.prefetch_model.PrefetchPolicy` is the only
        ordering seam: the default :class:`LayoutOrderPolicy` walks cold
        runs largest-first exactly as before; ``PredictedOrderPolicy``
        fetches by predicted next-touch.  Demand faults for pages not yet
        in flight still take priority on the RDMA engine's submit queue.
        (``max_extent_pages=N`` is the deprecated pre-policy spelling of
        ``LayoutOrderPolicy(N)``.)

        Under a NodePageServer the extents are enqueued ONCE per fan-out
        group on the host-wide pump, which drains them round-robin across
        all co-located restores instead of spawning a private thread."""
        if policy is None and max_extent_pages is None \
                and self.policy is not None:
            policy = self.policy
        policy = resolve_policy(policy, max_extent_pages,
                                "RestoreEngine.start_prefetcher")
        if self.server is not None:
            self.server.enqueue_prefetch(self, policy=policy)
            return
        if self.rdma_engine is None or self._prefetch_thread is not None:
            return
        inflight = max(1, self.rdma_engine.tier.cost.max_inflight)
        self._prefetch_sem = threading.Semaphore(inflight)
        self._prefetch_thread = threading.Thread(
            target=self._prefetch_loop, args=(policy,), daemon=True)
        self._prefetch_thread.start()

    def stop(self) -> None:
        """Stop serving and leave no residue: in-flight completions are
        drained (their demand-read buffers go back to the BufferPool, their
        pages install normally) and stale ``_inflight`` entries are cleared.
        Node-server sessions detach from the shared runtime instead."""
        self._stop.set()
        if self.heat is not None:
            self.heat.end_stream(id(self))
        if self.server is not None:
            self.server.detach(self)
            self._unregister_links()
            return
        if self._prefetch_thread is not None:
            self._prefetch_thread.join(timeout=1.0)
        if self.rdma_engine is not None:
            # let already-posted reads execute so their buffers come back
            self.rdma_engine.quiesce()
        if self._completion_thread is not None:
            self._completion_thread.join(timeout=1.0)
        if self.rdma_engine is not None:
            while True:
                item = self.rdma_engine.poll_completion(block=False)
                if item is None:
                    break
                self._install_completion(*item)
        with self._inflight_lock:
            self._inflight.clear()
        self._unregister_links()

    def _unregister_links(self) -> None:
        for arbiter, key in self.link_keys:
            arbiter.unregister(key)
        self.link_keys = []

    def handle_fault(self, page: int) -> None:
        """userfaultfd fault for `page`; never blocks on RDMA (§3.4)."""
        if self.instance.present[page]:
            return
        kind, off = self.reader.lookup(page)
        if kind == "zero":
            self.instance.stats["fault_zero"] += 1
            self.instance.uffd_zeropage(page)
            return
        if kind == "cxl":
            self.instance.stats["fault_cxl"] += 1
            self._record_heat([page], "touch")
            ht = self.reader.cxl_health()
            if ht is not None and not ht.allow():
                self._degraded_cxl_fault(page, off)
                return
            try:
                src = call_with_retries(
                    lambda: self.reader.view.read(off, PAGE_SIZE),
                    policy=self.retry, rng=self._retry_rng,
                    ledger=self.ledger, clock=self.clock, urgent=True,
                    trace=self.retry_trace)
            except TierFaultError as e:
                if ht is None:
                    raise
                # a blocked guest vCPU cannot wait out the link: record the
                # failure and serve the page over RDMA right now
                ht.record_failure(hard=(e.kind == "brownout"))
                self._degraded_cxl_fault(page, off)
                return
            if ht is not None:
                ht.record_success()
            self._install_verified(np.array([page], dtype=np.int64), src)
            return
        # cold page → async RDMA read (optionally zstd per-page)
        self.instance.stats["fault_rdma"] += 1
        if kind == "rdma_z":
            pool_off, nbytes, raw = self.reader.cold_extent(off)
        else:
            pool_off, nbytes, raw = off, PAGE_SIZE, True
        if self.rdma_engine is None and self.server is None:
            self._record_heat([page], "demand_fault")
            payload = call_with_retries(
                lambda: self.reader.rdma.read(pool_off, nbytes),
                policy=self.retry, rng=self._retry_rng,
                ledger=self.ledger, clock=self.clock, urgent=True,
                trace=self.retry_trace)
            self.ledger.add("rdma_read", self._rdma_arbiter.charge(nbytes))
            self._install_verified(
                np.array([page], dtype=np.int64),
                self.reader.decompress_page(payload, raw)
                if kind == "rdma_z" else payload)
            return
        with self._inflight_lock:
            covered = bool(self._inflight.get(page))
            if not covered:
                self._inflight[page] = True
        # a fault landing on an in-flight prefetch extent is a prefetch
        # hit: the page is clearly part of the live working set, but the
        # demand-path latency was (partially) hidden
        self._record_heat([page],
                          "prefetch_hit" if covered else "demand_fault")
        if covered:
            return     # already in flight (demand or prefetch extent)
        buf = self.buffers.acquire()
        if self.server is not None:
            self.server.submit_demand(self, pool_off, nbytes, buf,
                                      (page, nbytes, raw, kind))
        else:
            self.rdma_engine.submit_read(pool_off, nbytes, buf,
                                         ("page", page, nbytes, raw, kind),
                                         urgent=True)

    def access(self, page: int, timeout_s: float = 30.0) -> None:
        """Guest touch: fault if needed and wait for install (test/replay API)."""
        if self.instance.present[page]:
            self._record_heat([page], "touch")
            return
        self.handle_fault(page)
        if not self.instance.wait_present(page, timeout_s):
            raise TimeoutError(f"page {page} not installed within {timeout_s}s")

    def touch_pages(self, pages, timeout_s: float = 30.0) -> Dict[str, int]:
        """Replay one invocation's guest touches (batch form of :meth:`access`).

        Already-present pages (hot pre-installed or prefetched) are recorded
        as heat `touch`es in ONE vectorized record; the rest go through the
        fault path, which reports its own demand-fault / prefetch-hit
        telemetry.  Returns {"present": ..., "faulted": ...}.
        """
        pages = np.asarray(pages, dtype=np.int64).reshape(-1)
        if pages.size == 0:
            return {"present": 0, "faulted": 0}
        present_mask = self.instance.present[pages]
        hit = pages[present_mask]
        if hit.size:
            self._record_heat(hit, "touch")
        missing = pages[~present_mask]
        for p in missing:
            if not self.instance.present[p]:
                self.handle_fault(int(p))
        for p in missing:
            if not self.instance.wait_present(int(p), timeout_s):
                raise TimeoutError(f"page {int(p)} not installed within {timeout_s}s")
        return {"present": int(present_mask.sum()), "faulted": int(missing.size)}

    def _install_completion(self, buf: np.ndarray, token) -> None:
        if token[0] == "extent":
            _tag, start, n, rank0 = token
            try:
                mat = self.reader.split_cold_extent(rank0, n, buf)
                k = self._install_verified(np.arange(start, start + n), mat)
                self.prefetch_stats["pages_installed"] += k
            except RuntimeError as e:
                # completion-thread context: an exhausted repair budget
                # cannot raise into the guest — record it (waiters observe
                # the absent page via ``repair_error``)
                if not self._is_fault(e):
                    raise
                self.repair_error = e
            finally:
                with self._inflight_lock:
                    for p in range(start, start + n):
                        self._inflight.pop(p, None)
                if self._prefetch_sem is not None:
                    self._prefetch_sem.release()
            return
        _tag, page, nbytes, raw, kind = token
        try:
            data = (self.reader.decompress_page(buf[:nbytes], raw)
                    if kind == "rdma_z" else buf[:PAGE_SIZE])
            self._install_verified(np.array([int(page)], dtype=np.int64), data)
        except RuntimeError as e:
            if not self._is_fault(e):
                raise
            self.repair_error = e
        finally:
            self.buffers.release(buf)
            with self._inflight_lock:
                self._inflight.pop(int(page), None)

    def _completion_loop(self) -> None:
        eng = self.rdma_engine
        assert eng is not None
        while not self._stop.is_set():
            item = eng.poll_completion(block=True)
            if item is None:
                continue
            while item is not None:
                buf, token = item
                self._install_completion(buf, token)
                # hybrid poll: batch further completions without sleeping
                polled = None
                for _ in range(eng.poll_budget):
                    polled = eng.poll_completion(block=False)
                    if polled is not None:
                        eng.stats["busy_polls"] += 1
                        break
                item = polled

    # -- cold extent prefetcher (§3.4, DESIGN.md §6, §17) ----------------------
    def _prefetch_loop(self, policy: PrefetchPolicy) -> None:
        eng = self.rdma_engine
        assert eng is not None and self._prefetch_sem is not None
        cost = eng.tier.cost
        pending_bytes, pending_ops = 0, 0

        def flush_doorbell():
            nonlocal pending_bytes, pending_ops
            if pending_ops:
                # doorbell-batched posts: op latencies overlap up to QP depth;
                # the link arbiter floors the charge at this session's fair
                # share of the RNIC when co-located restores contend
                self.ledger.add("rdma_prefetch",
                                eng.arbiter.charge_pipelined(pending_bytes, pending_ops))
                self.prefetch_stats["doorbells"] += 1
                pending_bytes, pending_ops = 0, 0

        for es, en, rank0, pool_off, nbytes in policy.order_extents(self, None):
            if self._stop.is_set():
                flush_doorbell()
                return
            if self.instance.present[es : es + en].all():
                self.prefetch_stats["extents_skipped"] += 1
                continue
            while not self._prefetch_sem.acquire(timeout=0.05):
                if self._stop.is_set():
                    flush_doorbell()
                    return
            # mark in flight only once a QP slot is held: demand faults on
            # these pages must keep their urgent-read path while the
            # extent is still waiting for a slot
            with self._inflight_lock:
                for p in range(es, es + en):
                    self._inflight.setdefault(p, True)
            pending_bytes += nbytes
            pending_ops += 1
            if pending_ops >= cost.max_inflight:
                flush_doorbell()
            buf = np.empty(nbytes, dtype=np.uint8)
            eng.submit_read(pool_off, nbytes, buf, ("extent", es, en, rank0),
                            urgent=False, charge=False)
            self.prefetch_stats["extents_posted"] += 1
        flush_doorbell()

    def wait_prefetch_idle(self, timeout_s: float = 30.0) -> bool:
        """Block until the prefetch walk posted everything and all cold pages
        are installed (test/benchmark helper).

        Vectorized: ONE condition-variable wait on a predicate over the
        `present` bitmap sliced by the cold page index — no per-page Python
        loop, and no per-page lock/notify round trips."""
        if self.server is not None:
            if self._group is None or not getattr(self._group, "enqueued", False):
                return True
        elif self._prefetch_thread is None:
            return True
        else:
            self._prefetch_thread.join(timeout=timeout_s)
            if self._prefetch_thread.is_alive():
                return False
        cold = self.reader.cold_page_indices()
        if cold.size == 0:
            return True
        present = self.instance.present
        with self.instance._cv:
            return self.instance.clock.cv_wait_for(
                self.instance._cv, lambda: bool(present[cold].all()), timeout_s)

    # -- bulk restore (used by tests / eager baselines) --------------------------
    def install_all_sync(self, use_batch: bool = True) -> None:
        if not use_batch:
            for page in range(self.instance.image.total_pages):
                if not self.instance.present[page]:
                    kind, off = self.reader.lookup(page)
                    if kind == "zero":
                        self.instance.uffd_zeropage(page)
                    elif kind == "cxl":
                        self.instance.uffd_copy(page, self.reader.cxl_read(off, PAGE_SIZE))
                    else:
                        nbytes = (self.reader.cold_extent(off)[1]
                                  if kind == "rdma_z" else PAGE_SIZE)
                        self.ledger.add("rdma_read", self._rdma_arbiter.charge(nbytes))
                        self.instance.uffd_copy(page, self.reader.read_page(page))
            return
        for start, n in self.reader.zero_runs():
            self.instance.uffd_zeropage_range(int(start), int(n))
        self.pre_install_hot()
        self.drain_degraded_hot()
        if self.reader.regions.dedup:
            # dedup cold pages are not rank-compacted: walk the dual-
            # contiguous extents (split only at store discontinuities)
            for es, en, _rank0, pool_off, nbytes in self.reader.iter_cold_extents(
                    max_extent_pages=1 << 30):
                payload = call_with_retries(
                    lambda o=pool_off, b=nbytes: self.reader.rdma.read(o, b),
                    policy=self.retry, rng=self._retry_rng,
                    ledger=self.ledger, clock=self.clock,
                    trace=self.retry_trace)
                self.ledger.add("rdma_read", self._rdma_arbiter.charge(nbytes))
                self._install_verified(np.arange(es, es + en),
                                       payload.reshape(en, PAGE_SIZE))
            return
        for start, n in self.reader.cold_runs():
            start, n = int(start), int(n)
            rank0 = self.reader.cold_rank(start)
            pool_off, nbytes = self.reader.cold_extent_span(rank0, n)
            payload = call_with_retries(
                lambda o=pool_off, b=nbytes: self.reader.rdma.read(o, b),
                policy=self.retry, rng=self._retry_rng,
                ledger=self.ledger, clock=self.clock,
                trace=self.retry_trace)
            self.ledger.add("rdma_read", self._rdma_arbiter.charge(nbytes))
            self._install_verified(np.arange(start, start + n),
                                   self.reader.split_cold_extent(rank0, n, payload))


# The restore engine IS the paper's per-instance "restore session"; the
# simulator and some call sites use that name.
RestoreSession = RestoreEngine


def mmap_install_cost(pages: Sequence[int]) -> float:
    """Modeled cost of installing `pages` via per-range mmap (the rejected
    alternative, §2.3.4): one mmap syscall per contiguous run plus a per-page
    cost 2.6x that of uffd.copy."""
    runs = runs_from_pages(pages)
    return sum(n * MMAP_PER_PAGE_S for _, n in runs) + len(runs) * MMAP_SYSCALL_S
