"""Train step builder: loss (CE + z-loss + MoE aux + optional MTP), grads,
AdamW — shard-ready (pure function of (state, batch) for pjit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..models.model_zoo import Model
from ..models import transformer as tf_mod
from ..sharding.partition import constrain
from .optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw


class TrainState(NamedTuple):
    """Everything a train step carries forward (params + optimizer)."""

    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class LossConfig:
    """Auxiliary-loss weights layered onto the cross-entropy objective."""

    z_loss: float = 1e-4
    aux_weight: float = 0.01     # MoE load-balance loss
    mtp_weight: float = 0.3      # DeepSeek-V3 MTP objective weight


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 0.0) -> jnp.ndarray:
    """Mean next-token CE in f32 with optional z-loss; logits (B,S,V).

    The label logit is extracted with a one-hot contraction rather than
    take_along_axis: with vocab-parallel logits (V sharded over 'model') the
    contraction keeps every operand sharded and reduces with a partial-sum +
    all-reduce, instead of all-gathering the (B,S,V) logits.
    """
    logits = constrain(logits, ("pod", "data"), None, "model")
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * (lse ** 2).mean()
    return loss


def make_loss_fn(model: Model, loss_cfg: LossConfig = LossConfig()) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch) -> Tuple[jnp.ndarray, Dict]:
        labels = batch["labels"]
        if cfg.family == "moe" and cfg.mtp:
            logits, mtp_logits, aux = tf_mod.lm_forward_mtp(params, batch["tokens"], cfg)
            # shift-1 main objective
            loss = cross_entropy(logits[:, :-1], labels[:, 1:], loss_cfg.z_loss)
            # MTP predicts t+2
            mtp = cross_entropy(mtp_logits[:, :-2], labels[:, 2:], 0.0)
            loss = loss + loss_cfg.mtp_weight * mtp + loss_cfg.aux_weight * aux
            return loss, {"aux": aux, "mtp": mtp}
        logits, aux = model.forward(params, batch)
        loss = cross_entropy(logits[:, :-1], labels[:, 1:], loss_cfg.z_loss)
        if cfg.family == "moe":
            loss = loss + loss_cfg.aux_weight * aux
        return loss, {"aux": aux}

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig = AdamWConfig(),
                    loss_cfg: LossConfig = LossConfig(),
                    grad_transform: Callable = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(model, loss_cfg)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        (loss, extra), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, om = adamw_update(
            opt_cfg, grads, state.opt, state.params, grad_transform
        )
        metrics = {"loss": loss, **{k: v for k, v in extra.items()}, **om}
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, init_adamw(params))
