"""Training loop with Aquifer fault tolerance.

Features exercised by tests/examples:
  * periodic async checkpoint publish (non-blocking: snapshot build happens
    on a background thread over a host copy of the state);
  * crash/restart recovery: on start, the loop tries to borrow the latest
    snapshot and resumes from its step counter (data pipeline skip-ahead is
    O(1), so the restored run replays the exact batch stream);
  * straggler-tolerant restore: compute restarts on the hot set (params)
    while optimizer moments stream in.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..core import HierarchicalPool, Orchestrator, PoolMaster
from ..checkpoint.ckpt import restore_checkpoint, save_checkpoint
from ..data.pipeline import SyntheticLMData
from ..models.model_zoo import Model
from .trainstep import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    """Training-loop schedule (total steps and checkpoint cadence)."""

    steps: int = 50
    ckpt_every: int = 20
    ckpt_name: str = "train-ckpt"
    async_checkpoint: bool = True
    log_every: int = 10


class Trainer:
    """Runs the jitted train step over the data stream, checkpointing."""

    def __init__(
        self,
        model: Model,
        data: SyntheticLMData,
        master: Optional[PoolMaster] = None,
        orch: Optional[Orchestrator] = None,
        loop_cfg: LoopConfig = LoopConfig(),
        train_step: Optional[Callable] = None,
        seed: int = 0,
    ):
        self.model = model
        self.data = data
        pool = master.pool if master else HierarchicalPool()
        self.master = master or PoolMaster(pool)
        self.orch = orch or Orchestrator("trainer-host", self.master.pool, self.master.catalog)
        self.loop_cfg = loop_cfg
        self.train_step = jax.jit(train_step or make_train_step(model))
        self.seed = seed
        self.metrics_log: List[Dict] = []
        self._ckpt_thread: Optional[threading.Thread] = None
        self.ckpt_stats: List[Dict] = []

    # -- checkpointing -------------------------------------------------------
    def _publish(self, state_host, step: int) -> None:
        _, stats = save_checkpoint(
            self.master, self.loop_cfg.ckpt_name,
            {"params": state_host.params, "opt": state_host.opt}, step,
        )
        stats["step"] = step
        self.ckpt_stats.append(stats)

    def checkpoint(self, state: TrainState, step: int, block: bool = False) -> None:
        state_host = jax.tree.map(np.asarray, state)  # device→host copy
        if self.loop_cfg.async_checkpoint and not block:
            if self._ckpt_thread is not None:
                self._ckpt_thread.join()
            self._ckpt_thread = threading.Thread(
                target=self._publish, args=(state_host, step), daemon=True
            )
            self._ckpt_thread.start()
        else:
            self._publish(state_host, step)

    def try_restore(self, template: TrainState):
        """-> (state, start_step) — cold init if no snapshot is published."""
        try:
            restored, stats = restore_checkpoint(
                self.orch, self.loop_cfg.ckpt_name,
                {"params": template.params, "opt": template.opt},
            )
            state = TrainState(restored["params"], restored["opt"])
            return state, int(stats["meta"]["step"]), stats
        except FileNotFoundError:
            return template, 0, None

    # -- main loop -------------------------------------------------------------
    def run(self, state: Optional[TrainState] = None, resume: bool = False):
        if state is None:
            state = init_train_state(self.model, jax.random.PRNGKey(self.seed))
        start = 0
        if resume:
            state, start, rstats = self.try_restore(state)
            if rstats:
                self.metrics_log.append({"event": "restored", "step": start, **{
                    k: rstats[k] for k in ("time_to_hot_s", "time_to_full_s")}})
        t0 = time.perf_counter()
        for step in range(start, self.loop_cfg.steps):
            batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch_at(step).items()}
            state, metrics = self.train_step(state, batch)
            if step % self.loop_cfg.log_every == 0 or step == self.loop_cfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, wall_s=time.perf_counter() - t0)
                self.metrics_log.append(m)
            if self.loop_cfg.ckpt_every and (step + 1) % self.loop_cfg.ckpt_every == 0:
                self.checkpoint(state, step + 1)
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        return state
