"""AdamW + gradient clipping + LR schedules, from scratch (no optax).

Optimizer state is a pytree mirroring params (f32 moments), so the same
partition specs apply — ZeRO-style sharded optimizer state falls out of the
param sharding rules for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    """AdamW optimizer state (step counter plus moment pytrees)."""

    step: jnp.ndarray          # int32 scalar
    m: Any                     # first moment (pytree like params)
    v: Any                     # second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    """AdamW hyperparameters (learning rate, betas, weight decay)."""

    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params,
                 grad_transform: Callable = None):
    """-> (new_params, new_state, metrics). Decoupled weight decay; decay is
    skipped for 1-D leaves (norm scales, biases, gate vectors)."""
    if grad_transform is not None:
        grads = grad_transform(grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim > 1:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
