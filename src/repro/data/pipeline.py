"""Deterministic, shardable synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard) via counter-based RNG
(Philox), which gives the properties a 1000+-node training fleet needs:

* **restart tolerance** — a restored worker regenerates exactly the batch
  stream it would have seen (skip-ahead is O(1), no state to checkpoint
  beyond the step counter, which Aquifer snapshots anyway);
* **elastic resharding** — shards are pure index math, so changing the
  data-parallel degree re-partitions the same global stream;
* **straggler decoupling** — no ordered queue between hosts.

Token stream: a mixture of Zipfian unigrams and short Markov motifs, enough
structure for the loss to fall measurably during the e2e example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """Synthetic LM data-stream parameters (vocab, geometry, seed)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3


class SyntheticLMData:
    """Deterministic sharded token-batch generator for training runs."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=self.cfg.seed, counter=[step, self.shard, 0, 0])
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The shard-local batch for `step` (O(1) skip-ahead)."""
        cfg = self.cfg
        rng = self._rng(step)
        b, s = self.local_batch, cfg.seq_len
        # Zipf unigrams clipped to vocab
        toks = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        toks = np.minimum(toks - 1, cfg.vocab - 1)
        # overlay motifs: each sequence repeats a short pattern at random slots
        motif_len = 8
        motif = rng.integers(0, cfg.vocab, size=(b, motif_len))
        starts = rng.integers(0, max(1, s - motif_len), size=(b, 4))
        for i in range(b):
            for st in starts[i]:
                toks[i, st : st + motif_len] = motif[i]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
