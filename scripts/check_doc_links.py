#!/usr/bin/env python3
"""Fail if docs name repo paths that no longer exist (CI docs gate).

Scans the docs listed in ``DOCS`` for
  * backticked repo paths   — `src/repro/core/pool.py`, `tests/`, ...
  * dotted module names     — `repro.sim.cluster`, `benchmarks.fleet_bench`
  * relative markdown links — [DESIGN.md](../DESIGN.md)

and exits non-zero listing every reference whose target is missing, so a
rename/delete that leaves ARCHITECTURE.md stale fails CI instead of
rotting silently.

Usage: python scripts/check_doc_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

DOCS = [
    REPO / "docs" / "ARCHITECTURE.md",
    REPO / "docs" / "OPERATIONS.md",
]

# top-level roots a backticked token must start with to count as a path
PATH_ROOTS = ("src/", "tests/", "benchmarks/", "examples/", "experiments/",
              "scripts/", "docs/")

BACKTICK_RE = re.compile(r"`([^`\n]+)`")
MDLINK_RE = re.compile(r"\[[^\]]+\]\(([^)#\s]+)\)")
DOTTED_RE = re.compile(r"^(repro|benchmarks)(\.[A-Za-z_][A-Za-z0-9_]*)+$")


def module_path(dotted: str) -> list[Path]:
    """Candidate file locations for a dotted module name."""
    parts = dotted.split(".")
    if parts[0] == "repro":
        base = REPO / "src" / Path(*parts)
    else:
        base = REPO / Path(*parts)
    return [base.with_suffix(".py"), base / "__init__.py"]


def check_doc(doc: Path) -> list[str]:
    text = doc.read_text()
    rel = doc.relative_to(REPO)
    missing: list[str] = []

    for tok in BACKTICK_RE.findall(text):
        tok = tok.strip()
        if any(c in tok for c in "*<{"):  # glob / placeholder, not a path
            continue
        if tok.startswith(PATH_ROOTS) and " " not in tok:
            target = REPO / tok.rstrip("/")
            if not target.exists():
                missing.append(f"{rel}: stale path `{tok}`")
        elif DOTTED_RE.match(tok):
            if not any(p.exists() for p in module_path(tok)):
                missing.append(f"{rel}: stale module `{tok}`")

    for link in MDLINK_RE.findall(text):
        if "://" in link:  # external URL — not checked
            continue
        target = (doc.parent / link).resolve()
        if not target.exists():
            missing.append(f"{rel}: broken link ({link})")

    return missing


def main() -> int:
    missing: list[str] = []
    for doc in DOCS:
        if not doc.exists():
            missing.append(f"missing doc: {doc.relative_to(REPO)}")
            continue
        missing.extend(check_doc(doc))
    if missing:
        print("check_doc_links: FAIL")
        for m in missing:
            print(f"  {m}")
        return 1
    print(f"check_doc_links: OK ({len(DOCS)} docs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
