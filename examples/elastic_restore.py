"""Elastic scaling: checkpoint under one mesh, restore re-sharded under
another, and keep training — the snapshot's offset-array indirection makes
pages location-independent, so the restore path is mesh-agnostic.

    PYTHONPATH=src python examples/elastic_restore.py [--quick]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import HierarchicalPool, Orchestrator, PoolMaster
from repro.checkpoint.ckpt import restore_checkpoint, reshard, save_checkpoint
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models.model_zoo import build
from repro.sharding.partition import param_specs
from repro.train.trainstep import TrainState, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller arch/batch, 2+2 steps (CI smoke)")
    args = ap.parse_args(argv)
    arch = "xlstm-125m" if args.quick else "qwen2.5-14b"
    n_steps = 2 if args.quick else 5

    cfg = get_config(arch).reduced(vocab=512)
    model = build(cfg)
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=32 if args.quick else 64,
                                      global_batch=4 if args.quick else 8))
    step = jax.jit(make_train_step(model))

    # phase 1: "big mesh" run (this container has one device; the mesh
    # plumbing is identical — the dry-run proves the 256/512-chip variants)
    state = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(n_steps):
        state, m = step(state, {k: jnp.asarray(v) for k, v in data.batch_at(i).items()})
    print(f"phase1 loss={float(m['loss']):.3f} — checkpointing")

    pool = HierarchicalPool(1 << 30, 2 << 30)
    master = PoolMaster(pool)
    save_checkpoint(master, "elastic", {"params": state.params, "opt": state.opt},
                    step=n_steps)

    # phase 2: restore on a DIFFERENT mesh ("scale-down" re-shard)
    orch = Orchestrator("new-fleet-host", pool, master.catalog)
    restored, stats = restore_checkpoint(
        orch, "elastic", {"params": state.params, "opt": state.opt})
    mesh = make_host_mesh(1, 1)
    placed = reshard(restored["params"], mesh, param_specs(restored["params"]))
    print(f"restored step={stats['meta']['step']} and re-sharded onto "
          f"mesh {dict(mesh.shape)} — time-to-hot={stats['time_to_hot_s']*1e3:.1f}ms")

    state2 = TrainState(placed, restored["opt"])
    for i in range(n_steps, 2 * n_steps):
        state2, m = step(state2, {k: jnp.asarray(v) for k, v in data.batch_at(i).items()})
    print(f"phase2 (post-reshard) loss={float(m['loss']):.3f} — training continued ✓")


if __name__ == "__main__":
    main()
