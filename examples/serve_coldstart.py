"""Serverless model serving with Aquifer cold-start mitigation.

Publishes a model snapshot to the two-tier pool, then compares the five
restore strategies (§5.1.3) on a real workload instance, and finally does an
actual warm restore into a pre-provisioned skeleton and serves tokens.

    PYTHONPATH=src:. python examples/serve_coldstart.py --workload chameleon
"""
import argparse

import jax
import jax.numpy as jnp

from benchmarks.workloads import all_workloads, get_workload
from repro.core import HierarchicalPool, Orchestrator, PoolMaster
from repro.checkpoint.ckpt import save_checkpoint
from repro.configs.base import get_config
from repro.models.model_zoo import build
from repro.serve.coldstart import SkeletonPool, restore_server
from repro.serve.strategies import STRATEGIES, run_strategy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="chameleon", choices=all_workloads())
    ap.add_argument("--concurrency", type=int, default=32)
    ap.add_argument("--quick", action="store_true",
                    help="smallest workload, fewer tokens (CI smoke)")
    args = ap.parse_args(argv)
    if args.quick:
        args.workload = "pyaes"       # xlstm-125m — the smallest image
        args.concurrency = 8

    bw = get_workload(args.workload)
    spec = bw.spec()
    print(f"workload={args.workload} arch={bw.wdef.arch} "
          f"image={bw.image.buf.nbytes/(1<<20):.0f}MiB "
          f"(scaled to paper-size 1.5GiB instances, x{spec.scale:.1f})")
    print(f"\nrestore strategies @ concurrency={args.concurrency} (modeled):")
    print(f"{'strategy':12s}{'setup':>9s}{'prefetch':>9s}{'install':>9s}{'total':>9s}")
    rows = {}
    for s in STRATEGIES:
        r = run_strategy(s, spec, concurrency=args.concurrency)
        rows[s] = r
        b = r.breakdown()
        print(f"{s:12s}{b['setup']:9.4f}{b['prefetch']:9.4f}{b['exec_install']:9.4f}"
              f"{b['total']:9.4f}")
    print(f"\nAquifer speedup: {rows['firecracker'].total_s/rows['aquifer'].total_s:.2f}x "
          f"vs firecracker, {rows['faasnap'].total_s/rows['aquifer'].total_s:.2f}x vs faasnap")

    # real restore path: publish model params → skeleton → warm restore → serve
    cfg = get_config(bw.wdef.arch).reduced(vocab=512)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pool = HierarchicalPool(1 << 30, 2 << 30)
    master = PoolMaster(pool)
    save_checkpoint(master, "model", {"params": params}, step=0)
    orch = Orchestrator("serve-host", pool, master.catalog)
    sp = SkeletonPool(cfg, batch=1, max_len=64, target_size=1, background=False)
    out = restore_server(orch, "model", sp.claim(), params)
    st = out["stats"]
    print(f"\nwarm restore: time-to-hot={st['time_to_hot_s']*1e3:.1f}ms "
          f"time-to-full={st['time_to_full_s']*1e3:.1f}ms "
          f"(pre-installed {st['instance']['pre_installed']} hot pages, "
          f"{st['instance']['fault_rdma']} async RDMA cold faults)")
    toks = out["instance"].generate(jnp.asarray([[1, 2, 3]], jnp.int32),
                                    2 if args.quick else 8)
    print("served tokens:", toks[0].tolist())
    sp.close()


if __name__ == "__main__":
    main()
