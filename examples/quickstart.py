"""Quickstart: train a tiny LM, publish its state to the hierarchical pool,
warm-restore it on another "host", and serve tokens from the restored
instance.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import HierarchicalPool, Orchestrator, PoolMaster
from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model_zoo import build
from repro.serve.engine import ServerInstance
from repro.train.loop import LoopConfig, Trainer


def main():
    # 1) a tiny same-family config of an assigned arch (full configs are for
    #    the dry-run; --arch selects any of the ten)
    cfg = get_config("qwen2.5-14b").reduced(vocab=512)
    model = build(cfg)
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))

    # 2) shared pod infrastructure: two-tier pool + pool master
    pool = HierarchicalPool(cxl_capacity=512 << 20, rdma_capacity=1 << 30)
    master = PoolMaster(pool)

    # 3) train a few steps with periodic Aquifer checkpoints
    trainer = Trainer(model, data, master=master,
                      loop_cfg=LoopConfig(steps=30, ckpt_every=15, log_every=10))
    state = trainer.run()
    print("train metrics:", [(m.get("step"), round(m.get("loss", 0), 3))
                             for m in trainer.metrics_log if "loss" in m])
    print("checkpoint composition:", trainer.ckpt_stats[-1])

    # 4) warm restore on a different host (borrow → clflush → pre-install hot
    #    set → demand-page cold pages from the RDMA tier)
    orch = Orchestrator("other-host", pool, master.catalog)
    restored, stats = restore_checkpoint(
        orch, trainer.loop_cfg.ckpt_name,
        {"params": state.params, "opt": state.opt})
    print(f"restored step={stats['meta']['step']} "
          f"time-to-hot={stats['time_to_hot_s']*1e3:.1f}ms "
          f"time-to-full={stats['time_to_full_s']*1e3:.1f}ms")
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("restored params are bit-identical ✓")

    # 5) serve from the restored weights
    inst = ServerInstance(model, restored["params"],
                          model.init_caches(None, 1, 64), 64)
    prompt = jnp.asarray([[5, 17, 42]], jnp.int32)
    tokens = inst.generate(prompt, 12)
    print("generated:", tokens[0].tolist())


if __name__ == "__main__":
    main()
