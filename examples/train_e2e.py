"""End-to-end training driver with Aquifer fault tolerance.

    PYTHONPATH=src python examples/train_e2e.py                      # demo
    PYTHONPATH=src python examples/train_e2e.py --preset 100m        # ~124M
    PYTHONPATH=src python examples/train_e2e.py --arch olmoe-1b-7b   # any arch
    PYTHONPATH=src python examples/train_e2e.py --resume             # restart

The `100m` preset is a GPT-2-small-class dense model (~124M params) for a
few hundred steps; `demo` is a ~10M model that finishes in about a minute on
this CPU container.  A mid-run simulated crash + restore is exercised with
--crash-at N.
"""
import argparse
import dataclasses
import time

import jax

from repro.configs.base import ModelConfig, get_config
from repro.core import HierarchicalPool, PoolMaster
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model_zoo import build
from repro.train.loop import LoopConfig, Trainer

PRESETS = {
    "demo": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_ff=1024,
                 vocab=2048, d_head=64, seq=128, batch=8, steps=60),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                 vocab=50304, d_head=64, seq=512, batch=8, steps=300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--arch", default="qwen2.5-14b",
                    help="assigned arch whose family the preset reduces")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a crash after N steps, then auto-restore")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    steps = args.steps or p["steps"]
    cfg = get_config(args.arch).reduced(
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        d_head=p["d_head"], scan_layers=True)
    model = build(cfg)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} family={cfg.family} params≈{n_params/1e6:.0f}M "
          f"steps={steps}")

    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=p["seq"],
                                      global_batch=p["batch"]))
    pool = HierarchicalPool(cxl_capacity=2 << 30, rdma_capacity=4 << 30)
    master = PoolMaster(pool)

    if args.crash_at:
        t1 = Trainer(model, data, master=master,
                     loop_cfg=LoopConfig(steps=args.crash_at,
                                         ckpt_every=max(1, args.crash_at // 2),
                                         log_every=10, async_checkpoint=False))
        t1.run()
        print(f"--- simulated crash after step {args.crash_at} ---")
        args.resume = True

    trainer = Trainer(model, data, master=master,
                      loop_cfg=LoopConfig(steps=steps, ckpt_every=50, log_every=10))
    t0 = time.perf_counter()
    trainer.run(resume=args.resume)
    wall = time.perf_counter() - t0
    losses = [(m["step"], round(m["loss"], 3)) for m in trainer.metrics_log if "loss" in m]
    print("loss curve:", losses)
    if trainer.ckpt_stats:
        s = trainer.ckpt_stats[-1]
        print(f"last checkpoint: {s['total_pages']} pages "
              f"(zero={s['zero']} hot={s['hot']} cold={s['cold']}) "
              f"publish={s['publish_s']*1e3:.0f}ms (async, off critical path)")
    print(f"wall={wall:.1f}s  tokens/s={steps*p['seq']*p['batch']/wall:,.0f} (CPU container)")


if __name__ == "__main__":
    main()
