"""Host-wide page-serving runtime (core/nodeserver.py) + contention-aware
modeled time (core/pool.LinkArbiter):

* hot-chunk fan-out bit-identity — k same-snapshot restores, ONE physical
  CXL read per chunk, k scatters;
* demand-over-prefetch priority across instances on the shared engine;
* cross-instance DRR fairness — a heavy prefetcher neighbour cannot starve
  a co-located light restore;
* property test: executed modeled restore time under the LinkArbiter
  matches the analytic `strategies._shared()`-based model within 15%
  across random concurrency/workload mixes, in BOTH runtimes;
* RestoreEngine.stop() drains in-flight completions and conserves
  demand-read buffers;
* vectorized `strategies._classify` equivalence with the scalar reference.
"""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import (
    HierarchicalPool,
    Instance,
    LayoutOrderPolicy,
    LinkArbiter,
    NodePageServer,
    Orchestrator,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
    TimeLedger,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.pool import RDMA_COST
from repro.core.profiler import AccessRecorder
from repro.core.serving import AsyncRDMAEngine
from repro.serve.strategies import (
    WorkloadSpec,
    _classify,
    modeled_concurrent_restore_s,
)


def make_image(seed=0, hot_pages=128, cold_pages=384, zero_pages=512):
    rng = np.random.default_rng(seed)
    arrays = {
        "params": rng.standard_normal(hot_pages * PAGE_SIZE // 4).astype(np.float32),
        "runtime": rng.integers(1, 7, (cold_pages * PAGE_SIZE,)).astype(np.uint8),
        "arena": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
    }
    img = StateImage.build(arrays)
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params")
    rt = img.manifest.by_name()["runtime"]
    for s in range(5, cold_pages - 4, max(8, cold_pages // 12)):
        rec.touch_pages(range(rt.first_page + s, rt.first_page + s + 2))
    return img, rec.working_set()


def make_stack(images, names=None):
    pool = HierarchicalPool(256 << 20, 512 << 20)
    master = PoolMaster(pool)
    names = names or [f"s{i}" for i in range(len(images))]
    for name, (img, ws) in zip(names, images):
        master.publish(name, img, ws)
    return pool, master, names


def drive_full_restore(ris, policy=None):
    """Concurrently run each restore to completion: hot pre-install + zero
    ranges + cold extent prefetch (the benchmark flow)."""
    errs = []
    policy = policy or LayoutOrderPolicy()

    def drive(ri):
        try:
            ri.engine.pre_install_hot()
            ri.engine.install_zero_runs()
            ri.engine.start_prefetcher(policy=policy)
            assert ri.engine.wait_prefetch_idle(60.0)
        except Exception as exc:            # pragma: no cover
            errs.append(exc)

    threads = [threading.Thread(target=drive, args=(ri,)) for ri in ris]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errs, errs


class TestLinkArbiter:
    def test_uncontended_charge_is_serial(self):
        arb = LinkArbiter(RDMA_COST)
        assert arb.active() == 1
        assert arb.charge(PAGE_SIZE) == pytest.approx(RDMA_COST.xfer_time(PAGE_SIZE))

    def test_fair_share_floor_and_refcount(self):
        arb = LinkArbiter(RDMA_COST)
        for key in ("a", "b", "c"):
            arb.register(key)
        arb.register("a")                   # refcounted: still 3 streams
        assert arb.active() == 3
        nbytes = 1 << 20
        serial = RDMA_COST.xfer_time(nbytes)
        assert arb.charge(nbytes) == pytest.approx(
            max(serial, nbytes * 3 / RDMA_COST.bandwidth_Bps))
        arb.unregister("a")
        assert arb.active() == 3            # one ref of "a" remains
        arb.unregister("a")
        assert arb.active() == 2
        arb.unregister("b")
        arb.unregister("c")
        assert arb.active() == 1
        assert arb.charge(nbytes) == pytest.approx(serial)

    def test_charge_pipelined_floor(self):
        arb = LinkArbiter(RDMA_COST)
        arb.register("x")
        arb.register("y")
        nbytes, ops = 4 << 20, 128
        assert arb.charge_pipelined(nbytes, ops) == pytest.approx(
            max(RDMA_COST.xfer_time_pipelined(nbytes, ops),
                nbytes * 2 / RDMA_COST.bandwidth_Bps))


class TestHotChunkFanout:
    def test_one_read_k_scatters_bit_identical(self):
        k = 4
        img, ws = make_image(seed=1)
        pool, master, names = make_stack([(img, ws)])
        server = NodePageServer("h0", pool)
        orch = Orchestrator("h0", pool, master.catalog, node_server=server)
        ris = [orch.restore(names[0], pre_install=False, prefetch_cold=False)
               for _ in range(k)]
        assert all(ri is not None for ri in ris)
        drive_full_restore(ris)

        for ri in ris:
            assert ri.instance.present.all()
            assert np.array_equal(ri.instance.image.buf, img.buf)
            assert ri.engine.prefetch_stats["pages_installed"] > 0

        reader = ris[0].engine.reader
        n_hot = int(reader.hot_page_indices().size)
        n_chunks = -(-n_hot // RestoreEngine.HOT_CHUNK_PAGES)
        assert server.chunks.stats["reads"] == n_chunks
        assert server.chunks.stats["fanout_hits"] == (k - 1) * n_chunks
        assert server.stats["fanout_installs"] > 0

        # the CXL link carried the hot bytes ONCE; each session still read
        # its own machine state + offset array
        r = reader.regions
        per_session_index = r.ms_size + r.total_pages * 8
        total_read = sum(ri.engine.reader.view.stats["bytes_read"] for ri in ris)
        assert total_read == k * per_session_index + n_hot * PAGE_SIZE

        # followers were still CHARGED the chunk-read time they waited on
        for ri in ris:
            assert ri.ledger.seconds.get("cxl_read", 0.0) > 0.0
        for ri in ris:
            ri.shutdown()
        # un-borrow released the refcounted cache: nothing left for the group
        assert server.chunks.drop_group((names[0], r.version)) == 0
        orch.close()
        server.close()

    def test_solo_restores_bypass_cache_and_stay_exact(self):
        """A one-session group has nobody to fan out to: the cache is not
        populated (no hot-region duplication in DRAM), and sequential
        restores of the same snapshot stay bit-identical."""
        img, ws = make_image(seed=2)
        pool, master, names = make_stack([(img, ws)])
        server = NodePageServer("h0", pool)
        orch = Orchestrator("h0", pool, master.catalog, node_server=server)
        ri1 = orch.restore(names[0], pre_install=True, prefetch_cold=False)
        assert server.chunks.stats["reads"] == 0
        assert server.chunks.stats["fanout_hits"] == 0
        ri1.shutdown()
        ri2 = orch.restore(names[0], pre_install=True, prefetch_cold=False)
        ri2.engine.install_all_sync()
        assert np.array_equal(ri2.instance.image.buf, img.buf)
        ri2.shutdown()
        server.close()

    def test_late_joiner_gets_cold_pages(self):
        """Regression: a session attaching to a LIVE group after the group's
        prefetch walk completed must still get its cold pages prefetched
        (its start_prefetcher re-enqueues what the pump no longer covers)."""
        img, ws = make_image(seed=12)
        pool, master, names = make_stack([(img, ws)])
        server = NodePageServer("h0", pool)
        orch = Orchestrator("h0", pool, master.catalog, node_server=server)
        ri_a = orch.restore(names[0], pre_install=False, prefetch_cold=True)
        assert ri_a.engine.wait_prefetch_idle(60)       # A's walk fully done
        # B joins while A is still alive: same FanoutGroup, walk already run
        ri_b = orch.restore(names[0], pre_install=False, prefetch_cold=True)
        assert ri_b.engine.wait_prefetch_idle(60)
        cold = ri_b.engine.reader.cold_page_indices()
        assert ri_b.instance.present[cold].all()
        ri_b.engine.pre_install_hot()
        ri_b.engine.install_zero_runs()
        assert np.array_equal(ri_b.instance.image.buf, img.buf)
        ri_a.shutdown()
        ri_b.shutdown()
        server.close()

    def test_demand_fanout_one_read_credits_every_session(self):
        """Regression (ISSUE 10 satellite): two same-group sessions faulting
        the SAME cold page must issue ONE physical demand read; the sibling
        records a prefetch_hit and the completion installs into both.
        Pre-fix, in-flight cover was per-session, so the sibling posted a
        duplicate read and nobody got hit credit."""
        from repro.core import HeatRegistry
        img, ws = make_image(seed=21)
        pool, master, names = make_stack([(img, ws)])
        heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
        server = NodePageServer("h0", pool, heat=heat)
        orch = Orchestrator("h0", pool, master.catalog, node_server=server)
        ri_a = orch.restore(names[0], pre_install=False, prefetch_cold=False)
        ri_b = orch.restore(names[0], pre_install=False, prefetch_cold=False)
        # park the shared engine so A's read is still queued when B faults
        server.engine._stop.set()
        server.engine._worker.join(timeout=10)
        assert not server.engine._worker.is_alive()

        page = int(ri_a.engine.reader.cold_page_indices()[0])
        ri_a.engine.handle_fault(page)      # posts the one physical read
        ri_b.engine.handle_fault(page)      # covered → prefetch_hit, no post
        assert server.stats["demand_reads"] == 1

        server.engine.start()               # resume; completion fans out
        assert ri_a.instance.wait_present(page, 30.0)
        assert ri_b.instance.wait_present(page, 30.0)
        assert server.stats["demand_reads"] == 1
        assert server.stats["demand_fanout_installs"] >= 1
        hm = heat.find(names[0], 0)
        assert hm.stats["prefetch_hits"] >= 1
        assert hm.stats["demand_faults"] == 1
        want = img.buf[page * PAGE_SIZE:(page + 1) * PAGE_SIZE]
        for ri in (ri_a, ri_b):
            got = ri.instance.image.buf[page * PAGE_SIZE:(page + 1) * PAGE_SIZE]
            assert np.array_equal(got, want)
        ri_a.shutdown()
        ri_b.shutdown()
        server.close()


class TestDemandOverPrefetchPriority:
    def test_urgent_overtakes_queued_prefetch_across_instances(self):
        """Deterministic: queue prefetch extents from instance A, then demand
        faults from instance B, on a stopped engine; on start, B's demand
        reads complete FIRST despite being posted last."""
        pool = HierarchicalPool(8 << 20, 8 << 20)
        eng = AsyncRDMAEngine(pool.rdma, TimeLedger(), start=False)
        for i in range(6):
            eng.submit_read(i * PAGE_SIZE, PAGE_SIZE,
                            np.empty(PAGE_SIZE, np.uint8),
                            ("prefetch", "instA", i), urgent=False)
        for j in range(2):
            eng.submit_read(j * PAGE_SIZE, PAGE_SIZE,
                            np.empty(PAGE_SIZE, np.uint8),
                            ("demand", "instB", j), urgent=True)
        eng.start()
        try:
            order = []
            while len(order) < 8:
                item = eng.poll_completion(block=True, timeout_s=1.0)
                assert item is not None
                order.append(item[1])
            assert [t[0] for t in order[:2]] == ["demand", "demand"]
            assert eng.stats["urgent_reads"] == 2
        finally:
            eng.close()

    def test_server_demand_faults_are_urgent(self):
        imgs = [make_image(seed=3), make_image(seed=4)]
        pool, master, names = make_stack(imgs)
        server = NodePageServer("h0", pool)
        orch = Orchestrator("h0", pool, master.catalog, node_server=server)
        ri_a = orch.restore(names[0], pre_install=False, prefetch_cold=True)
        ri_b = orch.restore(names[1], pre_install=False, prefetch_cold=False)
        cold_b = ri_b.engine.reader.cold_page_indices()[:16]
        for p in cold_b:
            ri_b.engine.access(int(p), timeout_s=30)
        assert server.stats["demand_reads"] >= cold_b.size
        assert server.engine.stats["urgent_reads"] >= cold_b.size
        assert ri_a.engine.wait_prefetch_idle(60)
        ri_a.shutdown()
        ri_b.shutdown()
        server.close()


class TestCrossInstanceFairness:
    def test_light_restore_not_starved_by_heavy_prefetcher(self):
        heavy = make_image(seed=5, hot_pages=16, cold_pages=512, zero_pages=32)
        light = make_image(seed=6, hot_pages=16, cold_pages=64, zero_pages=32)
        pool, master, names = make_stack([heavy, light],
                                         names=["heavy", "light"])
        # shallow QP depth (own CostModel copy — RDMA_COST is shared): the
        # pump can only burst 4 posts before blocking on completions, so the
        # light enqueue always lands while the heavy walk is still queued
        # (at the default depth of 64 the whole heavy walk could post in one
        # burst, making the interleaving assertions a scheduling race); the
        # assertions below read post ordering, never modeled time
        pool.rdma.cost = dataclasses.replace(pool.rdma.cost, max_inflight=4)
        # quantum = one 8-page extent: strict round-robin alternation
        server = NodePageServer("h0", pool, drr_quantum=8 * PAGE_SIZE)
        orch = Orchestrator("h0", pool, master.catalog, node_server=server,
                            prefetch_policy=LayoutOrderPolicy(8))
        ri_h = orch.restore("heavy", pre_install=False, prefetch_cold=False)
        ri_l = orch.restore("light", pre_install=False, prefetch_cold=False)
        ri_h.engine.start_prefetcher(policy=LayoutOrderPolicy(8))  # heavy 1st
        ri_l.engine.start_prefetcher(policy=LayoutOrderPolicy(8))
        assert ri_h.engine.wait_prefetch_idle(60)
        assert ri_l.engine.wait_prefetch_idle(60)

        posts = list(server.post_order)
        h_key = ri_h.engine._group.key if ri_h.engine._group else ("heavy", 0)
        light_posts = [i for i, (g, _es) in enumerate(posts) if g != h_key]
        heavy_posts = [i for i, (g, _es) in enumerate(posts) if g == h_key]
        n_light = len(light_posts)
        assert n_light >= 8                        # all light extents posted
        # DRR: the light group's last extent is posted long before the heavy
        # walk finishes (FIFO starvation would place it at the very end)
        assert light_posts[-1] < len(posts) - len(heavy_posts) // 3
        assert light_posts[-1] < 3 * n_light + 16
        # genuinely interleaved
        assert any(h > light_posts[0] for h in heavy_posts)

        # both restores complete exactly
        drive_full_restore([ri_h, ri_l], policy=LayoutOrderPolicy(8))
        assert np.array_equal(ri_h.instance.image.buf, heavy[0].buf)
        assert np.array_equal(ri_l.instance.image.buf, light[0].buf)
        ri_h.shutdown()
        ri_l.shutdown()
        server.close()


class TestExecutedMatchesAnalyticShared:
    """Property: executed modeled restore time under the LinkArbiter tracks
    the analytic `_shared()`-based model within 15% across random
    concurrency/workload mixes, for BOTH runtimes."""

    @pytest.mark.parametrize("shared,same_snapshot,conc,seed", [
        (True, False, 3, 10),     # shared runtime, 3 distinct groups
        (True, True, 4, 11),      # shared runtime, one fan-out group of 4
        (False, True, 3, 12),     # per-instance engines, same snapshot
        (False, False, 2, 13),    # per-instance engines, mixed
    ])
    def test_executed_within_15pct(self, shared, same_snapshot, conc, seed):
        rng = np.random.default_rng(seed)
        n_imgs = 1 if same_snapshot else conc
        images = [make_image(seed=seed + i,
                             hot_pages=int(rng.integers(32, 160)),
                             cold_pages=int(rng.integers(64, 384)),
                             zero_pages=int(rng.integers(64, 512)))
                  for i in range(n_imgs)]
        pool, master, names = make_stack(images)
        orch = Orchestrator("h0", pool, master.catalog, use_node_server=shared)
        ris = [orch.restore(names[0 if same_snapshot else k],
                            pre_install=False, prefetch_cold=False)
               for k in range(conc)]
        drive_full_restore(ris)
        groups = 1 if (shared and same_snapshot) else conc
        for k, ri in enumerate(ris):
            src = images[0 if same_snapshot else k][0]
            assert np.array_equal(ri.instance.image.buf, src.buf)
            t_exec = ri.ledger.total()
            t_model = modeled_concurrent_restore_s(ri.engine.reader, groups)
            assert t_exec == pytest.approx(t_model, rel=0.15), \
                (t_exec, t_model, shared, same_snapshot, conc)
        for ri in ris:
            ri.shutdown()
        orch.close()


class TestStopDrainsInflight:
    def test_stop_returns_demand_buffers_per_instance_engine(self):
        img, ws = make_image(seed=7)
        pool, master, names = make_stack([(img, ws)])
        orch = Orchestrator("h0", pool, master.catalog, use_node_server=False)
        ri = orch.restore(names[0], pre_install=False, prefetch_cold=False)
        cold = ri.engine.reader.cold_page_indices()
        for p in cold[:64]:                  # posts urgent reads, no waiting
            ri.engine.handle_fault(int(p))
        ri.shutdown()                        # stop with reads in flight
        assert ri.engine.buffers.outstanding == 0
        assert ri.engine._inflight == {}
        # drained completions installed normally (no lost pages, no doubles)
        installed = int(ri.instance.present[cold[:64]].sum())
        assert installed == ri.instance.stats["uffd_copies"]
        orch.close()

    def test_stop_shared_runtime_conserves_buffers(self):
        img, ws = make_image(seed=8)
        pool, master, names = make_stack([(img, ws)])
        server = NodePageServer("h0", pool)
        orch = Orchestrator("h0", pool, master.catalog, node_server=server)
        ri = orch.restore(names[0], pre_install=False, prefetch_cold=False)
        cold = ri.engine.reader.cold_page_indices()
        for p in cold[:32]:
            ri.engine.handle_fault(int(p))
        ri.shutdown()                        # detach parks + drains the host
        assert server.buffers.outstanding == 0
        server.close()


class TestClassifyVectorized:
    @staticmethod
    def _classify_reference(spec):
        zero = spec.image.zero_page_bitmap()
        ws = set(int(p) for p in spec.working_set)
        touched = [int(p) for p in spec.touched]
        t_zero = [p for p in touched if zero[p]]
        t_hot = [p for p in touched if not zero[p] and p in ws]
        t_cold = [p for p in touched if not zero[p] and p not in ws]
        ws_zero = [p for p in ws if zero[p]]
        ws_nonzero = [p for p in ws if not zero[p]]
        return zero, t_zero, t_hot, t_cold, ws_zero, ws_nonzero

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_identical_to_reference(self, seed):
        rng = np.random.default_rng(seed)
        img, _ws = make_image(seed=seed, hot_pages=32, cold_pages=64,
                              zero_pages=96)
        total = img.total_pages
        ws = rng.choice(total, size=int(rng.integers(1, total // 2)),
                        replace=False)
        touched = rng.integers(0, total, size=int(rng.integers(1, total)))
        touched = np.concatenate([touched, touched[:7]])    # duplicates too
        spec = WorkloadSpec(name="t", image=img, working_set=ws,
                            touched=touched, compute_s=0.0)
        zero_v, tz_v, th_v, tc_v, wsz_v, wsn_v = _classify(spec)
        zero_r, tz_r, th_r, tc_r, wsz_r, wsn_r = self._classify_reference(spec)
        np.testing.assert_array_equal(zero_v, zero_r)
        # touched classes preserve order + duplicates exactly
        assert list(tz_v) == tz_r
        assert list(th_v) == th_r
        assert list(tc_v) == tc_r
        # working-set classes are the same sets (vectorized form is sorted)
        assert set(int(p) for p in wsz_v) == set(wsz_r)
        assert set(int(p) for p in wsn_v) == set(wsn_r)
        assert list(wsz_v) == sorted(wsz_v)
        assert list(wsn_v) == sorted(wsn_v)

    def test_empty_touched(self):
        img, ws = make_image(seed=9, hot_pages=16, cold_pages=16, zero_pages=16)
        spec = WorkloadSpec(name="t", image=img, working_set=ws,
                            touched=np.zeros(0, np.int64), compute_s=0.0)
        _zero, tz, th, tc, _wsz, wsn = _classify(spec)
        assert len(tz) == len(th) == len(tc) == 0
        assert len(wsn) > 0
