"""Online hotness feedback, re-curation, and CXL capacity management
(ISSUE 4 tentpole): telemetry wiring, reconstruct/replan fidelity, the
break-even gate, clock eviction under borrows, and degrade-to-RDMA."""
import numpy as np
import pytest

from repro.core import (
    AccessRecorder,
    HeatRegistry,
    HierarchicalPool,
    Orchestrator,
    PoolMaster,
    StateImage,
    TouchEvent,
    estimate_snapshot_cxl_size,
    plan_recuration,
    reconstruct_image,
)
from repro.core.coherence import STATE_PUBLISHED
from repro.core.pagestore import PAGE_SIZE
from repro.serve.strategies import (
    recuration_benefit_s,
    recuration_cost_s,
    recuration_economics,
)


def make_image(seed=0, hot_pages=32, cold_pages=64, zero_pages=16):
    rng = np.random.default_rng(seed)
    img = StateImage.build({
        "params": rng.standard_normal(hot_pages * PAGE_SIZE // 4).astype(np.float32),
        "runtime": rng.integers(1, 7, (cold_pages * PAGE_SIZE,)).astype(np.uint8),
        "arena": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
    })
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params")
    return img, rec.working_set()


def make_pod(cxl_budget=None, heat=None):
    pool = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool, cxl_budget=cxl_budget, heat=heat)
    return pool, master


# -- reconstruction fidelity -------------------------------------------------

@pytest.mark.parametrize("compress", [False, True])
def test_reconstruct_image_bit_identical(compress):
    img, ws = make_image()
    pool, master = make_pod()
    regions = master.publish("s", img, ws, compress_cold=compress)
    if compress and not regions.cold_compressed:
        pytest.skip("zstandard unavailable")
    rebuilt = reconstruct_image(pool, regions)
    assert np.array_equal(rebuilt.buf, img.buf)
    assert rebuilt.manifest.to_dict() == img.manifest.to_dict()


@pytest.mark.parametrize("compress", [False, True])
@pytest.mark.parametrize("metadata", [None, {"origin": "test", "n": 3}])
def test_estimate_matches_build(compress, metadata):
    img, ws = make_image()
    pool, master = make_pod()
    est = estimate_snapshot_cxl_size(img, ws, metadata=metadata,
                                     compress_cold=compress)
    regions = master.publish("s", img, ws, metadata=metadata,
                             compress_cold=compress)
    assert est == regions.cxl_size


# -- telemetry wiring --------------------------------------------------------

def test_restore_telemetry_reaches_registry():
    img, ws = make_image()
    pool, _ = make_pod()
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    master.publish("s", img, ws)
    orch = Orchestrator("h0", pool, master.catalog, heat=heat)
    rt = img.manifest.by_name()["runtime"]
    drift = np.arange(rt.first_page, rt.first_page + 8)
    ri = orch.restore("s")
    ri.engine.touch_pages(drift)          # cold -> demand faults
    ri.engine.touch_pages(drift)          # now present -> touches
    ri.shutdown()
    orch.close()
    hm = heat.find("s", 0)
    assert hm is not None and hm.restores == 1
    assert hm.stats["demand_faults"] == 8
    assert hm.stats["touches"] == 8
    assert (hm.counts()[drift] >= 1.0).all()


def test_per_instance_path_records_heat_too():
    img, ws = make_image()
    pool, _ = make_pod()
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    master.publish("s", img, ws)
    orch = Orchestrator("h0", pool, master.catalog, heat=heat,
                        use_node_server=False)
    rt = img.manifest.by_name()["runtime"]
    ri = orch.restore("s")
    ri.engine.touch_pages(np.arange(rt.first_page, rt.first_page + 4))
    ri.shutdown()
    assert heat.find("s", 0).stats["demand_faults"] == 4


# -- planning + economics ----------------------------------------------------

def test_plan_recuration_promotes_and_demotes():
    img, ws = make_image()
    pool, _ = make_pod()
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    regions = master.publish("s", img, ws)
    hm = heat.map_for("s", 0, regions.total_pages)
    rt = img.manifest.by_name()["runtime"]
    drift = np.arange(rt.first_page, rt.first_page + 10)
    hm.record(TouchEvent(pages=drift, kind="demand_fault"))
    hm.record(TouchEvent(pages=drift, kind="demand_fault"))
    pm = img.manifest.by_name()["params"]
    touched_hot = np.arange(pm.first_page, pm.first_page + 8)
    hm.record(TouchEvent(pages=touched_hot, kind="touch"))
    hm.note_restore(); hm.note_restore()
    plan = plan_recuration(pool, regions, hm, min_restores=2)
    assert plan.changed
    assert set(plan.promote) == set(drift)
    # untouched hot pages are demoted; touched ones survive
    assert set(touched_hot).isdisjoint(plan.demote)
    assert plan.demote.size == regions.n_hot - touched_hot.size
    assert set(plan.new_working_set) == set(touched_hot) | set(drift)


def test_recuration_economics_break_even():
    img, ws = make_image()
    pool, _ = make_pod()
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    regions = master.publish("s", img, ws)
    hm = heat.map_for("s", 0, regions.total_pages)
    rt = img.manifest.by_name()["runtime"]
    hm.record(TouchEvent(pages=np.arange(rt.first_page, rt.first_page + 10),
                         kind="demand_fault"))
    hm.record(TouchEvent(pages=np.arange(rt.first_page, rt.first_page + 10),
                         kind="demand_fault"))
    hm.note_restore()
    plan = plan_recuration(pool, regions, hm, min_restores=1)
    cheap = recuration_economics(regions, plan, expected_restores=1)
    rich = recuration_economics(regions, plan, expected_restores=100000)
    assert not cheap["worthwhile"]           # one restore never amortizes
    assert rich["worthwhile"]
    assert rich["benefit_s"] > cheap["benefit_s"]
    assert rich["cost_s"] == pytest.approx(cheap["cost_s"])
    # and the master's gate honours it
    assert master.recurate("s", expected_restores=1) is None
    new = master.recurate("s", expected_restores=100000)
    assert new is not None and new.version == 1


def test_recuration_benefit_monotone():
    assert recuration_benefit_s(0, 0, 100) == 0.0
    assert recuration_benefit_s(10, 0, 100) > recuration_benefit_s(5, 0, 100)
    assert recuration_benefit_s(10, 5, 100) > recuration_benefit_s(10, 0, 100)
    img, ws = make_image()
    pool, master = make_pod()
    regions = master.publish("s", img, ws)
    assert recuration_cost_s(regions) > 0.0


def test_recurated_restore_bit_identical_and_version_bumped():
    img, ws = make_image()
    pool, _ = make_pod()
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    master.publish("s", img, ws)
    orch = Orchestrator("h0", pool, master.catalog, heat=heat)
    rt = img.manifest.by_name()["runtime"]
    drift = np.arange(rt.first_page, rt.first_page + 12)
    for _ in range(2):
        ri = orch.restore("s")
        ri.engine.touch_pages(drift)
        ri.shutdown()
    new = master.recurate("s", expected_restores=100000)
    assert new is not None and new.version == 1
    entry = master.catalog.find("s")
    assert entry.state.load() == STATE_PUBLISHED and entry.version == 1
    ri = orch.restore("s")
    assert ri.borrow.version == 1
    # the drifted pages are now pre-installed from CXL — no faults
    assert bool(ri.instance.present[drift].all())
    f0 = ri.instance.stats["fault_rdma"]
    ri.engine.touch_pages(drift)
    assert ri.instance.stats["fault_rdma"] == f0
    ri.engine.install_all_sync()
    assert np.array_equal(ri.instance.image.buf, img.buf)
    ri.shutdown()
    orch.close()


def test_recurate_aborts_stale_when_update_races_in():
    """A legitimate owner update landing between re-curation's read phase
    and its republish must win: the re-curated (now stale) bytes abort with
    ("stale", ...) instead of resurrecting old data at a newer version."""
    img, ws = make_image()
    pool, _ = make_pod()
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    regions = master.publish("s", img, ws)
    hm = heat.map_for("s", 0, regions.total_pages)
    rt = img.manifest.by_name()["runtime"]
    hm.record(TouchEvent(pages=np.arange(rt.first_page, rt.first_page + 8),
                         kind="demand_fault"))
    hm.record(TouchEvent(pages=np.arange(rt.first_page, rt.first_page + 8),
                         kind="demand_fault"))
    hm.note_restore()
    gen = master.recurate_steps("s", force=True)
    labels = []
    label = None
    while label != "reconstructed":
        label, _val = next(gen)
        labels.append(label)
    # concurrent legitimate update bumps the version mid-recuration
    img2, ws2 = make_image(7)
    master.publish("s", img2, ws2)
    tail = [lbl for lbl, _v in gen]
    assert tail == ["stale"]
    entry = master.catalog.find("s")
    assert entry.version == 1 and entry.state.load() == STATE_PUBLISHED
    # the racing update's bytes survived
    from repro.core import Orchestrator
    orch = Orchestrator("h0", pool, master.catalog)
    ri = orch.restore("s")
    ri.engine.install_all_sync()
    assert np.array_equal(ri.instance.image.buf, img2.buf)
    ri.shutdown()
    orch.close()


def test_heat_registry_pruned_on_republish():
    img, ws = make_image()
    pool, _ = make_pod()
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    regions = master.publish("s", img, ws)
    for v in range(3):
        heat.map_for("s", v, regions.total_pages)
    master.publish("s", img, ws)       # -> version 1, prunes < 0 (none)
    master.publish("s", img, ws)       # -> version 2, prunes < 1
    assert heat.find("s", 0) is None
    assert heat.find("s", 1) is not None


def test_rdma_exhaustion_is_not_degraded():
    """The degrade-to-RDMA retry applies only to CXL alloc failures: an
    RDMA-tier AllocError would only grow with an all-cold rebuild, so it
    propagates instead of silently failing twice."""
    from repro.core.pool import AllocError

    img, ws = make_image(cold_pages=64)
    pool = HierarchicalPool(cxl_capacity=128 << 20,
                            rdma_capacity=8 * 4096)   # tiny RDMA tier
    master = PoolMaster(pool, cxl_budget=1 << 30)
    with pytest.raises(AllocError):
        master.publish("s", img, ws)
    assert master.capacity.budget.stats["degraded"] == 0


def test_recurate_missing_or_no_heat_returns_none():
    img, ws = make_image()
    pool, master = make_pod()
    master.publish("s", img, ws)
    assert master.recurate("nope") is None        # unknown name
    assert master.recurate("s") is None           # no heat recorded


# -- CXL capacity management -------------------------------------------------

def budget_for(n_snapshots, regions):
    return int(n_snapshots * regions.cxl_size)


def test_capacity_demotes_clock_victims_and_never_fails_alloc():
    imgs = {}
    pool, probe_master = make_pod()
    img0, ws0 = make_image(0)
    probe = probe_master.publish("probe", img0, ws0)
    pool2 = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool2, cxl_budget=int(2.5 * probe.cxl_size))
    for i in range(4):
        img, ws = make_image(i)
        imgs[f"s{i}"] = img
        master.publish(f"s{i}", img, ws)
    report = master.capacity.report()
    assert report["demotions"] >= 1
    assert report["in_use"] <= report["budget_bytes"]
    # oldest snapshots were demoted (hot set moved to RDMA), newest kept hot
    demoted = [e.name for e in master.catalog.entries
               if e.regions is not None and e.regions.n_hot == 0]
    kept = [e.name for e in master.catalog.entries
            if e.regions is not None and e.regions.n_hot > 0]
    assert "s0" in demoted and "s3" in kept
    # every snapshot — demoted or not — still restores bit-identically
    orch = Orchestrator("h0", pool2, master.catalog)
    for i in range(4):
        ri = orch.restore(f"s{i}")
        ri.engine.install_all_sync()
        assert np.array_equal(ri.instance.image.buf, imgs[f"s{i}"].buf)
        ri.shutdown()
    orch.close()


def test_capacity_skips_borrowed_entries_refcount_safe():
    pool, probe_master = make_pod()
    img0, ws0 = make_image(0)
    probe = probe_master.publish("probe", img0, ws0)
    pool2 = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool2, cxl_budget=int(2.5 * probe.cxl_size))
    for i in range(2):
        img, ws = make_image(i)
        master.publish(f"s{i}", img, ws)
    # pin BOTH published snapshots with live borrows (e.g. fan-out restores
    # holding HotChunkCache chunks); the clock hand must skip them
    b0 = master.catalog.borrow("s0")
    b1 = master.catalog.borrow("s1")
    img, ws = make_image(2)
    regions2 = master.publish("s2", img, ws)
    # nothing evictable -> the NEW publish degraded to RDMA instead of
    # failing alloc or evicting a pinned entry
    assert regions2.n_hot == 0
    assert master.capacity.budget.stats["degraded"] >= 1
    for e in master.catalog.entries:
        if e.name in ("s0", "s1"):
            assert e.regions.n_hot > 0, "pinned entry must not be demoted"
    b0.release(); b1.release()
    # with the pins gone, the next over-budget publish can demote again
    img, ws = make_image(3)
    regions3 = master.publish("s3", img, ws)
    assert regions3.n_hot > 0
    assert master.capacity.budget.stats["demotions"] >= 1


def test_demote_drain_timeout_rolls_victim_back_to_published():
    """A demotion whose drain times out (a borrow landed between the
    refcount check and the tombstone) must NOT wedge the victim as a
    permanent TOMBSTONE: the entry rolls back to PUBLISHED with its
    regions/version intact and stays borrowable."""
    img, ws = make_image()
    pool = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool, cxl_budget=1 << 30)
    master.capacity.demote_drain_timeout_s = 0.05
    regions = master.publish("s", img, ws)
    pin = master.catalog.borrow("s")         # blocks the drain
    from repro.core.snapshot import reconstruct_image
    image = reconstruct_image(pool, regions)
    ok = master.capacity._demote_publish("s", image, regions.version)
    assert not ok
    entry = master.catalog.find("s")
    assert entry.state.load() == STATE_PUBLISHED
    assert entry.regions is regions and entry.version == regions.version
    pin.release()
    # still borrowable and restorable after the aborted demotion
    b = master.catalog.borrow("s")
    assert b is not None and b.regions is regions
    b.release()


def test_capacity_second_chance_prefers_lru():
    pool, probe_master = make_pod()
    img0, ws0 = make_image(0)
    probe = probe_master.publish("probe", img0, ws0)
    pool2 = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool2, cxl_budget=int(2.5 * probe.cxl_size))
    for i in range(2):
        img, ws = make_image(i)
        master.publish(f"s{i}", img, ws)
    # restore s0 recently -> its referenced bit protects it for one sweep
    orch = Orchestrator("h0", pool2, master.catalog)
    ri = orch.restore("s0")
    ri.engine.install_all_sync()
    ri.shutdown()
    orch.close()
    img, ws = make_image(2)
    master.publish("s2", img, ws)
    by_name = {e.name: e.regions for e in master.catalog.entries
               if e.regions is not None}
    assert by_name["s0"].n_hot > 0, "recently-restored snapshot kept hot"
    assert by_name["s1"].n_hot == 0, "LRU victim demoted"


# -- incremental capacity sweep (ISSUE 7 satellite) ---------------------------

def test_admit_empty_catalog_returns_false_cleanly():
    """With nothing published there is nothing to demote: an over-budget
    admit must degrade (False) without tripping the clock hand or the
    conservation assert on a zero-length catalog."""
    pool, master = make_pod(cxl_budget=1 << 20)
    cap = master.capacity
    assert cap.admit(512) is True                    # fits, no sweep
    assert cap.admit((1 << 20) + 1) is False         # over budget, no victims
    assert cap.budget.stats["degraded"] == 1
    assert cap.budget.stats["sweeps"] == 1
    assert cap.usage() == 0


def test_admit_everything_excluded_returns_false():
    """The publisher's own name is excluded from the sweep: when it is the
    only candidate, the sweep must find no victim and degrade, leaving the
    excluded snapshot's hot region untouched."""
    img, ws = make_image(0)
    pool, master = make_pod(cxl_budget=None)
    regions = master.publish("only", img, ws)
    master.capacity = __import__("repro.core.master", fromlist=["x"]) \
        .CXLCapacityManager(master, budget_bytes=regions.cxl_size)
    cap = master.capacity
    assert cap.admit(regions.cxl_size, exclude_name="only") is False
    assert cap.budget.stats["degraded"] == 1
    entry = master.catalog.find("only")
    assert entry.regions.n_hot > 0, "excluded entry must not be demoted"


def test_admit_recomputes_usage_at_most_twice(monkeypatch):
    """Regression: the demotion loop recomputed the O(catalog) usage() on
    every iteration.  A sweep that demotes several victims must call
    usage() exactly twice — once at entry, once for the conservation
    recompute at exit — with every intermediate step incremental."""
    pool, probe_master = make_pod()
    img0, ws0 = make_image(0)
    probe = probe_master.publish("probe", img0, ws0)
    pool2 = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool2, cxl_budget=int(4.5 * probe.cxl_size))
    for i in range(4):
        img, ws = make_image(i)
        master.publish(f"s{i}", img, ws)
    cap = master.capacity
    calls = {"n": 0}
    orig = cap.usage
    def counting_usage():
        calls["n"] += 1
        return orig()
    monkeypatch.setattr(cap, "usage", counting_usage)
    # needs ~2 hot regions' worth of space -> multiple demotions in one admit
    assert cap.admit(int(1.5 * probe.cxl_size)) is True
    assert cap.budget.stats["demotions"] >= 2
    assert calls["n"] == 2, (
        f"usage() called {calls['n']}x during a multi-victim sweep; "
        "the sweep must be incremental (entry + conservation recompute)")


def test_admit_incremental_sweep_conserves_usage():
    """The incremental gauge must land exactly on the authoritative
    recompute after demotions (the in-admit assert), and the budget gauge
    must be synced to it."""
    pool, probe_master = make_pod()
    img0, ws0 = make_image(0)
    probe = probe_master.publish("probe", img0, ws0)
    pool2 = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool2, cxl_budget=int(3.5 * probe.cxl_size))
    for i in range(3):
        img, ws = make_image(i)
        master.publish(f"s{i}", img, ws)
    cap = master.capacity
    assert cap.admit(probe.cxl_size) is True         # forces >= 1 demotion
    assert cap.budget.stats["demotions"] >= 1
    u = cap.usage()
    assert cap.budget.in_use == u                    # gauge synced
    assert u + probe.cxl_size <= cap.budget.budget_bytes


def test_admit_incremental_sweep_with_dedup_store():
    """Dedup victims free store-unique bytes (not private-region bytes);
    the incremental accounting must capture that delta too or the
    conservation assert fires."""
    pool = HierarchicalPool(cxl_capacity=128 << 20, rdma_capacity=512 << 20)
    master = PoolMaster(pool, dedup=True)
    sizes = []
    for i in range(3):
        img, ws = make_image(i)
        master.publish(f"d{i}", img, ws)
        sizes.append(estimate_snapshot_cxl_size(img, ws, dedup=True, pool=pool))
    from repro.core.master import CXLCapacityManager
    usage_now = sum(e.regions.cxl_size for e in master.catalog.entries
                    if e.regions is not None) + pool.dedup_cxl.unique_bytes()
    master.capacity = CXLCapacityManager(master, budget_bytes=usage_now)
    cap = master.capacity
    # anything extra forces a sweep over dedup-layout victims; the assert
    # inside admit() is the real check here
    cap.admit(64 * PAGE_SIZE)
    assert cap.budget.stats["sweeps"] == 1
    assert cap.usage() <= usage_now
