"""Profiler coverage (ISSUE 4 satellites): AccessRecorder edge cases, the
vectorized touch_rows against a scalar reference, and HeatMap properties."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pagestore import PAGE_SIZE, Manifest, StateImage, runs_from_pages
from repro.core.profiler import (
    RUN_PAGES,
    START_RUN,
    AccessRecorder,
    HeatMap,
    HeatRegistry,
    TouchEvent,
    WorkloadProfile,
)


def make_manifest():
    img = StateImage.build({
        "emb": np.arange(300 * 7, dtype=np.float32).reshape(300, 7),   # rows < 1 page
        "kv": np.arange(8 * 2048, dtype=np.float32).reshape(8, 2048),  # rows = 2 pages
        "vec1d": np.arange(5000, dtype=np.float64),                    # 1-D array
        "bytes1d": np.arange(256, dtype=np.uint8),                     # sub-page 1-D
    })
    return img.manifest


def touch_rows_reference(manifest, name, rows):
    """The pre-vectorization scalar loop (row_pages per row)."""
    e = manifest.by_name()[name]
    row_elems = int(np.prod(e.shape[1:])) if len(e.shape) > 1 else 1
    pages = set()
    for r in rows:
        pages.update(e.row_pages(int(r), row_elems))
    return pages


@pytest.mark.parametrize("name,rows", [
    ("emb", [0, 1, 2]),
    ("emb", [0, 150, 299]),               # rows crossing page boundaries
    ("kv", [0, 3, 7]),                    # multi-page rows
    ("kv", range(8)),
    ("vec1d", [0, 511, 512, 4999]),       # 1-D: row == element
    ("bytes1d", [0, 255]),                # 1-D sub-page: all land on page 0
])
def test_touch_rows_matches_scalar_reference(name, rows):
    manifest = make_manifest()
    rec = AccessRecorder(manifest)
    rec.touch_rows(name, rows)
    assert rec.pages == touch_rows_reference(manifest, name, rows)


def test_touch_rows_accepts_arrays_and_duplicates():
    manifest = make_manifest()
    a, b = AccessRecorder(manifest), AccessRecorder(manifest)
    a.touch_rows("emb", np.asarray([5, 5, 9, 5]))
    b.touch_rows("emb", [5, 9])
    assert a.pages == b.pages


def test_touch_rows_empty_is_noop():
    rec = AccessRecorder(make_manifest())
    rec.touch_rows("emb", [])
    rec.touch_rows("vec1d", np.zeros(0, dtype=np.int64))
    assert rec.pages == set()
    assert rec.working_set().size == 0


def test_touch_rows_1d_array_is_per_element():
    manifest = make_manifest()
    rec = AccessRecorder(manifest)
    rec.touch_rows("vec1d", [0])
    e = manifest.by_name()["vec1d"]
    assert rec.pages == {e.first_page}


@given(st.lists(st.integers(min_value=0, max_value=299), min_size=0, max_size=40))
@settings(max_examples=30)
def test_touch_rows_property_equivalence(rows):
    manifest = make_manifest()
    rec = AccessRecorder(manifest)
    rec.touch_rows("emb", rows)
    assert rec.pages == touch_rows_reference(manifest, "emb", rows)


# -- empty working set through the stats pipeline ---------------------------

def test_empty_working_set_stats():
    assert runs_from_pages([]) == []
    prof = WorkloadProfile("empty", 4, np.zeros(0, dtype=np.int64))
    stats = prof.fragment_stats()
    assert stats == {"n_runs": 0, "mean_run": 0.0, "p90_run": 0.0,
                     "frac_runs_lt4": 0.0}
    # schema is identical to the non-empty case (consumers index blindly)
    full = WorkloadProfile("one", 1, np.asarray([3, 4, 9]))
    assert set(stats) == set(full.fragment_stats())
    rec = AccessRecorder(make_manifest())
    assert rec.run_lengths() == []


# -- HeatMap ----------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def monotonic(self):
        return self.t


def test_heatmap_record_weights_and_stats():
    clk = FakeClock()
    hm = HeatMap(16, half_life_s=10.0, clock=clk)
    hm.record(TouchEvent(pages=[1, 2, 2], kind="demand_fault"))
    hm.record(TouchEvent(pages=[3], kind="prefetch_hit"))
    hm.record(TouchEvent(pages=[4], kind="touch"))
    c = hm.counts()
    assert c[1] == pytest.approx(1.0)
    assert c[2] == pytest.approx(2.0)          # duplicates accumulate
    assert c[3] == pytest.approx(0.6)
    assert c[4] == pytest.approx(0.25)
    assert hm.stats["demand_faults"] == 3
    assert hm.stats["prefetch_hits"] == 1
    assert hm.stats["touches"] == 1


def test_heatmap_half_life_decay_exact():
    clk = FakeClock()
    hm = HeatMap(4, half_life_s=5.0, clock=clk)
    hm.record(TouchEvent(pages=[0], kind="demand_fault"))
    clk.t = 5.0
    assert hm.counts()[0] == pytest.approx(0.5)
    clk.t = 15.0
    assert hm.counts()[0] == pytest.approx(0.125)


@given(st.lists(st.integers(min_value=0, max_value=31), min_size=1, max_size=20),
       st.integers(min_value=0, max_value=1000),
       st.integers(min_value=0, max_value=1000))
@settings(max_examples=40)
def test_heatmap_decay_monotone_property(pages, dt1_ms, dt2_ms):
    """With no new records, heat never increases as time advances, and
    observing at a later time never yields more heat than at an earlier
    one (decay monotonicity, per page)."""
    clk = FakeClock()
    hm = HeatMap(32, half_life_s=0.25, clock=clk)
    hm.record(TouchEvent(pages=pages, kind="demand_fault"))
    t1 = dt1_ms / 1000.0
    t2 = t1 + dt2_ms / 1000.0
    c0 = hm.counts(now=0.0)
    c1 = hm.counts(now=t1)
    c2 = hm.counts(now=t2)
    assert (c1 <= c0 + 1e-12).all()
    assert (c2 <= c1 + 1e-12).all()
    assert (c2 >= 0).all()


def test_heatmap_candidates():
    clk = FakeClock()
    hm = HeatMap(10, half_life_s=100.0, clock=clk)
    hm.record(TouchEvent(pages=[2, 3], kind="demand_fault"))
    hm.record(TouchEvent(pages=[5], kind="touch"))
    cold = np.asarray([1, 2, 3, 4])
    assert hm.promotion_candidates(cold, min_heat=1.0).tolist() == [2, 3]
    hot = np.asarray([5, 6, 7])
    # not enough restores observed yet -> no demotions
    assert hm.demotion_candidates(hot, min_restores=2).size == 0
    hm.note_restore()
    hm.note_restore()
    assert hm.demotion_candidates(hot, min_restores=2).tolist() == [6, 7]
    # empty inputs stay empty
    assert hm.promotion_candidates(np.zeros(0, np.int64)).size == 0
    assert hm.demotion_candidates(np.zeros(0, np.int64)).size == 0


def test_heat_registry_keys_and_latest():
    reg = HeatRegistry()
    a = reg.map_for("w", 0, 8)
    assert reg.map_for("w", 0, 8) is a
    b = reg.map_for("w", 3, 8)
    assert reg.find("w", 1) is None
    assert reg.latest("w") == (3, b)
    assert reg.latest("nope") is None


# -- first-touch sequence telemetry (DESIGN.md §17) --------------------------

def test_touchevent_sequence_transitions():
    hm = HeatMap(8 * RUN_PAGES, clock=FakeClock())
    # stream 7 first-touches runs 3 → 1 → 2 (dedup within the stream)
    hm.record(TouchEvent(pages=np.arange(3 * RUN_PAGES, 4 * RUN_PAGES),
                         kind="demand_fault", stream=7))
    hm.record(TouchEvent(pages=[1 * RUN_PAGES, 1 * RUN_PAGES + 1],
                         kind="demand_fault", stream=7))
    hm.record(TouchEvent(pages=[3 * RUN_PAGES + 2],   # run 3 again: no-op
                         kind="demand_fault", stream=7))
    hm.record(TouchEvent(pages=[2 * RUN_PAGES], kind="touch", stream=7))
    src, dst, cnt = hm.transition_counts()
    got = {(int(s), int(d)): float(c) for s, d, c in zip(src, dst, cnt)}
    assert got == {(START_RUN, 3): 1.0, (3, 1): 1.0, (1, 2): 1.0}
    assert hm.stats["seq_transitions"] == 3


def test_touchevent_streams_are_independent_and_endable():
    hm = HeatMap(4 * RUN_PAGES, clock=FakeClock())
    hm.record(TouchEvent(pages=[0], kind="demand_fault", stream=1))
    hm.record(TouchEvent(pages=[RUN_PAGES], kind="demand_fault", stream=2))
    src, dst, _ = hm.transition_counts()
    # both streams start at START_RUN — neither sees the other's prev
    assert sorted(zip(src.tolist(), dst.tolist())) == [
        (START_RUN, 0), (START_RUN, 1)]
    hm.end_stream(1)
    # a reused stream id starts over from START_RUN
    hm.record(TouchEvent(pages=[2 * RUN_PAGES], kind="demand_fault", stream=1))
    src, dst, cnt = hm.transition_counts()
    got = dict(zip(zip(src.tolist(), dst.tolist()), cnt.tolist()))
    assert got[(START_RUN, 2)] == 1.0


def test_touchevent_without_stream_records_no_sequence():
    hm = HeatMap(4 * RUN_PAGES, clock=FakeClock())
    hm.record(TouchEvent(pages=[0, RUN_PAGES], kind="demand_fault"))
    _, _, cnt = hm.transition_counts()
    assert cnt.size == 0
    assert hm.stats["demand_faults"] == 2      # heat still accumulates


def test_heat_registry_record_entrypoint():
    reg = HeatRegistry()
    ev = TouchEvent(pages=[0, 1], kind="demand_fault", name="w", version=2,
                    total_pages=64, stream=5)
    hm = reg.record(ev)
    assert reg.find("w", 2) is hm
    assert hm.stats["demand_faults"] == 2
    with pytest.raises(ValueError):
        reg.record(TouchEvent(pages=[0]))       # no (name, version) routing


def test_legacy_record_spelling_warns_and_still_works():
    hm = HeatMap(16, clock=FakeClock())
    with pytest.warns(DeprecationWarning):
        hm.record([1, 2], kind="demand_fault")
    assert hm.stats["demand_faults"] == 2
