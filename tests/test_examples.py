"""Smoke tests: each runnable example's main() completes in --quick mode.

The examples are documentation that executes; these tests keep them from
rotting when the APIs they narrate move (the ISSUE-9 audit found none
broken, and this keeps it that way).
"""
import importlib.util
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _load_example(name):
    path = REPO / "examples" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(f"examples_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("name", ["serve_coldstart", "elastic_restore"])
def test_example_quick_mode(name, capsys):
    mod = _load_example(name)
    mod.main(["--quick"])
    out = capsys.readouterr().out
    # each example ends by proving real work happened
    if name == "serve_coldstart":
        assert "served tokens:" in out and "warm restore:" in out
    else:
        assert "training continued" in out and "restored step=2" in out
