"""The benchmark-regression CI gate (ISSUE 4 satellite): it must pass on
untouched baselines and demonstrably fail when a baseline key is perturbed
beyond tolerance — without re-running any benchmark."""
import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from benchmarks.check_regressions import (  # noqa: E402
    BASELINES,
    check_all,
    compare,
    get_path,
    load_baseline,
    main,
)


@pytest.fixture()
def disk_results():
    """The experiments/*.json currently on disk, for every gated bench that
    exists (they are committed baselines in a checkout)."""
    out = {}
    for fname in BASELINES:
        p = REPO / "experiments" / fname
        if p.exists():
            out[fname] = json.loads(p.read_text())
    if not out:
        pytest.skip("no experiment baselines on disk")
    return out


def _set_path(obj, path, value):
    parts = path.split(".")
    cur = obj
    for part in parts[:-1]:
        cur = cur[int(part)] if isinstance(cur, list) else cur[part]
    last = parts[-1]
    if isinstance(cur, list):
        cur[int(last)] = value
    else:
        cur[last] = value


def test_get_path_traverses_dicts_and_lists():
    obj = {"rows": [{"a": 1.0}, {"a": 2.0}], "flat": True}
    assert get_path(obj, "rows.1.a") == 2.0
    assert get_path(obj, "flat") is True


def test_gate_passes_on_identical_baselines(disk_results, tmp_path):
    for fname, data in disk_results.items():
        (tmp_path / fname).write_text(json.dumps(data))
    fresh = {f: json.loads(json.dumps(d)) for f, d in disk_results.items()}
    assert check_all(fresh, baseline_dir=tmp_path) == []


def test_gate_passes_within_tolerance(disk_results, tmp_path):
    fname, data = next(iter(disk_results.items()))
    key = next(k for k in BASELINES[fname]
               if isinstance(get_path(data, k if isinstance(k, str) else k[0]),
                             float))
    baseline = json.loads(json.dumps(data))
    _set_path(baseline, key, get_path(data, key) * 1.05)   # +5% < ±10%
    (tmp_path / fname).write_text(json.dumps(baseline))
    assert compare(fname, json.loads((tmp_path / fname).read_text()),
                   data, BASELINES[fname]) == []


@pytest.mark.parametrize("factor", [1.2, 0.8])
def test_gate_fails_on_perturbed_numeric_key(disk_results, tmp_path, factor):
    """ISSUE 4 acceptance: a baseline key perturbed beyond ±10% fails."""
    for fname, data in disk_results.items():
        key = next(k for k in BASELINES[fname]
                   if isinstance(get_path(data, k if isinstance(k, str) else k[0]),
                                 float))
        baseline = json.loads(json.dumps(data))
        _set_path(baseline, key, get_path(data, key) * factor)
        violations = compare(fname, baseline, data, BASELINES[fname])
        assert violations, f"{fname}:{key} perturbed x{factor} must fail"
        assert key in violations[0]


def test_gate_fails_on_flipped_boolean(disk_results):
    fname = next((f for f in disk_results
                  if any(isinstance(get_path(disk_results[f],
                                             k if isinstance(k, str) else k[0]),
                                    bool) for k in BASELINES[f])), None)
    if fname is None:
        pytest.skip("no boolean keys on disk")
    data = disk_results[fname]
    key = next(k for k in BASELINES[fname]
               if isinstance(get_path(data, k if isinstance(k, str) else k[0]),
                             bool))
    fresh = json.loads(json.dumps(data))
    _set_path(fresh, key, not get_path(data, key))
    violations = compare(fname, data, fresh, BASELINES[fname])
    assert violations and key in violations[0]


def test_gate_fails_on_missing_key(disk_results):
    fname, data = next(iter(disk_results.items()))
    assert compare(fname, data, {}, BASELINES[fname])
    assert compare(fname, {}, data, BASELINES[fname])


def test_cli_no_run_exit_codes(disk_results, tmp_path, monkeypatch):
    """End-to-end CLI behaviour without re-running benches: exit 0 on clean
    baselines, exit 1 after a >tolerance perturbation."""
    for fname, data in disk_results.items():
        (tmp_path / fname).write_text(json.dumps(data))
    assert main(["--no-run", "--baseline-dir", str(tmp_path)]) == 0
    fname, data = next(iter(disk_results.items()))
    key = next(k for k in BASELINES[fname]
               if isinstance(get_path(data, k if isinstance(k, str) else k[0]),
                             float))
    perturbed = json.loads(json.dumps(data))
    _set_path(perturbed, key, get_path(data, key) * 2.0)
    (tmp_path / fname).write_text(json.dumps(perturbed))
    assert main(["--no-run", "--baseline-dir", str(tmp_path)]) == 1


def test_load_baseline_from_git_or_dir(tmp_path):
    (tmp_path / "x.json").write_text('{"a": 1}')
    assert load_baseline("x.json", tmp_path) == {"a": 1}
    # committed files resolve through git show
    committed = load_baseline("breakdown.json")
    assert "breakdown" in committed
