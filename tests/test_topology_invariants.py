"""Multi-pod scenario matrix: replica coherence (I7) and the cluster-level
single writer (I8) under the deterministic simulator (DESIGN.md §16).

Same discipline as ``test_sim_cluster.py``: every scenario is a pure
function of a seed, drives the *real* topology objects (``PodGroup``,
``ReplicaManager``, ``MigrationManager``, ``InterPodRouter``) through the
seeded scheduler, and the invariant checker — now including per-step I7
bit-identity and I8 writer-lock checks — runs after every step.  Negative
tests prove the new checks actually fire on protocol bypasses.

Seed control: ``AQUIFER_SIM_SEED`` (default 0) offsets every scenario's
seed, matching the nightly rotation.
"""
import os

import pytest

from repro.core import STATE_PUBLISHED
from repro.sim import InvariantViolation, SimCluster

SEED = int(os.environ.get("AQUIFER_SIM_SEED", "0"))


def _pod_cluster(seed, n_pods=2, ports_per_pod=None, hosts=()):
    c = SimCluster(n_hosts=max(1, len(hosts)), seed=seed, n_pods=n_pods,
                   ports_per_pod=ports_per_pod, schedule="round_robin")
    for host, pod in hosts:
        c.group.assign_host(host, pod)
    return c


# ---------------------------------------------------------------------------
# scenario library: name -> callable(seed) -> SimCluster (assertions inside)
# ---------------------------------------------------------------------------

def scenario_replicated_publish_and_update(seed):
    """k=2 publish, then an update racing borrowers homed on three pods:
    the lockstep barrier means no step ever observes mixed PUBLISHED
    versions (I7 is checked after every one of these steps)."""
    c = _pod_cluster(seed, n_pods=3,
                     hosts=[("h1", 0), ("h2", 1), ("h3", 2)])
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0, 1]))
    c.run()
    assert c.replicas.replica_pods("s") == [0, 1]
    c.add_program("owner2", c.group_publish_program("s", 2.0))
    for h in ("h1", "h2", "h3"):
        c.add_program(h, c.group_borrower_program(h, "s", attempts=3))
    c.run()
    assert c.replicas.version_of("s") == 1
    for pid in (0, 1):
        entry = c.pods[pid].catalog.find("s")
        assert entry.version == 1 and entry.state.load() == STATE_PUBLISHED
    assert any("barrier" in lbl for _s, _n, lbl in c.trace)
    done = [e for e in c.events if e.startswith("group_borrower_done")]
    assert len(done) == 3
    # h3 has no pod-2 replica: its reads must have crossed the fabric
    assert c.replicas.stats["routed_interpod"] > 0
    assert c.router.stats["interpod_reads"] > 0
    return c


def scenario_replica_delete_drain_window(seed):
    """Group delete while a borrow is live on one replica: every replica
    tombstones first (no new borrows anywhere), then the delete polls GC
    until the straggler releases — the cross-pod drain window of I7."""
    c = _pod_cluster(seed, hosts=[("h1", 1)])
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0, 1]))
    c.run()

    def holder():
        rec = yield from c.borrow_program_steps("h1", "s", pod=1)
        assert rec is not None
        yield "held"
        yield ("sleep", 3e-3)       # keep the pin open across the delete
        c.release(rec)
        yield "released"

    c.add_program("h1", holder())
    c.add_program("deleter", c.delayed(
        1e-4, c.group_delete_program("s", drain_limit=None)))
    c.run(max_steps=40000)
    assert "gdel_done:s" in c.events
    assert c.replicas.names() == []
    # the drain window actually opened: delete polled GC at least once
    assert any(":gc_pending" in lbl for _s, n, lbl in c.trace
               if n == "deleter"), "delete never waited on a live borrow"
    for pid in (0, 1):
        entry = c.pods[pid].catalog.find("s")
        assert entry is None or entry.state.load() != STATE_PUBLISHED
    return c


def scenario_pod_link_partition(seed):
    """Data-plane partition between a host's home pod and the only replica
    pod: routed reads refuse cleanly (cold-start fallback, never stale
    bytes); healing the link restores inter-pod routing."""
    c = _pod_cluster(seed, hosts=[("h1", 1), ("h2", 1)])
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0]))
    c.run()
    c.add_program("cut", c.partition_program(1, 0, delay_s=1.5e-4))
    c.add_program("h1", c.group_borrower_program("h1", "s", attempts=4,
                                                 pause_s=1e-4))
    c.run(max_steps=20000)
    assert "partition:1-0" in c.events
    assert c.replicas.stats["routed_none"] > 0, \
        "partitioned host should have fallen back to cold start"
    assert any(e.startswith("cold_start:h1") for e in c.events)
    # heal: routing over the fabric works again (h2 starts after the heal)
    before = c.replicas.stats["routed_interpod"]
    c.add_program("heal", c.partition_program(1, 0, delay_s=0.0, up=True))
    c.add_program("h2", c.delayed(
        1e-4, c.group_borrower_program("h2", "s", attempts=2)))
    c.run(max_steps=30000)
    assert c.replicas.stats["routed_interpod"] > before
    assert "group_borrower_done:h2:2/2" in c.events
    return c


def scenario_owner_pod_loss_promote(seed):
    """Losing a whole pod promotes surviving replicas (a routing change,
    not a copy — survivors are already PUBLISHED at the group version);
    single-replica names on the dead pod are reported lost."""
    c = _pod_cluster(seed, hosts=[("h1", 0), ("h2", 1)])
    c.add_program("owner_s", c.group_publish_program("s", 1.0, pods=[0, 1]))
    c.add_program("owner_solo", c.group_publish_program("solo", 3.0, pods=[0]))
    c.run()
    c.add_program("loss", c.pod_loss_program(0, delay_s=1.5e-4))
    c.add_program("h1", c.group_borrower_program("h1", "s", attempts=4,
                                                 pause_s=1e-4))
    c.add_program("h2", c.group_borrower_program("h2", "solo", attempts=4,
                                                 pause_s=1e-4))
    c.run(max_steps=30000)
    assert "pod_lost:0" in c.events
    assert "replica_lost:solo" in c.events
    assert c.replicas.replica_pods("s") == [1]
    assert c.replicas.stats["promotions"] >= 2
    # after the loss, "solo" readers cold-start rather than touch dead bytes
    assert any(e.startswith("cold_start:h2") for e in c.events)
    # "s" stays servable throughout from the surviving replica
    assert "group_borrower_done:h1:4/4" in c.events
    return c


def scenario_port_starvation_burst(seed):
    """Fan-out burst of 5 hosts against a 2-port MHD: beyond-limit borrows
    fall through to inter-pod RDMA (even toward the home pod) instead of
    queueing forever; everyone completes, peak attach never exceeds the
    port limit."""
    hosts = [(f"h{i}", 0) for i in range(1, 6)]
    c = _pod_cluster(seed, ports_per_pod=2, hosts=hosts)
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0, 1]))
    c.run()
    for h, _pod in hosts:
        c.add_program(h, c.group_borrower_program(h, "s", attempts=3))
    c.run(max_steps=40000)
    done = [e for e in c.events if e.startswith("group_borrower_done")]
    assert sorted(done) == sorted(
        f"group_borrower_done:h{i}:3/3" for i in range(1, 6))
    ports = c.pods[0].ports
    assert ports.stats["peak"] <= 2, "port limit was exceeded"
    assert ports.stats["fallthrough"] > 0, \
        "burst never overflowed to the fabric"
    assert c.replicas.stats["routed_local"] > 0
    assert c.replicas.stats["routed_interpod"] > 0
    return c


def scenario_migration_break_even(seed):
    """Migration is economics-gated: a cold name (1 expected read) stays
    put; a hot one (10k expected reads) replicates to the demand pod at
    the same version, after which that pod's hosts borrow locally."""
    c = _pod_cluster(seed, ports_per_pod=4, hosts=[("h1", 1), ("h2", 1)])
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0]))
    c.run()
    c.add_program("mig_cold", c.migrate_program("s", 1, expected_reads=1))
    c.run()
    assert c.migrator.stats["skipped_uneconomic"] == 1
    assert c.replicas.replica_pods("s") == [0]
    c.add_program("mig_hot", c.migrate_program("s", 1, expected_reads=10000))
    c.run()
    assert c.migrator.stats["migrated"] == 1
    assert c.replicas.replica_pods("s") == [0, 1]
    assert c.replicas.version_of("s") == 0
    local_before = c.replicas.stats["routed_local"]
    c.add_program("h1", c.group_borrower_program("h1", "s", attempts=2))
    c.run(max_steps=20000)
    assert c.replicas.stats["routed_local"] > local_before
    assert "group_borrower_done:h1:2/2" in c.events
    return c


SCENARIOS = {
    "replicated_publish_and_update": scenario_replicated_publish_and_update,
    "replica_delete_drain_window": scenario_replica_delete_drain_window,
    "pod_link_partition": scenario_pod_link_partition,
    "owner_pod_loss_promote": scenario_owner_pod_loss_promote,
    "port_starvation_burst": scenario_port_starvation_burst,
    "migration_break_even": scenario_migration_break_even,
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("offset", [0, 1, 2])
def test_scenario(name, offset):
    SCENARIOS[name](SEED + 100 * offset + 7 * (sorted(SCENARIOS).index(name) + 1))


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_is_deterministic(name):
    seed = SEED + 2000 + sorted(SCENARIOS).index(name)
    a = SCENARIOS[name](seed)
    b = SCENARIOS[name](seed)
    assert a.trace == b.trace and a.events == b.events


# ---------------------------------------------------------------------------
# negative tests: the I7/I8 checkers actually fire on protocol bypasses
# ---------------------------------------------------------------------------

def test_i8_bypass_is_detected():
    """A pod-local owner mutating a group-managed name without the group
    writer lock is flagged mid-flight."""
    c = _pod_cluster(SEED)
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0, 1]))
    c.run()
    img, ws = c.make_image(9.0)

    def rogue():
        for label, _val in c.pods[1].master.publish_steps("s", img, ws):
            yield f"rogue:{label}"

    c.add_program("rogue", rogue())
    with pytest.raises(InvariantViolation, match="I8"):
        c.run()


def test_i7_mixed_versions_are_detected():
    """Two PUBLISHED replicas at different versions (here produced by a
    blocking pod-local republish outside the group protocol) violate
    replica version coherence."""
    c = _pod_cluster(SEED)
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0, 1]))
    c.run()
    img, ws = c.make_image(9.0)
    c.pods[1].master.publish("s", img, ws)   # bypass: pod 1 jumps to v1
    with pytest.raises(InvariantViolation, match="I7"):
        c.checker.check_all()


def test_i7_divergent_bytes_are_detected():
    """Same version, different bytes: the bit-identity sweep catches a
    replica whose content silently diverged."""
    c = _pod_cluster(SEED)
    c.add_program("owner", c.group_publish_program("s", 1.0, pods=[0, 1]))
    c.run()
    entry = c.pods[1].catalog.find("s")
    pool = c.pods[1].pool
    # corrupt one hot page of pod 1's replica in place (private CXL region)
    r = entry.regions
    page = pool.cxl.read(r.hot_off, 4096).copy()
    page[:16] ^= 0xFF
    pool.cxl.write(r.hot_off, page)
    c.checker._replica_sigs.pop("s", None)   # force a fresh bit-compare
    with pytest.raises(InvariantViolation, match="I7"):
        c.checker.check_all()
