"""Differential fuzz: batched vs per-page restore over random snapshot
layouts (hot/cold/zero run mixes, including empty-class and single-page-run
edges).  Both paths must produce bit-identical images AND agree on the
ioctl/transfer accounting (same page counts, same bytes; batching may only
*amortize* modeled time, never undercount it)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    HierarchicalPool,
    Instance,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
)
from repro.core.pagestore import PAGE_SIZE


def build_layout(classes, fill_seed=0):
    """Build a StateImage + working set from a per-page class list
    (entries in {"hot", "cold", "zero"})."""
    n = len(classes)
    rng = np.random.default_rng(fill_seed + 1000 * n)
    buf = np.zeros(n * PAGE_SIZE, dtype=np.uint8)
    for i, cls in enumerate(classes):
        if cls == "zero":
            continue
        page = rng.integers(0, 256, size=PAGE_SIZE, dtype=np.uint8)
        page[0] = max(1, int(page[0]))          # guarantee non-zero content
        buf[i * PAGE_SIZE : (i + 1) * PAGE_SIZE] = page
    img = StateImage.build({"blob": buf})
    working_set = [i for i, cls in enumerate(classes) if cls == "hot"]
    return img, working_set


def restore_both_ways(classes, fill_seed=0):
    img, ws = build_layout(classes, fill_seed)
    pool = HierarchicalPool(64 << 20, 64 << 20)
    master = PoolMaster(pool)
    master.publish("snap", img, ws)
    borrow = master.catalog.borrow("snap")
    assert borrow is not None

    results = []
    for use_batch in (True, False):
        view = pool.host_view(f"h-{use_batch}")
        reader = SnapshotReader(borrow.regions, view, pool.rdma)
        reader.invalidate_cxl()
        inst = Instance(StateImage.empty_like(img.manifest))
        engine = RestoreEngine(reader, inst, None)
        engine.install_all_sync(use_batch=use_batch)
        assert inst.all_present()
        results.append((inst, reader))
    borrow.release()
    return img, results


def check_differential(classes, fill_seed=0):
    img, ((batched, r_b), (perpage, _r_p)) = restore_both_ways(classes, fill_seed)
    n_hot = r_b.hot_page_indices().size
    n_cold = r_b.cold_page_indices().size
    n_zero = r_b.zero_page_indices().size
    assert n_hot + n_cold + n_zero == len(classes)

    # 1) bit-identical: both paths reproduce the published image exactly
    np.testing.assert_array_equal(batched.image.buf, img.buf)
    np.testing.assert_array_equal(perpage.image.buf, img.buf)

    # 2) accounting parity: identical page counts and installed bytes
    for key in ("uffd_copies", "uffd_zeropages", "bytes_installed"):
        assert batched.stats[key] == perpage.stats[key], (
            f"{key}: batched={batched.stats[key]} perpage={perpage.stats[key]} "
            f"classes={classes}")
    assert batched.stats["uffd_copies"] == n_hot + n_cold
    assert batched.stats["uffd_zeropages"] == n_zero

    # 3) modeled time: batching amortizes fixed ioctl/op costs, never adds
    for key in ("uffd_copy", "uffd_zeropage", "rdma_read"):
        b = batched.ledger.seconds.get(key, 0.0)
        p = perpage.ledger.seconds.get(key, 0.0)
        assert b <= p + 1e-12, f"{key}: batched {b} > per-page {p}"
    if any(c != "zero" for c in classes):
        assert batched.stats["uffd_batches"] > 0


EDGE_LAYOUTS = [
    ["zero"],                                     # single all-zero page
    ["hot"],                                      # single hot page
    ["cold"],                                     # single cold page
    ["zero"] * 8,                                 # empty hot AND cold classes
    ["hot"] * 8,                                  # one maximal hot run
    ["cold"] * 8,                                 # one maximal cold run
    ["hot", "cold"] * 4,                          # all single-page runs
    ["hot", "zero", "cold", "zero"] * 3,          # zeros splitting both classes
    ["hot"] * 3 + ["zero"] + ["hot"] * 2 + ["cold"] * 4 + ["zero"] * 2,
]


@pytest.mark.parametrize("classes", EDGE_LAYOUTS,
                         ids=["-".join(c[:4]) + f"x{len(c)}" for c in EDGE_LAYOUTS])
def test_edge_layouts(classes):
    check_differential(classes)


@given(st.lists(st.sampled_from(["hot", "cold", "zero"]), min_size=1, max_size=48),
       st.integers(0, 1 << 16))
@settings(max_examples=40, deadline=None)
def test_random_layouts(classes, fill_seed):
    check_differential(classes, fill_seed)


def test_restores_identical_under_concurrent_owner_update():
    """The borrow pins one version: restoring both ways while the owner
    publishes a new version must still be bit-identical to the *borrowed*
    version (the update drains only after release)."""
    import threading

    classes = ["hot"] * 4 + ["cold"] * 4 + ["zero"] * 2
    img, ws = build_layout(classes, fill_seed=7)
    pool = HierarchicalPool(64 << 20, 64 << 20)
    master = PoolMaster(pool)
    master.publish("snap", img, ws)
    borrow = master.catalog.borrow("snap")

    img2, ws2 = build_layout(classes, fill_seed=8)
    t = threading.Thread(target=master.publish, args=("snap", img2, ws2), daemon=True)
    t.start()

    images = []
    for use_batch in (True, False):
        view = pool.host_view(f"h{use_batch}")
        reader = SnapshotReader(borrow.regions, view, pool.rdma)
        reader.invalidate_cxl()
        inst = Instance(StateImage.empty_like(img.manifest))
        RestoreEngine(reader, inst, None).install_all_sync(use_batch=use_batch)
        images.append(inst.image.buf.copy())

    np.testing.assert_array_equal(images[0], img.buf)
    np.testing.assert_array_equal(images[1], img.buf)
    borrow.release()
    t.join(timeout=10)
    assert not t.is_alive()
    b2 = master.catalog.borrow("snap")
    assert b2.version == 1
    b2.release()
