"""Test-suite bootstrap.

On a clean box without ``hypothesis`` installed, register a minimal
deterministic fallback so the property tests still *run* (with fixed
pseudo-random examples) instead of erroring at collection.  When the real
``hypothesis`` is available it is used unchanged.
"""
import functools
import inspect
import sys
import types

import numpy as np

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        """A draw function over a seeded numpy Generator."""

        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def settings(max_examples=25, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(fn, "_fallback_max_examples", 25)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # hide the drawn parameters from pytest's fixture resolution
            del run.__wrapped__
            params = list(inspect.signature(fn).parameters.values())
            run.__signature__ = inspect.Signature(params[: len(params) - len(strategies)])
            return run
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.lists = lists
    _st.sampled_from = sampled_from

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
