"""Test-suite bootstrap.

Two jobs:

1. On a clean box without ``hypothesis`` installed, register a minimal
   deterministic fallback (including a tiny ``hypothesis.stateful``) so the
   property tests still *run* (with fixed pseudo-random examples) instead of
   erroring at collection.  When the real ``hypothesis`` is available it is
   used unchanged.
2. A thread-leak guard: every test asserts that it did not leave new live
   threads behind (bounded grace for daemon workers to exit).  Leaked
   heartbeat/completion threads were a real source of cross-test flakiness.
"""
import functools
import inspect
import sys
import threading
import time
import types

import numpy as np
import pytest

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
    import hypothesis.stateful  # noqa: F401
except ImportError:
    class _Strategy:
        """A draw function over a seeded numpy Generator."""

        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    class settings:
        """Usable both as a decorator (@settings(...)) and as a config object
        passed to run_state_machine_as_test (mirrors the real API shape)."""

        def __init__(self, max_examples=25, deadline=None,
                     stateful_step_count=50, **_kw):
            self.max_examples = max_examples
            self.deadline = deadline
            self.stateful_step_count = stateful_step_count

        def __call__(self, fn):
            fn._fallback_max_examples = self.max_examples
            return fn

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(fn, "_fallback_max_examples", 25)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # hide the drawn parameters from pytest's fixture resolution
            del run.__wrapped__
            params = list(inspect.signature(fn).parameters.values())
            run.__signature__ = inspect.Signature(params[: len(params) - len(strategies)])
            return run
        return deco

    # -- minimal hypothesis.stateful ---------------------------------------
    class RuleBasedStateMachine:
        def teardown(self):
            pass

    def rule(**strategies):
        def deco(fn):
            fn._fallback_rule = strategies
            return fn
        return deco

    def initialize(**strategies):
        def deco(fn):
            fn._fallback_initialize = strategies
            return fn
        return deco

    def invariant():
        def deco(fn):
            fn._fallback_invariant = True
            return fn
        return deco

    def run_state_machine_as_test(cls, settings=None, **_kw):
        """Deterministic replacement: seeded random walks over the rules,
        invariants checked after every rule application."""
        max_examples = getattr(settings, "max_examples", 10)
        step_count = getattr(settings, "stateful_step_count", 50)
        by_name = {}
        for klass in reversed(cls.__mro__):      # inherited rules count too
            for n, m in vars(klass).items():
                if callable(m):
                    by_name[n] = m
        members = [m for _n, m in sorted(by_name.items())]
        inits = [m for m in members if hasattr(m, "_fallback_initialize")]
        rules = [m for m in members if hasattr(m, "_fallback_rule")]
        invs = [m for m in members if getattr(m, "_fallback_invariant", False)]
        assert rules, f"{cls.__name__} defines no @rule methods"
        rng = np.random.default_rng(0)
        for _ex in range(max_examples):
            machine = cls()
            try:
                for fn in inits:
                    fn(machine, **{k: s.draw(rng)
                                   for k, s in fn._fallback_initialize.items()})
                for inv in invs:
                    inv(machine)
                for _step in range(step_count):
                    fn = rules[int(rng.integers(0, len(rules)))]
                    fn(machine, **{k: s.draw(rng)
                                   for k, s in fn._fallback_rule.items()})
                    for inv in invs:
                        inv(machine)
            finally:
                machine.teardown()

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.lists = lists
    _st.sampled_from = sampled_from
    _st.booleans = booleans

    _stateful = types.ModuleType("hypothesis.stateful")
    _stateful.RuleBasedStateMachine = RuleBasedStateMachine
    _stateful.rule = rule
    _stateful.initialize = initialize
    _stateful.invariant = invariant
    _stateful.run_state_machine_as_test = run_state_machine_as_test

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.stateful = _stateful
    _hyp.__fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
    sys.modules["hypothesis.stateful"] = _stateful


# ---------------------------------------------------------------------------
# thread-leak guard
# ---------------------------------------------------------------------------

# Thread names spawned by third-party runtimes (JAX/XLA thread pools etc.)
# that legitimately persist across tests.
_THIRDPARTY_THREAD_MARKERS = ("ThreadPoolExecutor", "pjrt", "xla", "grpc",
                              "QueueFeeder", "Profiler")


def _our_leaked_threads(before):
    leaked = []
    for t in threading.enumerate():
        if t in before or not t.is_alive() or t is threading.current_thread():
            continue
        if any(m.lower() in t.name.lower() for m in _THIRDPARTY_THREAD_MARKERS):
            continue
        leaked.append(t)
    return leaked


@pytest.fixture(autouse=True)
def no_thread_leaks():
    """Every test must join the threads it started (FailoverNode heartbeats,
    RDMA completion workers, skeleton-pool replenishers, ...) AND return
    every demand-read buffer it acquired: once the threads are gone, each
    BufferPool created during the test must have outstanding == 0 (a stop
    with reads in flight may not strand buffers)."""
    from repro.core.serving import BufferPool

    before = set(threading.enumerate())
    pools_before = set(BufferPool._all_pools)   # strong refs: stable snapshot
    yield
    deadline = time.monotonic() + 2.0
    leaked = _our_leaked_threads(before)
    while leaked and time.monotonic() < deadline:
        time.sleep(0.02)
        leaked = _our_leaked_threads(before)
    assert not leaked, (
        f"test leaked threads: {[t.name for t in leaked]} — join/stop them "
        f"(FailoverNode.stop(), RestoredInstance.shutdown(), SkeletonPool.close(), ...)")

    def _unreturned():
        return [p for p in BufferPool._all_pools
                if p not in pools_before and p.outstanding != 0]

    deadline = time.monotonic() + 2.0
    stranded = _unreturned()
    while stranded and time.monotonic() < deadline:
        time.sleep(0.02)
        stranded = _unreturned()
    assert not stranded, (
        f"test stranded {[p.outstanding for p in stranded]} demand-read "
        f"buffer(s) in {len(stranded)} BufferPool(s) — RestoreEngine.stop() "
        f"must drain in-flight completions back to the pool")
