"""SkeletonPool clock injection and condition-driven replenish (ISSUE 7
satellites): ``Skeleton.created_at`` must come from the pool's injected
Clock (deterministic under VirtualClock), and a full pool's replenish
thread must park on a condition instead of polling the stop event."""
import threading
import time

import pytest

from repro.configs.base import get_config
from repro.core.clock import RealClock
from repro.serve.coldstart import SkeletonPool
from repro.sim.clock import VirtualClock

TINY = get_config("qwen2.5-14b").reduced(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=128,
    d_head=32)


class CountingClock(RealClock):
    """RealClock that counts blocking-primitive calls: a busy-polling loop
    shows dozens of waits per second, a parked one shows ~1 total."""

    def __init__(self):
        self.wait_calls = 0

    def cv_wait_for(self, cv, predicate, timeout_s):
        self.wait_calls += 1
        return super().cv_wait_for(cv, predicate, timeout_s)

    def wait_event(self, event, timeout_s):
        self.wait_calls += 1
        return super().wait_event(event, timeout_s)


def test_created_at_uses_injected_clock():
    """Regression: created_at used a time.perf_counter default factory,
    bypassing the injected Clock entirely — under a VirtualClock the stamp
    must be simulated seconds, exactly."""
    clk = VirtualClock(start=100.0)
    sp = SkeletonPool(TINY, batch=1, max_len=32, target_size=1,
                      background=False, clock=clk)
    sk = sp.claim()                      # pre-filled at construction
    assert sk.created_at == 100.0
    clk.advance(5.0)
    sk2 = sp.claim()                     # queue empty -> built on demand
    assert sk2.created_at == 105.0
    assert sp.stats["created_on_demand"] == 1
    sp.close()


def test_full_pool_does_not_spin():
    """Regression: the replenish loop polled the stop event at 100 Hz while
    the pool was full.  With the condition-based loop, a full pool performs
    at most one (parking) wait over a 0.25 s window and never replenishes."""
    clk = CountingClock()
    sp = SkeletonPool(TINY, batch=1, max_len=32, target_size=1,
                      background=True, clock=clk)
    try:
        # let the thread reach its parked state, then watch it stay parked
        deadline = time.monotonic() + 2.0
        while clk.wait_calls < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        calls_before = clk.wait_calls
        time.sleep(0.25)                 # 100 Hz polling would add ~25 calls
        assert clk.wait_calls - calls_before <= 1
        assert clk.wait_calls <= 2
        assert sp.stats["replenished"] == 0
    finally:
        sp.close()


def test_claim_wakes_replenisher():
    """A claim that drains the pool must notify the parked loop, which then
    rebuilds exactly the claimed skeleton."""
    clk = CountingClock()
    sp = SkeletonPool(TINY, batch=1, max_len=32, target_size=1,
                      background=True, clock=clk)
    try:
        sp.claim()
        deadline = time.monotonic() + 10.0
        while sp.stats["replenished"] < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert sp.stats["replenished"] == 1
        assert sp._q.qsize() == 1
    finally:
        sp.close()
    assert not sp._t.is_alive(), "close() must stop the replenish thread"


def test_close_stops_parked_thread_promptly():
    sp = SkeletonPool(TINY, batch=1, max_len=32, target_size=1,
                      background=True)
    t0 = time.monotonic()
    sp.close()
    assert time.monotonic() - t0 < 5.0
    assert not sp._t.is_alive()


def test_virtual_clock_indefinite_wait_does_not_advance():
    """cv_wait_for(None) under VirtualClock returns the predicate without
    moving time: single-threaded sims cannot be notified mid-wait, so an
    indefinite park must not silently jump the clock."""
    clk = VirtualClock(start=7.0)
    cv = threading.Condition()
    with cv:
        assert clk.cv_wait_for(cv, lambda: True, None) is True
        assert clk.cv_wait_for(cv, lambda: False, None) is False
    assert clk.monotonic() == 7.0
