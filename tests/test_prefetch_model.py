"""Predictive prefetch (ISSUE 10): PrefetchModel determinism, the
PrefetchPolicy seam's cold-start fallback, bit-identity of predicted-order
installs, and the permutation property of predicted extent orders."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    HeatRegistry,
    HierarchicalPool,
    LayoutOrderPolicy,
    NodePageServer,
    Orchestrator,
    PoolMaster,
    PredictedOrderPolicy,
    StateImage,
    TouchEvent,
    fit_prefetch_model,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.profiler import RUN_PAGES, HeatMap
from repro.core.prefetch_model import PrefetchPolicy, resolve_policy
from repro.core.profiler import AccessRecorder


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def monotonic(self):
        return self.t


def make_image(seed=0, hot_pages=64, cold_pages=192, zero_pages=128):
    rng = np.random.default_rng(seed)
    arrays = {
        "params": rng.standard_normal(hot_pages * PAGE_SIZE // 4).astype(np.float32),
        "runtime": rng.integers(1, 7, (cold_pages * PAGE_SIZE,)).astype(np.uint8),
        "arena": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
    }
    img = StateImage.build(arrays)
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params")
    return img, rec.working_set()


def feed_sequence(hm, run_sequence, stream=0):
    """Record a first-touch walk visiting each run's pages in order."""
    for r in run_sequence:
        hm.record(TouchEvent(
            pages=np.arange(r * RUN_PAGES, (r + 1) * RUN_PAGES),
            kind="demand_fault", stream=stream))
    hm.end_stream(stream)


# -- model determinism -------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7, 42])
def test_model_fit_and_order_deterministic_per_seed(seed):
    rng = np.random.default_rng(seed)
    n_runs = 12
    hm = HeatMap(n_runs * RUN_PAGES, clock=FakeClock())
    for s in range(4):
        feed_sequence(hm, rng.permutation(n_runs).tolist(), stream=s)

    m1 = fit_prefetch_model(hm)
    m2 = fit_prefetch_model(hm)
    assert m1 is not None and m2 is not None
    assert np.array_equal(m1.trans, m2.trans)
    assert np.array_equal(m1.start, m2.start)
    # same telemetry → identical order, call after call and model after model
    assert np.array_equal(m1.run_order(), m2.run_order())
    assert np.array_equal(m1.run_order(3), m2.run_order(3))
    pages = rng.integers(0, n_runs * RUN_PAGES, 40)
    assert np.array_equal(m1.page_order(pages), m2.page_order(pages))


def test_model_learns_the_taught_chain():
    n_runs = 6
    hm = HeatMap(n_runs * RUN_PAGES, clock=FakeClock())
    chain = [4, 1, 5, 0, 2, 3]
    for s in range(3):
        feed_sequence(hm, chain, stream=s)
    m = fit_prefetch_model(hm)
    order = m.run_order().tolist()
    # with a single observed chain, predicted order IS the chain
    assert order[:len(chain)] == chain
    # seeded mid-chain, successors come first and the seed run drops out
    seeded = m.run_order(seed_run=1).tolist()
    assert seeded[0] == 5 and seeded[1] == 0


def test_fit_returns_none_without_sequence_telemetry():
    hm = HeatMap(4 * RUN_PAGES, clock=FakeClock())
    hm.record(TouchEvent(pages=[0, 1], kind="demand_fault"))   # no stream
    assert fit_prefetch_model(hm) is None
    assert fit_prefetch_model(None) is None


# -- the policy seam ---------------------------------------------------------

class _FakeReader:
    """Stands in for SnapshotReader: fixed cold-extent table."""

    def __init__(self, extents):
        self._extents = list(extents)

    def iter_cold_extents(self, max_extent_pages):
        return iter(self._extents)


class _FakeSession:
    def __init__(self, extents, heat=None):
        self.reader = _FakeReader(extents)
        self.heat = heat


def make_extents(n, pages_per_extent=RUN_PAGES):
    return [(i * pages_per_extent, pages_per_extent, i, 0, pages_per_extent * PAGE_SIZE)
            for i in range(n)]


def test_cold_start_falls_back_to_layout_order():
    exts = make_extents(8)
    sess = _FakeSession(exts, heat=HeatMap(8 * RUN_PAGES, clock=FakeClock()))
    layout = list(LayoutOrderPolicy().order_extents(sess, None))
    predicted = list(PredictedOrderPolicy().order_extents(sess, None))
    assert predicted == layout == exts
    # no heat object at all: same fallback
    sess2 = _FakeSession(exts)
    assert list(PredictedOrderPolicy().order_extents(sess2, None)) == exts


def test_predicted_policy_reorders_and_reseeds():
    hm = HeatMap(8 * RUN_PAGES, clock=FakeClock())
    feed_sequence(hm, [5, 2, 7, 0], stream=0)
    sess = _FakeSession(make_extents(8), heat=hm)
    pol = PredictedOrderPolicy()
    start_order = [e[0] // RUN_PAGES for e in pol.order_extents(sess, None)]
    assert start_order[:4] == [5, 2, 7, 0]
    # demand miss in run 2 re-seeds: 7 then 0 follow
    fault_order = [e[0] // RUN_PAGES
                   for e in pol.order_extents(sess, faulting_page=2 * RUN_PAGES)]
    assert fault_order[:2] == [7, 0]
    assert pol.reseed_on_demand


def test_resolve_policy_shim_warns_and_maps():
    with pytest.warns(DeprecationWarning):
        pol = resolve_policy(None, 16, "test")
    assert isinstance(pol, LayoutOrderPolicy)
    assert pol.max_extent_pages == 16
    default = resolve_policy(None, None, "test")
    assert isinstance(default, LayoutOrderPolicy)
    keep = PredictedOrderPolicy()
    assert resolve_policy(keep, None, "test") is keep


@given(st.integers(min_value=0, max_value=2 ** 32 - 1),
       st.integers(min_value=1, max_value=24))
@settings(max_examples=25, deadline=None)
def test_predicted_order_is_permutation_of_cold_set(seed, n_ext):
    """Whatever the model says, a policy only RE-ORDERS the reader's cold
    extents — the multiset of extents is preserved exactly."""
    rng = np.random.default_rng(seed)
    n_runs = max(n_ext, 4)
    hm = HeatMap(n_runs * RUN_PAGES, clock=FakeClock())
    for s in range(int(rng.integers(0, 3))):
        feed_sequence(hm, rng.permutation(n_runs).tolist(), stream=s)
    exts = make_extents(n_ext)
    sess = _FakeSession(exts, heat=hm)
    pol = PredictedOrderPolicy()
    out = list(pol.order_extents(sess, None))
    assert sorted(out) == sorted(exts)
    fault_page = int(rng.integers(0, n_ext * RUN_PAGES))
    out2 = list(pol.order_extents(sess, faulting_page=fault_page))
    assert sorted(out2) == sorted(exts)


# -- end-to-end bit-identity -------------------------------------------------

def run_full_restore(img, ws, policy, heat=None):
    pool = HierarchicalPool(256 << 20, 512 << 20)
    master = PoolMaster(pool)
    master.publish("s", img, ws)
    server = NodePageServer("h0", pool, heat=heat)
    orch = Orchestrator("h0", pool, master.catalog, node_server=server,
                        prefetch_policy=policy)
    ri = orch.restore("s", pre_install=True, prefetch_cold=True)
    assert ri is not None
    assert ri.engine.wait_prefetch_idle(60)
    ri.engine.install_zero_runs()
    buf = ri.instance.image.buf.copy()
    present = bool(ri.instance.present.all())
    ri.shutdown()
    server.close()
    return buf, present


def test_predicted_and_layout_installs_bit_identical():
    """A trained PredictedOrderPolicy changes only the ORDER bytes land in;
    the final restored image is bit-identical to the snapshot either way."""
    img, ws = make_image(seed=3)
    heat = HeatRegistry(half_life_s=1e6)
    hm = heat.map_for("s", 0, img.total_pages)
    rng = np.random.default_rng(11)
    feed_sequence(hm, rng.permutation(img.total_pages // RUN_PAGES).tolist())

    layout_buf, ok_l = run_full_restore(img, ws, LayoutOrderPolicy(16))
    pred_buf, ok_p = run_full_restore(img, ws, PredictedOrderPolicy(16),
                                      heat=heat)
    assert ok_l and ok_p
    assert np.array_equal(layout_buf, img.buf)
    assert np.array_equal(pred_buf, img.buf)
    assert np.array_equal(pred_buf, layout_buf)


def test_policy_base_class_is_abstract():
    with pytest.raises(NotImplementedError):
        PrefetchPolicy().order_extents(None)
