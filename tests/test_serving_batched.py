"""Run-coalesced batched serving (§3.4): run-index correctness, bit-identity
of the batched restore vs the per-page path, ledger equivalence (batching
never models more time and never undercounts bytes), prefetcher/demand-fault
interplay, and the batched uffd install primitives."""
import threading

import numpy as np
import pytest

from repro.core import (
    HierarchicalPool,
    Instance,
    LayoutOrderPolicy,
    Orchestrator,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
    TimeLedger,
    runs_from_pages,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.pool import MMAP_PER_PAGE_S, MMAP_SYSCALL_S
from repro.core.profiler import AccessRecorder
from repro.core.serving import AsyncRDMAEngine, mmap_install_cost
from repro.core.snapshot import _zstd, runs_of_indices


def make_fragmented_image(seed=0):
    """Image whose hot set is deliberately fragmented (short + long runs)."""
    rng = np.random.default_rng(seed)
    arrays = {
        "params": rng.standard_normal((40000,)).astype(np.float32),   # long hot runs
        "emb": np.zeros((128, 1024), np.float32),
        "runtime": rng.integers(1, 7, (200 * PAGE_SIZE,)).astype(np.uint8),
        "arena": np.zeros((48, 1024), np.float32),                    # zero pages
    }
    arrays["emb"][::4] = rng.standard_normal((32, 1024)).astype(np.float32)
    img = StateImage.build(arrays)
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params")
    rec.touch_rows("emb", [0, 4, 8, 40, 44])       # scattered short hot runs
    rt = img.manifest.by_name()["runtime"]
    for s in (3, 9, 17, 50, 51, 52, 120):          # fragmented runtime spans
        rec.touch_pages(range(rt.first_page + s, rt.first_page + s + 2))
    return img, rec.working_set()


def publish(img, ws, compress_cold=False, cxl=64 << 20, rdma=64 << 20):
    pool = HierarchicalPool(cxl, rdma)
    master = PoolMaster(pool)
    regions = master.publish("t", img, ws, compress_cold=compress_cold)
    return pool, master, regions


def fresh_reader(pool, regions, host="h"):
    ledger = TimeLedger()
    view = pool.host_view(host, ledger)
    reader = SnapshotReader(regions, view, pool.rdma)
    reader.invalidate_cxl()
    return reader, ledger


class TestRunIndex:
    def test_runs_match_runs_from_pages(self):
        img, ws = make_fragmented_image()
        pool, _, regions = publish(img, ws)
        reader, _ = fresh_reader(pool, regions)
        for runs, idx in (
            (reader.hot_runs(), reader.hot_page_indices()),
            (reader.cold_runs(), reader.cold_page_indices()),
            (reader.zero_runs(), reader.zero_page_indices()),
        ):
            expect = runs_from_pages(idx.tolist())
            assert [(int(s), int(n)) for s, n in runs] == expect

    def test_runs_partition_address_space(self):
        img, ws = make_fragmented_image()
        pool, _, regions = publish(img, ws)
        reader, _ = fresh_reader(pool, regions)
        covered = np.zeros(img.total_pages, dtype=int)
        for runs in (reader.hot_runs(), reader.cold_runs(), reader.zero_runs()):
            for s, n in runs:
                covered[int(s) : int(s) + int(n)] += 1
        assert (covered == 1).all()

    def test_runs_of_indices_empty(self):
        assert runs_of_indices(np.zeros(0, np.int64)).shape == (0, 2)

    def test_cold_extent_span_contiguous(self):
        img, ws = make_fragmented_image()
        pool, _, regions = publish(img, ws)
        reader, _ = fresh_reader(pool, regions)
        for s, n in reader.cold_runs():
            s, n = int(s), int(n)
            rank0 = reader.cold_rank(s)
            pool_off, nbytes = reader.cold_extent_span(rank0, n)
            payload = pool.rdma.read(pool_off, nbytes)
            mat = reader.split_cold_extent(rank0, n, payload)
            for i in range(n):
                np.testing.assert_array_equal(mat[i], img.page(s + i))


class TestBatchedRestoreBitIdentical:
    @pytest.mark.parametrize("compress", [False, True])
    def test_batched_vs_perpage(self, compress):
        if compress and _zstd is None:
            pytest.skip("zstandard not installed")
        img, ws = make_fragmented_image()
        pool, _, regions = publish(img, ws, compress_cold=compress)
        bufs = {}
        for batch in (False, True):
            reader, _ = fresh_reader(pool, regions, host=f"h{batch}")
            inst = Instance(StateImage.empty_like(img.manifest))
            eng = RestoreEngine(reader, inst, rdma_engine=None)
            eng.pre_install_hot(use_batch=batch)
            eng.install_all_sync(use_batch=batch)
            assert np.array_equal(inst.image.buf, img.buf)
            bufs[batch] = inst.image.buf.copy()
        assert np.array_equal(bufs[False], bufs[True])

    def test_async_restore_with_prefetcher_bit_identical(self):
        img, ws = make_fragmented_image(seed=5)
        pool, master, _ = publish(img, ws)
        orch = Orchestrator("h0", pool, master.catalog, use_async_rdma=True,
                            prefetch_cold=True,
                            prefetch_policy=LayoutOrderPolicy(16))
        ri = orch.restore("t")
        assert ri is not None
        assert ri.engine.wait_prefetch_idle(30)
        for p in range(img.total_pages):
            ri.engine.access(p)
        assert np.array_equal(ri.instance.image.buf, img.buf)
        assert ri.engine.prefetch_stats["extents_posted"] > 0
        assert ri.engine.prefetch_stats["pages_installed"] > 0
        ri.shutdown()

    def test_scatter_fn_pluggable(self):
        from repro.kernels.page_scatter.ops import page_scatter
        img, ws = make_fragmented_image(seed=2)
        pool, _, regions = publish(img, ws)
        reader, _ = fresh_reader(pool, regions)
        inst = Instance(StateImage.empty_like(img.manifest))
        eng = RestoreEngine(
            reader, inst, rdma_engine=None,
            scatter_fn=lambda dest, compact, idx: page_scatter(dest, compact, idx,
                                                               use_pallas=False))
        eng.pre_install_hot()
        eng.install_all_sync()
        assert np.array_equal(inst.image.buf, img.buf)


class TestLedgerEquivalence:
    def test_batched_never_models_more_time_or_fewer_bytes(self):
        img, ws = make_fragmented_image()
        pool, _, regions = publish(img, ws)
        res = {}
        for batch in (False, True):
            reader, ledger = fresh_reader(pool, regions, host=f"h{batch}")
            inst = Instance(StateImage.empty_like(img.manifest), ledger)
            eng = RestoreEngine(reader, inst, rdma_engine=None)
            eng.pre_install_hot(use_batch=batch)
            pre = dict(ledger.seconds)
            eng.install_all_sync(use_batch=batch)
            res[batch] = (pre, dict(ledger.seconds), inst.stats.copy(),
                          reader.view.stats.copy())
        pre_pp, tot_pp, stats_pp, view_pp = res[False]
        pre_bt, tot_bt, stats_bt, view_bt = res[True]
        # modeled pre-install and total time: batched <= per-page, per class
        for key in ("cxl_read", "uffd_copy"):
            assert pre_bt.get(key, 0.0) <= pre_pp.get(key, 0.0) + 1e-12
        for key in tot_bt:
            assert tot_bt[key] <= tot_pp.get(key, 0.0) + 1e-12
        # never undercounting bytes: same bytes installed, same bytes read
        assert stats_bt["bytes_installed"] == stats_pp["bytes_installed"]
        assert stats_bt["bytes_installed"] == img.total_pages * PAGE_SIZE - \
            int(img.zero_page_bitmap().sum()) * PAGE_SIZE
        assert view_bt["bytes_read"] == view_pp["bytes_read"]

    def test_batch_cost_counts_every_range(self):
        inst = Instance(StateImage.empty_like(
            StateImage.build({"a": np.ones(PAGE_SIZE * 8, np.uint8)}).manifest))
        # two disjoint runs installed in ONE batch: 2 ioctls charged
        pages = np.array([0, 1, 4, 5])
        mat = np.ones((4, PAGE_SIZE), np.uint8)
        assert inst.uffd_copy_batch(pages, mat) == 4
        from repro.core.pool import uffd_copy_batch_cost
        assert inst.ledger.seconds["uffd_copy"] == pytest.approx(
            uffd_copy_batch_cost(4, 2))

    def test_mmap_install_cost_charges_per_range(self):
        pages = [0, 1, 2, 10, 11]          # two ranges
        got = mmap_install_cost(pages)
        assert got == pytest.approx(5 * MMAP_PER_PAGE_S + 2 * MMAP_SYSCALL_S)
        assert got > 5 * MMAP_PER_PAGE_S   # the per-range term is not dead code


class TestBatchPrimitives:
    def _image(self):
        return StateImage.build({"a": np.zeros(PAGE_SIZE * 16, np.uint8)})

    def test_copy_batch_idempotent(self):
        inst = Instance(StateImage.empty_like(self._image().manifest))
        pages = np.arange(4)
        mat = np.full((4, PAGE_SIZE), 7, np.uint8)
        assert inst.uffd_copy_batch(pages, mat) == 4
        assert inst.uffd_copy_batch(pages, mat) == 0     # all present: no-op
        assert inst.stats["uffd_copies"] == 4
        # partial overlap installs only the missing pages
        pages2 = np.arange(2, 6)
        assert inst.uffd_copy_batch(pages2, np.full((4, PAGE_SIZE), 9, np.uint8)) == 2
        np.testing.assert_array_equal(inst.image.page(3), np.full(PAGE_SIZE, 7))
        np.testing.assert_array_equal(inst.image.page(4), np.full(PAGE_SIZE, 9))

    def test_zeropage_range_idempotent(self):
        inst = Instance(StateImage.empty_like(self._image().manifest))
        assert inst.uffd_zeropage_range(0, 8) == 8
        assert inst.uffd_zeropage_range(0, 8) == 0
        assert inst.uffd_zeropage_range(4, 8) == 4       # only 8..11 new
        assert inst.stats["uffd_zeropages"] == 12
        assert inst.present[:12].all() and not inst.present[12:].any()


class TestPrefetcherDemandRace:
    def test_demand_fault_during_inflight_prefetch_installs_once(self):
        img, ws = make_fragmented_image(seed=9)
        pool, master, _ = publish(img, ws)
        orch = Orchestrator("h0", pool, master.catalog, use_async_rdma=True,
                            prefetch_cold=True,
                            prefetch_policy=LayoutOrderPolicy(8))
        ri = orch.restore("t")
        assert ri is not None
        cold = ri.engine.reader.cold_page_indices()
        # hammer demand faults over the cold set while the prefetcher streams
        errs = []

        def hammer(pages):
            try:
                for p in pages:
                    ri.engine.access(int(p), timeout_s=30)
            except Exception as e:     # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=hammer, args=(cold[i::4],))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errs
        assert ri.engine.wait_prefetch_idle(30)
        for p in range(img.total_pages):
            ri.engine.access(p)
        # exactly-once: uffd_copies counts actual installs; every non-zero
        # page was installed exactly once even under the race
        nonzero_pages = img.total_pages - int(img.zero_page_bitmap().sum())
        assert ri.instance.stats["uffd_copies"] == nonzero_pages
        assert ri.instance.stats["bytes_installed"] == nonzero_pages * PAGE_SIZE
        assert np.array_equal(ri.instance.image.buf, img.buf)
        ri.shutdown()


class TestAsyncEngineStats:
    def test_event_waits_only_counts_actual_waits(self):
        pool = HierarchicalPool(4 << 20, 4 << 20)
        ledger = TimeLedger()
        eng = AsyncRDMAEngine(pool.rdma, ledger)
        try:
            buf = np.empty(PAGE_SIZE, np.uint8)
            eng.submit_read(0, PAGE_SIZE, buf, ("page", 0, PAGE_SIZE, True, "rdma"))
            # wait until the CQ has the completion queued
            for _ in range(200):
                if not eng._cq.empty():
                    break
                threading.Event().wait(0.005)
            assert not eng._cq.empty()
            got = eng.poll_completion(block=True)
            assert got is not None
            assert eng.stats["event_waits"] == 0     # entry was ready: no wait
            assert eng.poll_completion(block=True, timeout_s=0.01) is None
            assert eng.stats["event_waits"] == 1     # this one actually waited
        finally:
            eng.close()

    def test_urgent_reads_counted(self):
        pool = HierarchicalPool(4 << 20, 4 << 20)
        eng = AsyncRDMAEngine(pool.rdma, TimeLedger())
        try:
            buf = np.empty(PAGE_SIZE, np.uint8)
            eng.submit_read(0, PAGE_SIZE, buf, ("page", 0, PAGE_SIZE, True, "rdma"),
                            urgent=True)
            got = None
            for _ in range(200):
                got = eng.poll_completion(block=True, timeout_s=0.05)
                if got is not None:
                    break
            assert got is not None
            assert eng.stats["urgent_reads"] == 1
        finally:
            eng.close()
