"""Training substrate: optimizer math, loss behaviour, gradient compression,
and the data pipeline's fleet properties."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model_zoo import build
from repro.sharding.collectives import ErrorFeedback, compress_tree, quantize_int8, dequantize_int8
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_adamw,
    lr_schedule,
)
from repro.train.trainstep import init_train_state, make_train_step

TINY = get_config("qwen2.5-14b").reduced(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=128, d_head=32)

# Whole module jit-compiles train steps (slowest file in the suite): slow tier.
pytestmark = pytest.mark.slow


class TestOptimizer:
    def test_adamw_matches_reference_step(self):
        """One AdamW step vs a hand-rolled numpy reference."""
        cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                          weight_decay=0.1, clip_norm=1e9, min_lr_ratio=1.0)
        p = {"w": jnp.asarray([[1.0, -2.0]], jnp.float32)}
        g = {"w": jnp.asarray([[0.5, 0.25]], jnp.float32)}
        state = init_adamw(p)
        new_p, new_state, _ = adamw_update(cfg, g, state, p)
        # reference
        m = 0.1 * np.array([[0.5, 0.25]])
        v = 0.05 * np.array([[0.5, 0.25]]) ** 2
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.95)
        ref = np.array([[1.0, -2.0]]) - 1e-2 * (mh / (np.sqrt(vh) + 1e-8)
                                                + 0.1 * np.array([[1.0, -2.0]]))
        np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)

    def test_clip_and_schedule(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
        assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
        g = {"a": jnp.full((10,), 10.0)}
        n = float(global_norm(g))
        assert n == pytest.approx(np.sqrt(1000.0))

    def test_weight_decay_skips_1d(self):
        cfg = AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=1.0, clip_norm=1e9,
                          min_lr_ratio=1.0)
        p = {"scale": jnp.ones((4,)), "w": jnp.ones((2, 2))}
        g = jax.tree.map(jnp.zeros_like, p)
        new_p, _, _ = adamw_update(cfg, g, init_adamw(p), p)
        np.testing.assert_allclose(np.asarray(new_p["scale"]), 1.0)  # no decay
        assert float(new_p["w"][0, 0]) < 1.0                         # decayed


class TestTraining:
    def test_loss_decreases(self):
        model = build(TINY)
        data = SyntheticLMData(DataConfig(vocab=TINY.vocab, seq_len=64, global_batch=8))
        step = jax.jit(make_train_step(model))
        state = init_train_state(model, jax.random.PRNGKey(0))
        losses = []
        for i in range(30):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.1, losses[::10]
        assert not np.isnan(losses[-1])

    def test_moe_train_step_runs_with_aux(self):
        cfg = get_config("olmoe-1b-7b").reduced(vocab=128)
        model = build(cfg)
        data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
        step = jax.jit(make_train_step(model))
        state = init_train_state(model, jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        state, metrics = step(state, batch)
        assert float(metrics["aux"]) > 0.0
        assert np.isfinite(float(metrics["loss"]))

    def test_mtp_train_step(self):
        cfg = get_config("deepseek-v3-671b").reduced(vocab=128)
        assert cfg.mtp
        model = build(cfg)
        data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
        step = jax.jit(make_train_step(model))
        state = init_train_state(model, jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        state, metrics = step(state, batch)
        assert "mtp" in metrics and np.isfinite(float(metrics["mtp"]))


class TestGradCompression:
    @given(st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_int8_roundtrip_error_bound(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((64,)) * rng.uniform(0.01, 100))
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s)
        assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-9

    def test_error_feedback_converges(self):
        """EF-compressed grads still train (loss decreases comparably)."""
        model = build(TINY)
        data = SyntheticLMData(DataConfig(vocab=TINY.vocab, seq_len=64, global_batch=8))
        state = init_train_state(model, jax.random.PRNGKey(0))
        ef = ErrorFeedback(state.params)
        step = jax.jit(make_train_step(model))       # uncompressed reference

        from repro.train.trainstep import make_loss_fn, TrainState
        from repro.train.optimizer import AdamWConfig, adamw_update
        loss_fn = make_loss_fn(model)

        def ef_step(state, batch):
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params, batch)
            grads = ef(grads)                        # int8 + error feedback
            p, o, _ = adamw_update(AdamWConfig(), grads, state.opt, state.params)
            return TrainState(p, o), loss

        losses = []
        for i in range(15):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, loss = ef_step(state, batch)
            losses.append(float(loss))
        assert losses[-1] < losses[0]


class TestDataPipeline:
    def test_determinism_and_skip_ahead(self):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
        a = SyntheticLMData(cfg)
        b = SyntheticLMData(cfg)
        np.testing.assert_array_equal(a.batch_at(17)["tokens"], b.batch_at(17)["tokens"])

    def test_shards_partition_global_batch(self):
        cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
        shards = [SyntheticLMData(cfg, shard=i, num_shards=4) for i in range(4)]
        batches = [s.batch_at(3)["tokens"] for s in shards]
        assert all(b.shape == (2, 16) for b in batches)
        # different shards see different data
        assert not np.array_equal(batches[0], batches[1])

    def test_restart_resumes_identical_stream(self):
        cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
        run1 = [SyntheticLMData(cfg).batch_at(i)["tokens"] for i in range(5)]
        restarted = SyntheticLMData(cfg)                      # "new worker"
        run2 = [restarted.batch_at(i)["tokens"] for i in range(5)]
        for x, y in zip(run1, run2):
            np.testing.assert_array_equal(x, y)
