"""Content-addressed dedup: store semantics, collision safety, end-to-end
bit-exactness, capacity accounting, and cross-variant fan-out (ISSUE 5).

The hash seam (``DedupStore.hash_fn``) is exercised with a deliberately
colliding hash: the store must byte-verify every hash match before sharing,
so a collision costs a bucket slot, never correctness.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    DedupStore,
    HierarchicalPool,
    Instance,
    NodePageServer,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
    build_snapshot,
    estimate_snapshot_cxl_size,
    exclusive_cxl_bytes,
    fnv1a_page,
    fnv1a_pages,
    free_snapshot,
    reconstruct_image,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.pool import AllocError, MemoryTier, CXL_COST

RNG = np.random.default_rng(7)


def page(fill=None):
    if fill is None:
        return RNG.integers(0, 256, PAGE_SIZE, dtype=np.uint8).astype(np.uint8)
    return np.full(PAGE_SIZE, fill, dtype=np.uint8)


def small_pool(**kw):
    kw.setdefault("cxl_capacity", 32 << 20)
    kw.setdefault("rdma_capacity", 64 << 20)
    return HierarchicalPool(**kw)


def variant_image(base: np.ndarray, delta_pages, cold_pages=4, zero_pages=2,
                  seed=0):
    """Fine-tuned-variant image: shared base weights + per-variant deltas."""
    rng = np.random.default_rng(seed)
    w = base.copy()
    for i, p in enumerate(np.atleast_1d(delta_pages)):
        w[p * PAGE_SIZE : (p + 1) * PAGE_SIZE] = (i + 1 + seed) % 251 + 1
    return StateImage.build({
        "w": w,
        "cold": rng.integers(1, 255, cold_pages * PAGE_SIZE).astype(np.uint8),
        "z": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
    })


# ---------------------------------------------------------------------------
# DedupStore unit semantics
# ---------------------------------------------------------------------------

class TestDedupStore:
    def test_put_release_refcount_and_free(self):
        tier = MemoryTier("cxl", 1 << 20, CXL_COST)
        store = DedupStore(tier)
        a, b = page(1), page(2)
        off_a1 = store.put(a)
        off_a2 = store.put(a)
        off_b = store.put(b)
        assert off_a1 == off_a2 and off_b != off_a1
        assert store.refcounts() == {off_a1: 2, off_b: 1}
        assert tier.bytes_in_use == 2 * PAGE_SIZE
        store.release(off_a1)
        assert store.refcounts()[off_a1] == 1
        assert tier.bytes_in_use == 2 * PAGE_SIZE       # not freed yet
        store.release(off_a1)
        store.release(off_b)
        assert store.refcounts() == {}
        assert tier.bytes_in_use == 0                   # freed at refcount zero
        assert store.stats["freed"] == 2

    def test_release_unknown_offset_raises(self):
        store = DedupStore(MemoryTier("cxl", 1 << 20, CXL_COST))
        with pytest.raises(ValueError):
            store.release(12345)

    def test_forced_hash_collision_is_byte_verified(self):
        """Adversarial hash (everything collides): distinct contents must get
        distinct pages, identical contents must still share."""
        tier = MemoryTier("cxl", 1 << 20, CXL_COST)
        store = DedupStore(tier, hash_fn=lambda m: np.zeros(m.shape[0], np.uint64))
        a, b = page(1), page(2)
        off_a = store.put(a)
        off_b = store.put(b)                 # collides with a, different bytes
        assert off_a != off_b, "collision must not alias distinct contents"
        assert store.stats["collisions"] == 1
        assert store.put(b) == off_b         # same bytes still dedup in-bucket
        assert np.array_equal(tier.buf[off_a : off_a + PAGE_SIZE], a)
        assert np.array_equal(tier.buf[off_b : off_b + PAGE_SIZE], b)
        # releases tear the bucket down without cross-freeing
        store.release(off_a)
        assert store.refcounts() == {off_b: 2}
        store.release(off_b)
        store.release(off_b)
        assert tier.bytes_in_use == 0

    def test_put_pages_vectorized_matches_scalar(self):
        tier = MemoryTier("cxl", 1 << 20, CXL_COST)
        store = DedupStore(tier)
        mat = np.stack([page(1), page(2), page(1), page()])
        offs = store.put_pages(mat)
        assert offs[0] == offs[2] and len(set(map(int, offs))) == 3
        assert np.array_equal(fnv1a_pages(mat),
                              np.array([fnv1a_page(r) for r in mat],
                                       dtype=np.uint64))

    def test_probe_new_bytes_counts_marginal_uniques(self):
        tier = MemoryTier("cxl", 1 << 20, CXL_COST)
        store = DedupStore(tier)
        a, b, c = page(1), page(2), page(3)
        store.put_pages(np.stack([a, b]))
        # c is new; a is stored; duplicate c in one batch counts once
        assert store.probe_new_bytes(np.stack([a, c, c])) == PAGE_SIZE
        assert store.probe_new_bytes(np.stack([a, b])) == 0
        assert tier.bytes_in_use == 2 * PAGE_SIZE       # probe stored nothing

    def test_mid_batch_alloc_failure_rolls_back(self):
        tier = MemoryTier("cxl", 2 * PAGE_SIZE, CXL_COST)   # room for 2 pages
        store = DedupStore(tier)
        mat = np.stack([page(1), page(2), page(3)])
        with pytest.raises(AllocError):
            store.put_pages(mat)
        assert store.refcounts() == {}
        assert tier.bytes_in_use == 0, "failed put must leave no residue"

    def test_page_checksum_hash_fn_plugs_in(self):
        """The kernels/page_checksum polynomial hash satisfies the HashFn
        seam (CPU oracle path; the Pallas kernel shares the signature)."""
        from repro.core.dedup import pallas_hash_fn

        tier = MemoryTier("cxl", 1 << 20, CXL_COST)
        store = DedupStore(tier, hash_fn=pallas_hash_fn)
        a, b = page(1), page(2)
        off_a = store.put(a)
        assert store.put(a) == off_a
        assert store.put(b) != off_a
        assert store.dedup_ratio() > 0


# ---------------------------------------------------------------------------
# snapshot layout round-trips
# ---------------------------------------------------------------------------

class TestDedupSnapshot:
    def test_build_reconstruct_free_round_trip(self):
        pool = small_pool()
        base = RNG.integers(1, 255, 32 * PAGE_SIZE).astype(np.uint8)
        img = variant_image(base, [0])
        ws = list(range(16))
        r = build_snapshot(pool, img, ws, "s", dedup=True)
        assert r.dedup and r.rdma_size == 0
        rec = reconstruct_image(pool, r)
        assert np.array_equal(rec.buf, img.buf)
        free_snapshot(pool, r)
        assert pool.cxl.bytes_in_use == 0 and pool.rdma.bytes_in_use == 0
        assert pool.dedup_cxl.refcounts() == {} and pool.dedup_rdma.refcounts() == {}

    def test_estimate_matches_build_marginal_bytes(self):
        pool = small_pool()
        base = RNG.integers(1, 255, 24 * PAGE_SIZE).astype(np.uint8)
        img0 = variant_image(base, [0], seed=0)
        img1 = variant_image(base, [1], seed=1)
        ws = list(range(24))
        est0 = estimate_snapshot_cxl_size(img0, ws, dedup=True, pool=pool)
        before = pool.cxl.bytes_in_use
        r0 = build_snapshot(pool, img0, ws, "v0", dedup=True)
        assert pool.cxl.bytes_in_use - before == est0
        # the variant's estimate is MARGINAL: one delta page + metadata
        est1 = estimate_snapshot_cxl_size(img1, ws, dedup=True, pool=pool)
        before = pool.cxl.bytes_in_use
        r1 = build_snapshot(pool, img1, ws, "v1", dedup=True)
        assert pool.cxl.bytes_in_use - before == est1
        assert est1 == r1.ms_size + r1.oa_size + 2 * PAGE_SIZE  # 2 delta pages
        for r in (r1, r0):
            free_snapshot(pool, r)
        assert pool.cxl.bytes_in_use == 0

    def test_exclusive_bytes_shared_vs_private(self):
        pool = small_pool()
        base = RNG.integers(1, 255, 16 * PAGE_SIZE).astype(np.uint8)
        imgs = [variant_image(base, [i], seed=i) for i in range(2)]
        ws = list(range(16))
        r0 = build_snapshot(pool, imgs[0], ws, "v0", dedup=True)
        assert exclusive_cxl_bytes(pool, r0) == 16 * PAGE_SIZE  # alone: all mine
        r1 = build_snapshot(pool, imgs[1], ws, "v1", dedup=True)
        # each variant now exclusively owns its own delta page plus the base
        # page the OTHER variant replaced; the remaining 14 are shared
        assert exclusive_cxl_bytes(pool, r0) == 2 * PAGE_SIZE
        assert exclusive_cxl_bytes(pool, r1) == 2 * PAGE_SIZE
        free_snapshot(pool, r1)
        assert exclusive_cxl_bytes(pool, r0) == 16 * PAGE_SIZE
        free_snapshot(pool, r0)

    def test_invalidate_flushes_noncontiguous_hot_pages(self):
        """Dedup hot pages are scattered in the tier; the borrow-protocol
        flush must cover every one of them, not just the metadata region."""
        pool = small_pool()
        base = RNG.integers(1, 255, 8 * PAGE_SIZE).astype(np.uint8)
        img = variant_image(base, [0])
        r = build_snapshot(pool, img, list(range(8)), "s", dedup=True)
        view = pool.host_view("h")
        reader = SnapshotReader(r, view, pool.rdma)
        reader.invalidate_cxl()
        hot = reader.hot_page_indices()
        p = int(hot[3])
        first = reader.read_page(p).copy()      # populates the host cache
        kind, off = reader.lookup(p)
        assert kind == "cxl"
        pool.cxl.write(off, np.full(PAGE_SIZE, 0xAB, np.uint8))   # owner rewrite
        assert np.array_equal(reader.read_page(p), first), \
            "incoherent view must serve stale bytes before the flush"
        reader.invalidate_cxl()
        assert np.all(reader.read_page(p) == 0xAB), \
            "per-page flush must reach scattered dedup pages"
        free_snapshot(pool, r)

    def test_collision_seam_end_to_end_bit_identical(self):
        """Publishes under an always-colliding hash stay bit-exact."""
        pool = small_pool()
        pool.dedup_cxl.hash_fn = lambda m: np.zeros(m.shape[0], np.uint64)
        pool.dedup_rdma.hash_fn = lambda m: np.zeros(m.shape[0], np.uint64)
        master = PoolMaster(pool, dedup=True)
        base = RNG.integers(1, 255, 12 * PAGE_SIZE).astype(np.uint8)
        for i in range(2):
            img = variant_image(base, [i], seed=i)
            master.publish(f"v{i}", img, list(range(12)))
            rec = reconstruct_image(pool, master.catalog.find(f"v{i}").regions)
            assert np.array_equal(rec.buf, img.buf)
        assert pool.dedup_cxl.stats["collisions"] > 0


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=7), min_size=0, max_size=24),
       st.lists(st.integers(min_value=0, max_value=30), min_size=0, max_size=10),
       st.booleans())
def test_dedup_round_trip_property(fills, ws_pages, use_batch):
    """Property (ISSUE 5 satellite): arbitrary page sets — duplicate-heavy
    fills, arbitrary working sets, batched or per-page serving — round-trip
    bit-exactly through a dedup publish + restore, and freeing the snapshot
    returns the pool to its starting state."""
    pool = small_pool()
    n_pages = max(1, len(fills))
    buf = np.zeros(n_pages * PAGE_SIZE, np.uint8)
    for i, f in enumerate(fills):
        buf[i * PAGE_SIZE : (i + 1) * PAGE_SIZE] = f    # 0 ⇒ a zero page
    img = StateImage.build({"a": buf})
    ws = [p for p in set(ws_pages) if p < n_pages]
    r = build_snapshot(pool, img, ws, "prop", dedup=True)
    view = pool.host_view("h")
    reader = SnapshotReader(r, view, pool.rdma)
    reader.invalidate_cxl()
    inst = Instance(StateImage.empty_like(img.manifest))
    eng = RestoreEngine(reader, inst, rdma_engine=None)
    eng.install_all_sync(use_batch=use_batch)
    assert inst.all_present()
    assert np.array_equal(inst.image.buf, img.buf)
    free_snapshot(pool, r)
    assert pool.cxl.bytes_in_use == 0 and pool.rdma.bytes_in_use == 0


# ---------------------------------------------------------------------------
# ownership protocol + capacity integration
# ---------------------------------------------------------------------------

class TestDedupMaster:
    def test_variants_share_and_drain_on_delete(self):
        pool = small_pool()
        master = PoolMaster(pool, dedup=True)
        base = RNG.integers(1, 255, 20 * PAGE_SIZE).astype(np.uint8)
        imgs = [variant_image(base, [i], seed=i) for i in range(3)]
        for i, img in enumerate(imgs):
            master.publish(f"v{i}", img, list(range(20)))
        store = pool.dedup_cxl
        assert store.unique_pages() == 20 + 3        # base + one delta each
        assert store.logical_pages() == 60
        # update keeps sharing: v0 republishes with v1's content
        master.publish("v0", imgs[1], list(range(20)))
        assert store.unique_pages() == 20 + 2, "v0's old delta page must free"
        for i in range(3):
            master.delete(f"v{i}")
        master.gc()
        assert store.refcounts() == {}
        assert pool.cxl.bytes_in_use == 0 and pool.rdma.bytes_in_use == 0

    def test_capacity_accounts_unique_bytes(self):
        """A budget that could hold ~2 private snapshots holds a whole
        variant fleet once the budget gauge counts unique bytes."""
        pool = small_pool()
        base = RNG.integers(1, 255, 32 * PAGE_SIZE).astype(np.uint8)
        imgs = [variant_image(base, [i], seed=i) for i in range(6)]
        ws = list(range(32))
        # budget: base copy + fleet deltas + metadata, far below 6 full copies
        budget = (32 + 6 * 3) * PAGE_SIZE
        master = PoolMaster(pool, cxl_budget=budget, dedup=True)
        for i, img in enumerate(imgs):
            master.publish(f"v{i}", img, ws)
        rep = master.capacity.report()
        assert rep["demotions"] == 0 and rep["degraded"] == 0
        for i in range(6):
            assert master.catalog.find(f"v{i}").regions.n_hot == 32
        assert rep["in_use"] == sum(
            e.regions.cxl_size for e in master.catalog.entries
            if e.regions is not None) + pool.dedup_cxl.unique_bytes()

    def test_clock_skips_fully_shared_victims(self):
        """Demoting a snapshot whose every hot page is shared reclaims
        nothing — the clock must skip it and degrade the newcomer instead."""
        pool = small_pool()
        base = RNG.integers(1, 255, 16 * PAGE_SIZE).astype(np.uint8)
        img = variant_image(base, [], seed=0)
        twin = variant_image(base, [], seed=0)
        ws = list(range(16))
        master = PoolMaster(pool, cxl_budget=22 * PAGE_SIZE, dedup=True)
        master.publish("a", img, ws)
        master.publish("b", twin, ws)            # bit-identical: fully shared
        big = variant_image(
            RNG.integers(1, 255, 16 * PAGE_SIZE).astype(np.uint8), [], seed=3)
        master.publish("big", big, ws)
        rep = master.capacity.report()
        assert rep["shared_skips"] >= 1, "clock must notice zero-exclusive victims"
        assert rep["demotions"] == 0
        assert rep["degraded"] >= 1
        for name in ("a", "b"):
            assert master.catalog.find(name).regions.n_hot == 16, \
                "useless demotion of a fully-shared snapshot"
        # correctness didn't degrade: everything restores bit-exactly
        for name, src in (("a", img), ("b", twin), ("big", big)):
            rec = reconstruct_image(pool, master.catalog.find(name).regions)
            assert np.array_equal(rec.buf, src.buf)


# ---------------------------------------------------------------------------
# cross-variant hot-chunk fan-out (NodePageServer)
# ---------------------------------------------------------------------------

class TestCrossVariantFanout:
    def test_different_variants_share_physical_hot_reads(self):
        pool = small_pool()
        master = PoolMaster(pool, dedup=True)
        base = RNG.integers(1, 255, 24 * PAGE_SIZE).astype(np.uint8)
        imgs = {f"v{i}": variant_image(base, [i], seed=i) for i in range(2)}
        for name, img in imgs.items():
            master.publish(name, img, list(range(24)))
        server = NodePageServer("h", pool)
        try:
            sessions = []
            for name, img in imgs.items():
                borrow = master.catalog.borrow(name)
                reader = SnapshotReader(borrow.regions, pool.host_view("h"),
                                        pool.rdma)
                reader.invalidate_cxl()
                inst = Instance(StateImage.empty_like(img.manifest))
                s = server.attach(name, borrow.version, reader, inst)
                s.pre_install_hot(chunk_pages=8)
                sessions.append((name, img, borrow, s))
            # the base chunks were physically read once, shared across the
            # two DIFFERENT (name, version) fan-out groups
            assert server.chunks.stats["cross_group_hits"] > 0
            for name, img, borrow, s in sessions:
                s.install_all_sync()
                assert np.array_equal(s.instance.image.buf, imgs[name].buf)
                s.stop()
                borrow.release()
            assert server.chunks.drop_group(("v0", 0)) == 0  # already dropped
        finally:
            server.close()
