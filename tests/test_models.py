"""Per-architecture smoke tests + decode-vs-forward parity for every family.

Smoke: REDUCED configs of each assigned arch run one forward + one decode
step on CPU, asserting output shapes and no NaNs (full configs are exercised
by the dry-run only).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import all_arch_names, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, cell_supported
from repro.models.model_zoo import build
from repro.models.common import embed

KEY = jax.random.PRNGKey(0)
SMALL_TRAIN = ShapeSpec("t", 64, 2, "train")
SMALL_DECODE = ShapeSpec("d", 64, 2, "decode")

# Whole module is model-compile heavy (minutes of XLA time): slow tier only.
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", all_arch_names())
def test_smoke_forward_and_decode(arch, rng):
    cfg = get_config(arch).reduced()
    m = build(cfg)
    params = m.init(KEY)
    batch = m.make_batch(rng, SMALL_TRAIN)
    logits, aux = m.forward(params, batch)
    assert logits.shape == (2, 64, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    caches = m.init_caches(params, 2, 64)
    db = m.make_batch(rng, SMALL_DECODE)
    dlogits, _ = m.decode_step(params, db, caches)
    assert dlogits.shape == (2, 1, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(dlogits, np.float32)))


@pytest.mark.parametrize("arch", all_arch_names())
def test_full_configs_param_counts(arch):
    """Analytic parameter counts should be in the advertised ballpark."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen2-vl-72b": 72e9, "qwen2.5-32b": 32e9, "qwen2.5-14b": 14e9,
        "mistral-large-123b": 123e9, "phi4-mini-3.8b": 3.8e9,
        "xlstm-125m": 125e6, "deepseek-v3-671b": 671e9,
        "olmoe-1b-7b": 7e9, "zamba2-2.7b": 2.7e9,
        "seamless-m4t-medium": 1.2e9,
    }[arch]
    assert 0.5 * expected < n < 1.7 * expected, f"{arch}: {n:.3e} vs {expected:.3e}"


def _decode_all(m, params, tokens, caches):
    outs = []
    for t in range(tokens.shape[1]):
        lg, caches = m.decode_step(
            params, {"tokens": tokens[:, t:t + 1], "pos": jnp.asarray(t, jnp.int32)}, caches)
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("arch", [
    "qwen2.5-14b", "mistral-large-123b", "phi4-mini-3.8b",
    "xlstm-125m", "zamba2-2.7b",
])
def test_decode_parity(arch, rng):
    """Single-token decode with caches == full-sequence forward."""
    S = 16
    kw = dict(compute_dtype="float32", param_dtype="float32")
    cfg = get_config(arch).reduced(**kw)
    if cfg.family in ("ssm", "hybrid"):
        cfg = cfg.reduced(**kw, ssm_chunk=8)
    m = build(cfg)
    params = m.init(KEY)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, S)), jnp.int32)
    full, _ = m.forward(params, {"tokens": tokens})
    dec = _decode_all(m, params, tokens, m.init_caches(params, 2, S))
    rel = float(jnp.abs(full - dec).max()) / float(jnp.abs(full).max())
    assert rel < 2e-3, rel


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "olmoe-1b-7b"])
def test_decode_parity_moe_nodrop(arch, rng):
    """MoE parity holds under a no-drop capacity factor (dropping is
    group-dependent by design)."""
    S = 16
    base = get_config(arch).reduced(compute_dtype="float32", param_dtype="float32")
    cfg = base.reduced(compute_dtype="float32", param_dtype="float32",
                       capacity_factor=float(base.n_experts) / base.top_k)
    m = build(cfg)
    params = m.init(KEY)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, S)), jnp.int32)
    full, _ = m.forward(params, {"tokens": tokens})
    dec = _decode_all(m, params, tokens, m.init_caches(params, 2, S))
    rel = float(jnp.abs(full - dec).max()) / float(jnp.abs(full).max())
    assert rel < 2e-3, rel


def test_decode_parity_vlm(rng):
    """Full M-RoPE decode path == forward when vision embeds are the token
    embeddings (removes the modality difference, keeps the position math)."""
    S = 32
    cfg = get_config("qwen2-vl-72b").reduced(compute_dtype="float32",
                                             param_dtype="float32")
    m = build(cfg)
    params = m.init(KEY)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, S)), jnp.int32)
    vis = embed(params["embed"], tokens[:, :cfg.vision_prefix], jnp.float32)
    full, _ = m.forward(params, {"tokens": tokens, "vision_embeds": vis})
    dec = _decode_all(m, params, tokens, m.init_caches(params, 2, S))
    rel = float(jnp.abs(full - dec).max()) / float(jnp.abs(full).max())
    assert rel < 2e-5, rel


def test_decode_parity_encdec(rng):
    S = 16
    cfg = get_config("seamless-m4t-medium").reduced(compute_dtype="float32",
                                                    param_dtype="float32")
    m = build(cfg)
    params = m.init(KEY)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, S)), jnp.int32)
    frames = jnp.asarray(rng.standard_normal((2, S, cfg.d_model)), jnp.float32)
    full, _ = m.forward(params, {"tokens": tokens, "frames": frames})
    from repro.models.encdec import encode
    enc_out = encode(params, frames, cfg)
    caches = m.init_caches(params, 2, S, enc_out=enc_out)
    dec = _decode_all(m, params, tokens, caches)
    rel = float(jnp.abs(full - dec).max()) / float(jnp.abs(full).max())
    assert rel < 2e-3, rel


def test_mamba2_chunk_invariance(rng):
    """SSD chunked scan must be chunk-size invariant (same math)."""
    from repro.models import ssm
    cfg = get_config("zamba2-2.7b").reduced(compute_dtype="float32",
                                            param_dtype="float32")
    params = ssm.init_mamba2(KEY, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    import dataclasses
    y8 = ssm.mamba2_ssd(params, x, dataclasses.replace(cfg, ssm_chunk=8))
    y16 = ssm.mamba2_ssd(params, x, dataclasses.replace(cfg, ssm_chunk=16))
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=2e-4, atol=2e-5)


def test_long_500k_cell_support_flags():
    runs = {a: cell_supported(get_config(a), SHAPES["long_500k"])[0]
            for a in all_arch_names()}
    assert runs == {
        "qwen2-vl-72b": False, "qwen2.5-32b": False, "qwen2.5-14b": False,
        "mistral-large-123b": False, "phi4-mini-3.8b": False,
        "xlstm-125m": True, "deepseek-v3-671b": False, "olmoe-1b-7b": False,
        "zamba2-2.7b": True, "seamless-m4t-medium": False,
    }
