"""Fault tolerance: Aquifer checkpoint/restart, crash recovery, elastic
resharding, straggler-tolerant restore (hot-first)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import HierarchicalPool, Orchestrator, PoolMaster
from repro.checkpoint.ckpt import (
    default_train_hotness,
    flatten_state,
    restore_checkpoint,
    reshard,
    save_checkpoint,
    unflatten_state,
)
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model_zoo import build
from repro.train.loop import LoopConfig, Trainer
from repro.train.trainstep import init_train_state, make_train_step

TINY = get_config("qwen2.5-14b").reduced(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=128, d_head=32)


def make_stack():
    pool = HierarchicalPool(512 << 20, 1 << 30)
    master = PoolMaster(pool)
    orch = Orchestrator("host0", pool, master.catalog)
    return pool, master, orch


class TestCheckpoint:
    def test_state_roundtrip_bit_identical(self):
        model = build(TINY)
        state = init_train_state(model, jax.random.PRNGKey(0))
        tree = {"params": state.params, "opt": state.opt}
        pool, master, orch = make_stack()
        save_checkpoint(master, "ck", tree, step=0)
        restored, stats = restore_checkpoint(orch, "ck", tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # hot tier restore completes before the full state (straggler tolerance)
        assert stats["time_to_hot_s"] <= stats["time_to_full_s"]

    def test_hotness_split_params_hot_moments_cold(self):
        model = build(TINY)
        state = init_train_state(model, jax.random.PRNGKey(0))
        tree = {"params": state.params, "opt": state.opt}
        from repro.core import StateImage
        img = StateImage.build(flatten_state(tree))
        ws = set(default_train_hotness(img.manifest).tolist())
        by = img.manifest.by_name()
        for e in img.manifest.extents:
            if "/m/" in f"/{e.name}" or "/v/" in f"/{e.name}":
                continue
        # params pages are hot
        some_param = next(e for e in img.manifest.extents if "params" in e.name)
        assert set(some_param.pages()) <= ws
        # Adam moment pages are cold
        some_m = next(e for e in img.manifest.extents if "/m/" in e.name or e.name.startswith("opt/m"))
        assert not (set(some_m.pages()) & ws)

    @pytest.mark.slow
    def test_crash_resume_reproduces_uninterrupted_run(self):
        """train 10 → [crash] → restore → train to 20 must equal a straight
        20-step run (deterministic data + exact state restore)."""
        model = build(TINY)
        data = SyntheticLMData(DataConfig(vocab=TINY.vocab, seq_len=32, global_batch=4))

        # uninterrupted reference
        step = jax.jit(make_train_step(model))
        ref = init_train_state(model, jax.random.PRNGKey(0))
        for i in range(20):
            ref, _ = step(ref, {k: jnp.asarray(v) for k, v in data.batch_at(i).items()})

        # crash/restart path
        pool, master, orch = make_stack()
        t1 = Trainer(model, data, master=master, orch=orch,
                     loop_cfg=LoopConfig(steps=10, ckpt_every=10, log_every=100,
                                         async_checkpoint=False))
        t1.run()
        t2 = Trainer(model, data, master=master, orch=orch,
                     loop_cfg=LoopConfig(steps=20, ckpt_every=0, log_every=100))
        final = t2.run(resume=True)

        for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(final.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)

    def test_elastic_reshard_roundtrip(self):
        """Snapshot pages are mesh-agnostic: restore onto a different mesh."""
        model = build(TINY)
        state = init_train_state(model, jax.random.PRNGKey(0))
        from repro.launch.mesh import make_host_mesh
        from repro.sharding.partition import param_specs
        mesh = make_host_mesh(1, 1)
        specs = param_specs(state.params)
        placed = reshard(state.params, mesh, specs)
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(placed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_missing_snapshot_falls_back(self):
        pool, master, orch = make_stack()
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(orch, "nope", {})
        assert orch.stats["cold_starts"] == 1


class TestServing:
    def test_skeleton_pool_claim(self):
        from repro.serve.coldstart import SkeletonPool
        sp = SkeletonPool(TINY, batch=1, max_len=32, target_size=1, background=False)
        sk = sp.claim()
        assert sk.cfg.name == TINY.name
        sk2 = sp.claim()           # pool empty → created on demand
        assert sp.stats["created_on_demand"] >= 1
        sp.close()

    def test_generate_from_restored_params(self):
        """End-to-end serverless path: publish params snapshot → warm restore
        → bind to skeleton → generate tokens; equals direct generation."""
        from repro.serve.coldstart import SkeletonPool, restore_server
        from repro.serve.engine import ServerInstance
        model = build(TINY)
        params = model.init(jax.random.PRNGKey(1))
        pool, master, orch = make_stack()
        save_checkpoint(master, "srv", {"params": params}, step=0,
                        working_set=None)
        sp = SkeletonPool(TINY, batch=1, max_len=48, target_size=1, background=False)
        out = restore_server(orch, "srv", sp.claim(), {"params": params})
        inst = out["instance"]
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        got = inst.generate(prompt, 8)

        direct = ServerInstance(model, params, model.init_caches(params, 1, 48), 48)
        want = direct.generate(prompt, 8)
        np.testing.assert_array_equal(got, want)
        sp.close()
