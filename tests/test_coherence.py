"""Ownership-based coherence protocol (§3.3): invariants, stale-cache
behaviour on the emulated non-coherent CXL tier, and a multithreaded
borrower/owner stress test."""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Catalog,
    HierarchicalPool,
    LeaseFallback,
    PoolMaster,
    STATE_PUBLISHED,
    STATE_TOMBSTONE,
    SnapshotReader,
    StateImage,
)
from repro.core.profiler import AccessRecorder


def publish_version(master, name, value, n=2000):
    arr = {"data": np.full((n,), value, np.float32)}
    img = StateImage.build(arr)
    rec = AccessRecorder(img.manifest)
    rec.touch_array("data")
    master.publish(name, img, rec.working_set())
    return img


class TestProtocol:
    def test_borrow_release(self):
        pool = HierarchicalPool(32 << 20, 32 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        b = master.catalog.borrow("s")
        assert b is not None
        entry = master.catalog.find("s")
        assert entry.refcount.load() == 1
        b.release()
        assert entry.refcount.load() == 0

    def test_borrow_fails_on_tombstone(self):
        pool = HierarchicalPool(32 << 20, 32 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        master.catalog.tombstone("s")
        assert master.catalog.borrow("s") is None  # → cold start

    def test_no_reclaim_while_borrowed(self):
        pool = HierarchicalPool(32 << 20, 32 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        b = master.catalog.borrow("s")
        master.delete("s")
        in_use_during_borrow = pool.cxl.bytes_in_use
        assert in_use_during_borrow > 0  # data region NOT freed yet
        b.release()
        master.gc()
        assert pool.cxl.bytes_in_use < in_use_during_borrow

    def test_update_waits_for_borrows(self):
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        b = master.catalog.borrow("s")
        done = threading.Event()

        def update():
            publish_version(master, "s", 2.0)
            done.set()

        t = threading.Thread(target=update, daemon=True)
        t.start()
        time.sleep(0.05)
        assert not done.is_set()          # blocked on the active borrow
        b.release()
        t.join(timeout=5)
        assert done.is_set()
        b2 = master.catalog.borrow("s")
        assert b2.version == 1
        b2.release()

    def test_stale_cache_without_flush_then_flush_fixes(self):
        """The clflushopt step is load-bearing: a host that read v0 and skips
        invalidate() observes stale bytes for v1."""
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        view = pool.host_view("h0")

        b0 = master.catalog.borrow("s")
        r0 = SnapshotReader(b0.regions, view, pool.rdma)
        r0.invalidate_cxl()
        page0 = r0.read_page(int(r0.hot_page_indices()[0]))
        b0.release()

        publish_version(master, "s", 2.0)
        b1 = master.catalog.borrow("s")
        r1 = SnapshotReader(b1.regions, view, pool.rdma)
        # no invalidate: stale host cache serves old bytes
        stale = r1.read_page(int(r1.hot_page_indices()[0]))
        assert np.array_equal(stale.view(np.float32)[:16], page0.view(np.float32)[:16])
        # protocol-correct: invalidate → fresh bytes
        r1b = SnapshotReader(b1.regions, pool.host_view("h0b"), pool.rdma)
        view2 = r1.view
        r1.invalidate_cxl()
        fresh = r1.read_page(int(r1.hot_page_indices()[0]))
        assert fresh.view(np.float32)[0] == 2.0
        b1.release()

    def test_lease_fallback(self):
        pool = HierarchicalPool(32 << 20, 32 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        leases = LeaseFallback(master.catalog)
        l1 = leases.acquire("s")
        assert l1 is not None
        assert leases.acquire("missing") is None
        l1.release()
        assert leases.rpc_count == 3  # acquire + release + failed acquire


class TestDoomedBorrowRegression:
    """Pins the PR-1 `Catalog.borrow` fix.  The hazardous interleaving —
    owner tombstones *between* the borrower's refcount increment and its
    state CAS — is driven deterministically through the step generators."""

    def test_owner_tombstone_between_increment_and_cas(self):
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        catalog = master.catalog
        entry = catalog.find("s")

        # borrower: run the protocol up to (and including) refcount++
        steps = catalog.borrow_steps("s")
        label, val = next(steps)
        assert label == "refcount_incremented"
        assert entry.refcount.load() == 1

        # owner: interleave an update — tombstone lands before the CAS
        arr = {"data": np.full((2000,), 2.0, np.float32)}
        img = StateImage.build(arr)
        from repro.core.profiler import AccessRecorder
        rec = AccessRecorder(img.manifest)
        rec.touch_array("data")
        pub = master.publish_steps("s", img, rec.working_set())
        label, _ = next(pub)
        assert label == "tombstoned"

        # borrower resumes: CAS must fail, increment must be backed out
        label, _ = next(steps)
        assert label == "doomed"
        assert entry.refcount.load() == 0, "doomed borrow must decrement"
        label, borrow = next(steps)
        assert label == "done" and borrow is None, "borrower must cold-start"

        # owner must complete WITHOUT a single drain stall
        labels = [label for label, _v in pub]
        assert "draining" not in labels, "owner stalled on a doomed borrow"
        assert labels[-1] == "done"

        # post-update: normal borrows see the new version
        b = catalog.borrow("s")
        assert b is not None and b.version == 1
        b.release()

    def test_tombstoned_entry_rejected_without_touching_refcount(self):
        """The fix itself: a borrow of a TOMBSTONE entry fast-fails before
        the refcount increment, so tight retry loops cannot livelock the
        owner's wait-for-drain."""
        pool = HierarchicalPool(32 << 20, 32 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        entry = master.catalog.tombstone("s")
        steps = list(master.catalog.borrow_steps("s"))
        assert steps == [("done", None)], "no refcount traffic on TOMBSTONE"
        assert entry.refcount.load() == 0
        # reverting the fix (state_precheck=False) re-exposes the increment
        labels = [label for label, _v in
                  master.catalog.borrow_steps("s", state_precheck=False)]
        assert "refcount_incremented" in labels and "doomed" in labels
        assert entry.refcount.load() == 0

    def test_owner_drains_against_tight_borrow_loop(self):
        """Threaded end-to-end: an owner update completes promptly while a
        borrower retries in a tight loop (pre-PR-1 this livelocked)."""
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 1.0)
        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                b = master.catalog.borrow("s")
                if b is not None:
                    b.release()

        t = threading.Thread(target=hammer, daemon=True)
        t.start()
        try:
            publish_version(master, "s", 2.0)   # must not TimeoutError
        finally:
            stop.set()
            t.join(timeout=5)
        assert not t.is_alive()
        assert master.catalog.find("s").version == 1


class TestFailoverThreadHygiene:
    def test_stop_and_crash_join_heartbeat_thread(self):
        from repro.core.failover import FailoverNode, MasterLease
        pool = HierarchicalPool(32 << 20, 32 << 20)
        master = PoolMaster(pool)
        lease = MasterLease(timeout_s=0.1)
        before = set(threading.enumerate())
        n1 = FailoverNode(1, pool, master.catalog, lease, beat_interval_s=0.01)
        n2 = FailoverNode(2, pool, master.catalog, lease, beat_interval_s=0.01)
        n1.start()
        n2.start()
        deadline = time.monotonic() + 5.0
        while not (n1.is_master or n2.is_master):
            assert time.monotonic() < deadline, "no master elected"
            time.sleep(0.005)
        n1.stop()
        n2.crash()
        assert set(threading.enumerate()) - before == set(), \
            "stop()/crash() must join the heartbeat thread"


class TestStress:
    def test_concurrent_borrowers_vs_owner_updates(self):
        """Many borrower threads racing owner updates: every successful
        borrow must observe internally-consistent (single-version) data."""
        pool = HierarchicalPool(128 << 20, 128 << 20)
        master = PoolMaster(pool)
        publish_version(master, "s", 0.0)
        stop = threading.Event()
        errors = []

        def borrower(hid):
            view = pool.host_view(f"h{hid}")
            while not stop.is_set():
                b = master.catalog.borrow("s")
                if b is None:
                    continue
                try:
                    r = SnapshotReader(b.regions, view, pool.rdma)
                    r.invalidate_cxl()
                    hot = r.hot_page_indices()
                    vals = set()
                    for p in hot[:4]:
                        vals.add(float(r.read_page(int(p)).view(np.float32)[0]))
                    if len(vals) > 1:
                        errors.append(f"torn read: {vals}")
                finally:
                    b.release()

        threads = [threading.Thread(target=borrower, args=(i,), daemon=True)
                   for i in range(4)]
        for t in threads:
            t.start()
        for v in range(1, 6):
            publish_version(master, "s", float(v))
        stop.set()
        for t in threads:
            t.join(timeout=5)
        assert not errors, errors

    @given(st.lists(st.sampled_from(["borrow", "release", "tombstone", "publish"]),
                    min_size=1, max_size=40))
    @settings(max_examples=30, deadline=None)
    def test_refcount_never_negative(self, ops):
        catalog = Catalog(capacity=4)
        pool = HierarchicalPool(32 << 20, 32 << 20)
        master = PoolMaster(pool, catalog)
        publish_version(master, "s", 1.0)
        borrows = []
        for op in ops:
            if op == "borrow":
                b = catalog.borrow("s")
                if b:
                    borrows.append(b)
            elif op == "release" and borrows:
                borrows.pop().release()
            elif op == "tombstone":
                catalog.tombstone("s")
            elif op == "publish" and not borrows:
                publish_version(master, "s", 9.0)
            entry = catalog.find("s")
            if entry is not None:
                assert entry.refcount.load() >= 0
                assert entry.state.load() in (STATE_PUBLISHED, STATE_TOMBSTONE)
        for b in borrows:
            b.release()
