"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles
over shape/dtype sweeps, plus hypothesis property tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import (
    flash_attention,
    page_checksum,
    page_gather,
    page_scatter,
    zero_detect,
)
from repro.kernels.flash_attention.ref import attention_ref, chunked_attention_ref
from repro.kernels.page_checksum.ref import page_checksum_ref, poly_weights
from repro.kernels.zero_detect.ref import zero_detect_ref


class TestZeroDetect:
    @pytest.mark.parametrize("dtype,page_elems", [
        (np.float32, 1024), (np.float32, 2048),
        (np.int8, 4096), (np.uint8, 4096), (np.float16, 2048),
    ])
    @pytest.mark.parametrize("n_pages", [1, 7, 256, 300])
    def test_sweep(self, dtype, page_elems, n_pages):
        rng = np.random.default_rng(hash((n_pages, page_elems)) % 2**31)
        if np.issubdtype(dtype, np.floating):
            pages = rng.standard_normal((n_pages, page_elems)).astype(dtype)
        else:
            pages = rng.integers(0, 100, (n_pages, page_elems)).astype(dtype)
        zero_idx = rng.choice(n_pages, size=max(1, n_pages // 3), replace=False)
        pages[zero_idx] = 0
        got = zero_detect(pages, use_pallas=True, interpret=True, block_pages=8)
        want = zero_detect_ref(jnp.asarray(pages))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.slow
    @given(st.integers(1, 64), st.integers(0, 63))
    @settings(max_examples=20, deadline=None)
    def test_property_single_nonzero_elem(self, n_pages, elem):
        """A single nonzero element anywhere makes exactly that page hot."""
        pages = np.zeros((n_pages, 256), np.float32)
        p = elem % n_pages
        pages[p, elem % 256] = 1.0
        got = np.asarray(zero_detect(pages, use_pallas=True, interpret=True,
                                     block_pages=8))
        assert got[p] == 0
        assert got.sum() == n_pages - 1


class TestGatherScatter:
    @pytest.mark.parametrize("dtype", [np.float32, np.int8])
    @pytest.mark.parametrize("n,m", [(16, 4), (100, 33), (256, 256)])
    def test_gather_sweep(self, dtype, n, m):
        rng = np.random.default_rng(1)
        pages = rng.standard_normal((n, 512)).astype(np.float32).astype(dtype)
        idx = rng.choice(n, size=m, replace=False).astype(np.int32)
        got = page_gather(pages, idx, use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), pages[idx])

    @pytest.mark.parametrize("n,m", [(16, 4), (64, 17)])
    def test_scatter_sweep(self, n, m):
        rng = np.random.default_rng(2)
        dest = rng.standard_normal((n, 512)).astype(np.float32)
        compact = rng.standard_normal((m, 512)).astype(np.float32)
        idx = rng.choice(n, size=m, replace=False).astype(np.int32)
        got = page_scatter(dest.copy(), compact, idx, use_pallas=True, interpret=True)
        want = dest.copy()
        want[idx] = compact
        np.testing.assert_array_equal(np.asarray(got), want)

    @pytest.mark.slow
    @given(st.integers(2, 40))
    @settings(max_examples=15, deadline=None)
    def test_property_gather_scatter_inverse(self, n):
        """scatter(gather(img)) with the same indices is identity."""
        rng = np.random.default_rng(n)
        img = rng.standard_normal((n, 256)).astype(np.float32)
        idx = rng.permutation(n)[: max(1, n // 2)].astype(np.int32)
        compact = page_gather(img, idx, use_pallas=True, interpret=True)
        back = page_scatter(jnp.asarray(img).copy(), compact, idx,
                            use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(back), img)


class TestChecksum:
    @pytest.mark.parametrize("n_pages", [1, 17, 64])
    def test_sweep(self, n_pages):
        rng = np.random.default_rng(3)
        pages = rng.integers(0, 256, (n_pages, 4096), dtype=np.uint8)
        got = page_checksum(pages, use_pallas=True, interpret=True, block_pages=8)
        want = page_checksum_ref(
            jnp.asarray(pages.view(np.uint32).reshape(n_pages, -1)), poly_weights(1024))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_collision_resistance_on_flip(self):
        page = np.zeros((1, 4096), np.uint8)
        base = int(np.asarray(page_checksum(page, use_pallas=True, interpret=True, block_pages=8))[0])
        flipped = page.copy()
        flipped[0, 1234] = 1
        other = int(np.asarray(page_checksum(flipped, use_pallas=True, interpret=True, block_pages=8))[0])
        assert base != other


@pytest.mark.slow
class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,dk,dv", [
        (1, 4, 4, 128, 128, 64, 64),      # MHA
        (2, 8, 2, 256, 256, 64, 64),      # GQA 4:1
        (1, 4, 1, 128, 256, 64, 64),      # MQA, chunked-prefill (Sq<Skv)
        (1, 2, 2, 128, 128, 192, 128),    # MLA-style dk != dv
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep(self, b, hq, hkv, sq, skv, dk, dv, causal):
        rng = np.random.default_rng(4)
        q = rng.standard_normal((b, hq, sq, dk)).astype(np.float32)
        k = rng.standard_normal((b, hkv, skv, dk)).astype(np.float32)
        v = rng.standard_normal((b, hkv, skv, dv)).astype(np.float32)
        got = flash_attention(q, k, v, causal=causal, use_pallas=True,
                              interpret=True, block_q=128, block_k=128)
        want = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.bfloat16)
        got = flash_attention(q, k, v, use_pallas=True, interpret=True,
                              block_q=128, block_k=128)
        want = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), rtol=2e-2, atol=2e-2)

    def test_chunked_oracle_matches_naive(self):
        """The long-sequence CPU path (chunked online softmax) == naive."""
        rng = np.random.default_rng(6)
        q = rng.standard_normal((1, 2, 256, 32)).astype(np.float32)
        k = rng.standard_normal((1, 2, 256, 32)).astype(np.float32)
        v = rng.standard_normal((1, 2, 256, 32)).astype(np.float32)
        got = chunked_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                    causal=True, block_k=64)
        want = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
