"""Unit + property tests for the Aquifer core: paged state images, the
hotness-based snapshot format, and page serving."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    PAGE_SIZE,
    ZERO_SENTINEL,
    HierarchicalPool,
    Instance,
    Orchestrator,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
    TIER_CXL,
    TIER_RDMA,
    classify_pages,
    decode_slot,
    encode_slot,
    runs_from_pages,
)
from repro.core.profiler import AccessRecorder


def make_image(seed=0, n_params=3000, n_zero_rows=64):
    rng = np.random.default_rng(seed)
    arrays = {
        "params": rng.standard_normal((n_params,)).astype(np.float32),
        "emb": np.zeros((128, 64), np.float32),
        "arena": np.zeros((n_zero_rows, 1024), np.float32),
    }
    arrays["emb"][::3] = rng.standard_normal((43, 64)).astype(np.float32)
    return StateImage.build(arrays), arrays


class TestStateImage:
    def test_roundtrip(self):
        img, arrays = make_image()
        for name, arr in arrays.items():
            np.testing.assert_array_equal(img.read_array(name), arr)

    def test_page_alignment(self):
        img, _ = make_image()
        for e in img.manifest.extents:
            assert e.byte_offset % PAGE_SIZE == 0

    def test_zero_bitmap(self):
        img, _ = make_image()
        zb = img.zero_page_bitmap()
        arena = img.manifest.by_name()["arena"]
        assert zb[list(arena.pages())].all()
        params = img.manifest.by_name()["params"]
        assert not zb[params.first_page]

    @given(st.lists(st.integers(0, 500), max_size=60))
    @settings(max_examples=50, deadline=None)
    def test_runs_roundtrip(self, pages):
        runs = runs_from_pages(pages)
        # runs are disjoint, sorted, and cover exactly the page set
        out = []
        for s, n in runs:
            assert n >= 1
            out.extend(range(s, s + n))
        assert out == sorted(set(pages))


class TestSnapshotFormat:
    def test_slot_encoding(self):
        for tier in (TIER_CXL, TIER_RDMA):
            for off in (0, PAGE_SIZE, 123 * PAGE_SIZE, (1 << 40)):
                t, o = decode_slot(encode_slot(tier, off))
                assert (t, o) == (tier, off)

    def test_classify(self):
        img, _ = make_image()
        rec = AccessRecorder(img.manifest)
        rec.touch_array("params")
        rec.touch_rows("emb", [0, 3])
        classes = classify_pages(img, rec.working_set())
        s = classes.summary()
        assert s["zero"] + s["hot"] + s["cold"] == s["total"]
        # zero pages are never stored
        assert s["zero"] >= img.manifest.by_name()["arena"].page_count

    def test_offset_array_sentinel_and_tiers(self):
        img, _ = make_image()
        rec = AccessRecorder(img.manifest)
        rec.touch_array("params")
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        regions = master.publish("t", img, rec.working_set())
        view = pool.host_view("h0")
        reader = SnapshotReader(regions, view, pool.rdma)
        oa = reader.offset_array()
        zb = img.zero_page_bitmap()
        for p in range(img.total_pages):
            if zb[p]:
                assert oa[p] == ZERO_SENTINEL
        # hot pages point at CXL, cold at RDMA
        assert set(np.asarray(reader.hot_page_indices())) <= set(rec.working_set().tolist())

    def test_restore_bit_identical(self):
        img, _ = make_image(seed=7)
        rec = AccessRecorder(img.manifest)
        rec.touch_array("params")
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        master.publish("t", img, rec.working_set())
        orch = Orchestrator("h0", pool, master.catalog, use_async_rdma=True)
        ri = orch.restore("t")
        assert ri is not None
        for p in range(img.total_pages):
            ri.engine.access(p)
        assert np.array_equal(ri.instance.image.buf, img.buf)
        # hot set was pre-installed, zero pages took the zeropage fast path
        assert ri.instance.stats["pre_installed"] > 0
        assert ri.instance.stats["uffd_zeropages"] > 0
        assert ri.instance.stats["fault_rdma"] > 0
        ri.shutdown()

    def test_snapshot_immutable_across_concurrent_restores(self):
        img, _ = make_image(seed=3)
        rec = AccessRecorder(img.manifest)
        rec.touch_array("params")
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        master.publish("t", img, rec.working_set())
        before = pool.cxl.buf.copy()
        orchs = [Orchestrator(f"h{i}", pool, master.catalog, use_async_rdma=False)
                 for i in range(3)]
        ris = [o.restore("t") for o in orchs]
        for ri in ris:
            ri.engine.install_all_sync()
            assert np.array_equal(ri.instance.image.buf, img.buf)
            ri.shutdown()
        np.testing.assert_array_equal(pool.cxl.buf, before)  # pool untouched


class TestMemoryTierFreeList:
    """bisect-insert + neighbor-merge free list: conservation + coalescing."""

    def _tier(self, capacity=1 << 20):
        from repro.core import MemoryTier
        from repro.core.pool import CXL_COST
        return MemoryTier("t", capacity, CXL_COST)

    def test_conservation_and_merge_under_random_churn(self):
        tier = self._tier()
        rng = np.random.default_rng(0)
        live = {}
        for step in range(400):
            if live and (len(live) > 24 or rng.random() < 0.45):
                off = list(live)[int(rng.integers(0, len(live)))]
                tier.free(off, live.pop(off))
            else:
                nbytes = int(rng.integers(1, 16)) * PAGE_SIZE
                try:
                    live[tier.alloc(nbytes)] = nbytes
                except Exception:
                    continue
            # invariants after EVERY operation: bytes conserved, free list
            # sorted, fully coalesced, non-overlapping
            st = tier.free_list_stats()
            assert st["free_bytes"] + tier.bytes_in_use == tier.capacity
            fl = tier._free
            for (o1, s1), (o2, _s2) in zip(fl, fl[1:]):
                assert o1 + s1 < o2      # sorted, disjoint, and UNMERGEABLE
        for off, nbytes in live.items():
            tier.free(off, nbytes)
        # everything returned: one block, zero fragmentation, zero in use
        assert tier._free == [(0, tier.capacity)]
        assert tier.bytes_in_use == 0

    def test_free_merges_both_neighbors(self):
        tier = self._tier(capacity=16 * PAGE_SIZE)
        a = tier.alloc(4 * PAGE_SIZE)
        b = tier.alloc(4 * PAGE_SIZE)
        c = tier.alloc(4 * PAGE_SIZE)
        tier.free(a, 4 * PAGE_SIZE)
        tier.free(c, 4 * PAGE_SIZE)
        assert tier.free_list_stats()["blocks"] == 2   # a-hole, c+tail
        tier.free(b, 4 * PAGE_SIZE)                    # merges a+b+c+tail
        assert tier._free == [(0, tier.capacity)]
        # a full-capacity allocation fits again (no phantom fragmentation)
        off = tier.alloc(tier.capacity)
        assert off == 0


class TestEviction:
    def test_borrow_counter_eviction(self):
        img, _ = make_image(n_params=500, n_zero_rows=8)
        rec = AccessRecorder(img.manifest)
        rec.touch_array("params")
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        for name in ("a", "b", "c"):
            master.publish(name, img, rec.working_set())
        # borrow "a" a lot, "b" once, "c" never
        for _ in range(5):
            master.catalog.borrow("a").release()
        master.catalog.borrow("b").release()
        evicted = master.evict_for(1)
        assert evicted[0] == "c"


class TestCapacityTradeoffs:
    def test_zero_elimination_shrinks_pool_usage(self):
        img, _ = make_image(n_zero_rows=512)   # mostly zero pages
        rec = AccessRecorder(img.manifest)
        rec.touch_array("params")
        pool = HierarchicalPool(256 << 20, 256 << 20)
        master = PoolMaster(pool)
        regions = master.publish("t", img, rec.working_set())
        stored = regions.cxl_size + regions.rdma_size
        assert stored < img.buf.nbytes / 2  # >=50% shrink from zero-elim
