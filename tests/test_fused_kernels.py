"""Fused snapshot data plane (kernels/snapshot_fuse, DESIGN.md §13).

Interpret-mode bit-identity of the fused publish sweep and the fused
gather→verify→scatter restore against both the piecemeal Pallas ops and the
numpy oracles — odd page counts, tail chunks, all-zero and all-hot layouts,
the dedup ``hash_fn`` seam, the pluggable zero-scan backend, and the
publish→restore checksum-verification loop end-to-end.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import HierarchicalPool
from repro.core.coherence import Catalog
from repro.core.dedup import pallas_hash_fn
from repro.core.master import PoolMaster
from repro.core.orchestrator import Orchestrator
from repro.core.pagestore import (
    PAGE_SIZE,
    StateImage,
    numpy_zero_scan,
    pallas_zero_scan,
    set_zero_scan_backend,
)
from repro.core.snapshot import SnapshotReader, build_snapshot
from repro.kernels import (
    FusedScatter,
    fused_publish,
    fused_restore,
    make_fused_publish_fn,
    page_checksum,
    page_gather,
    page_scatter,
    zero_detect,
)
from repro.kernels.snapshot_fuse.ops import ChecksumMismatchError

INTERP = {"use_pallas": True, "interpret": True}


def _pages(n, seed=0, zero_every=3):
    rng = np.random.default_rng(seed)
    pages = rng.integers(0, 256, size=(n, PAGE_SIZE), dtype=np.uint8)
    if zero_every:
        pages[::zero_every] = 0
    ws = np.zeros(n, dtype=bool)
    if n:
        ws[rng.choice(n, size=max(1, n // 2), replace=False)] = True
    return pages, ws


class TestFusedPublish:
    @pytest.mark.parametrize("n", [1, 7, 8, 37, 64])
    def test_interpret_matches_oracle(self, n):
        """Odd counts and block tails (block_pages=8) vs the numpy ref."""
        pages, ws = _pages(n, seed=n)
        got = fused_publish(pages, ws, block_pages=8, **INTERP)
        want = fused_publish(pages, ws, use_pallas=False)
        np.testing.assert_array_equal(got.zero_bitmap, want.zero_bitmap)
        np.testing.assert_array_equal(got.checksums, want.checksums)
        np.testing.assert_array_equal(got.hot, want.hot)
        np.testing.assert_array_equal(got.cold, want.cold)

    def test_matches_piecemeal_ops(self):
        """The fused sweep ≡ zero_detect + page_checksum + 2× page_gather +
        dedup hash, run as separate interpret-mode kernels."""
        pages, ws = _pages(37, seed=2)
        u32 = pages.view(np.uint32).reshape(37, -1)
        fp = fused_publish(pages, ws, block_pages=8, **INTERP)
        zb = np.asarray(zero_detect(u32, block_pages=8, **INTERP)) != 0
        csum = np.asarray(page_checksum(pages, block_pages=8, **INTERP))
        hot_idx = np.flatnonzero(~zb & ws).astype(np.int32)
        cold_idx = np.flatnonzero(~zb & ~ws).astype(np.int32)
        hot = np.asarray(page_gather(u32, hot_idx, **INTERP))
        cold = np.asarray(page_gather(u32, cold_idx, **INTERP))
        np.testing.assert_array_equal(fp.zero_bitmap, zb)
        np.testing.assert_array_equal(fp.checksums, csum)
        np.testing.assert_array_equal(
            fp.hot.view(np.uint32).reshape(hot.shape), hot)
        np.testing.assert_array_equal(
            fp.cold.view(np.uint32).reshape(cold.shape), cold)

    def test_all_zero_layout(self):
        pages = np.zeros((16, PAGE_SIZE), np.uint8)
        ws = np.ones(16, bool)
        fp = fused_publish(pages, ws, block_pages=8, **INTERP)
        assert fp.zero_bitmap.all()
        assert fp.hot.shape[0] == 0 and fp.cold.shape[0] == 0

    def test_all_hot_layout(self):
        pages, _ = _pages(24, seed=3, zero_every=0)
        ws = np.ones(24, bool)
        fp = fused_publish(pages, ws, block_pages=8, **INTERP)
        assert not fp.zero_bitmap.any() and fp.cold.shape[0] == 0
        np.testing.assert_array_equal(fp.hot, pages)

    def test_empty(self):
        fp = fused_publish(np.zeros((0, PAGE_SIZE), np.uint8),
                           np.zeros(0, bool), **INTERP)
        assert fp.zero_bitmap.shape == (0,) and fp.hot.shape[0] == 0

    def test_dedup_hash_seam(self):
        """The fused checksum column IS the dedup hash: bit-equal to
        ``pallas_hash_fn`` (the store hash marked ``is_poly32``), so
        ``put_pages(..., hashes=checksums[idx])`` lands in the same buckets
        the store would compute itself."""
        pages, ws = _pages(21, seed=4)
        fp = fused_publish(pages, ws, block_pages=8, **INTERP)
        assert getattr(pallas_hash_fn, "is_poly32", False)
        np.testing.assert_array_equal(fp.checksums,
                                      np.asarray(pallas_hash_fn(pages)))


class TestFusedRestore:
    @pytest.mark.parametrize("n,m", [(16, 4), (37, 21), (64, 64)])
    def test_interpret_matches_piecemeal(self, n, m):
        """gather → checksum → scatter as three interpret-mode kernels vs
        the one fused kernel, including tail chunks and permuted sources."""
        rng = np.random.default_rng(n * m)
        chunk = rng.integers(0, 256, size=(m, PAGE_SIZE), dtype=np.uint8)
        chunk_u32 = chunk.view(np.uint32).reshape(m, -1)
        dst = np.sort(rng.choice(n, size=m, replace=False)).astype(np.int32)
        src = rng.permutation(m).astype(np.int32)
        dest = np.zeros((n, PAGE_SIZE), np.uint8)

        g = np.asarray(page_gather(chunk_u32, src, **INTERP))
        cs = np.asarray(page_checksum(g, block_pages=8, **INTERP))
        want = np.asarray(page_scatter(
            np.zeros((n, PAGE_SIZE // 4), np.uint32), g, dst, **INTERP))

        out, csums = fused_restore(dest, chunk, dst, src_indices=src, **INTERP)
        np.testing.assert_array_equal(
            np.asarray(out).reshape(n, PAGE_SIZE).view(np.uint32), want)
        np.testing.assert_array_equal(csums, cs)

    def test_cpu_path_in_place(self):
        chunk, _ = _pages(6, seed=5, zero_every=0)
        dest = np.zeros((12, PAGE_SIZE), np.uint8)
        idx = np.array([1, 3, 5, 7, 9, 11], np.int32)
        out, _ = fused_restore(dest, chunk, idx, use_pallas=False)
        assert out is dest
        np.testing.assert_array_equal(dest[idx], chunk)
        assert not dest[::2].any()

    def test_checksum_verify_pass_and_fail(self):
        chunk, _ = _pages(5, seed=6, zero_every=0)
        exp = np.asarray(pallas_hash_fn(chunk))
        dest = np.zeros((8, PAGE_SIZE), np.uint8)
        idx = np.arange(5, dtype=np.int32)
        fused_restore(dest, chunk, idx, expected_csums=exp, use_pallas=False)

        bad = exp.copy()
        bad[2] ^= 1
        with pytest.raises(ChecksumMismatchError) as ei:
            fused_restore(dest, chunk, idx, expected_csums=bad,
                          use_pallas=False)
        assert ei.value.pages.tolist() == [2]


class TestFusedScatterSeam:
    def test_scatterfn_signature_unbound(self):
        """Drop-in for the serving seam: (dest, compact, indices) -> dest,
        numerically the plain scatter when no checksum table is bound."""
        chunk, _ = _pages(4, seed=7, zero_every=0)
        dest = np.zeros((10, PAGE_SIZE), np.uint8)
        idx = np.array([0, 2, 5, 9], np.int32)
        sf = FusedScatter(use_pallas=False)
        out = sf(dest, chunk, idx)
        np.testing.assert_array_equal(out[idx], chunk)
        assert sf.stats == {"batches": 1, "pages": 4, "pages_verified": 0}

    def test_bound_copy_shares_stats_and_verifies(self):
        chunk, _ = _pages(4, seed=8, zero_every=0)
        table = np.zeros(10, np.uint32)
        idx = np.array([1, 4, 6, 8], np.int32)
        table[idx] = np.asarray(pallas_hash_fn(chunk))
        template = FusedScatter(use_pallas=False)
        bound = template.bind_checksums(table)
        bound(np.zeros((10, PAGE_SIZE), np.uint8), chunk, idx)
        assert template.stats["pages_verified"] == 4  # shared dict

        table[4] ^= 1
        with pytest.raises(ChecksumMismatchError):
            template.bind_checksums(table)(
                np.zeros((10, PAGE_SIZE), np.uint8), chunk, idx)


def _image(seed=11):
    rng = np.random.default_rng(seed)
    return StateImage.build({
        "w": rng.standard_normal((48, 1024)).astype(np.float32),
        "b": np.zeros((4, 1024), np.float32),
    })


class TestEndToEnd:
    @pytest.mark.parametrize("dedup", [False, True])
    def test_publish_restore_bit_identical_and_verified(self, dedup):
        """Fused publish (master-wide publish_fn) → node-server restore with
        the fused verified scatter: bytes identical, every page checked."""
        img = _image()
        ws = list(range(0, img.total_pages, 3))
        pool = HierarchicalPool(
            cxl_capacity=64 << 20, rdma_capacity=128 << 20,
            dedup_hash_fn=pallas_hash_fn if dedup else None)
        catalog = Catalog()
        master = PoolMaster(pool, catalog, dedup=dedup,
                            publish_fn=make_fused_publish_fn(use_pallas=False))
        regions = master.publish("model", img, ws)
        assert getattr(regions, "page_checksums", None) is not None
        if dedup:
            assert pool.dedup_cxl.stats["unique"] > 0
        orch = Orchestrator("hostA", pool, catalog,
                            scatter_fn=FusedScatter(use_pallas=False))
        ri = orch.restore("model", pre_install=True)
        assert ri is not None
        ri.engine.install_all_sync()
        assert ri.instance.all_present()
        np.testing.assert_array_equal(ri.instance.image.buf, img.buf)
        assert ri.instance.scatter_fn.stats["pages_verified"] > 0
        ri.shutdown()
        orch.close()

    @pytest.mark.parametrize("dedup", [False, True])
    def test_publish_fn_layout_identical_to_piecemeal(self, dedup):
        """The fused publish produces byte-identical snapshots (regions AND
        tier contents) to the default path — so rebuilds/re-curations that
        ride the master's publish_fn can't drift the layout."""
        img = _image(seed=12)
        ws = list(range(0, img.total_pages, 4))
        snaps = []
        for publish_fn in (None, make_fused_publish_fn(use_pallas=False)):
            pool = HierarchicalPool(
                cxl_capacity=64 << 20, rdma_capacity=128 << 20,
                dedup_hash_fn=pallas_hash_fn if dedup else None)
            regions = build_snapshot(pool, img, ws, "m", dedup=dedup,
                                     publish_fn=publish_fn)
            snaps.append((pool, regions))
        (pool_a, ra), (pool_b, rb) = snaps
        assert dataclasses.asdict(ra) == dataclasses.asdict(rb)
        np.testing.assert_array_equal(pool_a.cxl.buf, pool_b.cxl.buf)
        np.testing.assert_array_equal(pool_a.rdma.buf, pool_b.rdma.buf)
        assert SnapshotReader(rb, pool_b.host_view("h", None),
                              pool_b.rdma).page_checksums() is not None

    def test_corruption_detected_on_restore(self):
        img = _image(seed=13)
        ws = list(range(0, img.total_pages, 3))
        pool = HierarchicalPool(cxl_capacity=64 << 20, rdma_capacity=128 << 20)
        catalog = Catalog()
        master = PoolMaster(pool, catalog,
                            publish_fn=make_fused_publish_fn(use_pallas=False))
        regions = master.publish("m2", img, ws)
        pool.cxl.buf[regions.hot_off + 100] ^= 0xFF
        orch = Orchestrator("hostB", pool, catalog,
                            scatter_fn=FusedScatter(use_pallas=False))
        with pytest.raises(ChecksumMismatchError):
            orch.restore("m2", pre_install=True)
        orch.close()


class TestZeroScanBackend:
    def test_parity_and_install(self):
        img = _image(seed=14)
        want = numpy_zero_scan(img.pages_matrix())
        np.testing.assert_array_equal(
            img.zero_page_bitmap(backend=pallas_zero_scan), want)
        prev = set_zero_scan_backend(pallas_zero_scan)
        try:
            np.testing.assert_array_equal(img.zero_page_bitmap(), want)
        finally:
            set_zero_scan_backend(prev)
        np.testing.assert_array_equal(img.zero_page_bitmap(), want)
