"""Beyond-paper extensions: compressed cold tier, dedup layer, pool-master
failover, HLO analyzer."""
import time

import numpy as np
import pytest

from repro.core import (
    HierarchicalPool,
    Orchestrator,
    PoolMaster,
    StateImage,
)
from repro.core.dedup import DedupStore, fnv1a_page, fnv1a_pages
from repro.core.snapshot import _zstd
from repro.core.failover import FailoverNode, MasterLease
from repro.core.profiler import AccessRecorder


def make_image(seed=0):
    rng = np.random.default_rng(seed)
    arrays = {
        "params": rng.standard_normal((3000,)).astype(np.float32),
        "runtime": rng.integers(0, 4, (120000,)).astype(np.uint8),  # compressible
        "arena": np.zeros((32, 1024), np.float32),
    }
    img = StateImage.build(arrays)
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params")
    return img, rec.working_set()


@pytest.mark.skipif(_zstd is None,
                    reason="zstandard not installed (optional extra)")
class TestCompressedColdTier:
    def test_roundtrip_bit_identical_and_smaller(self):
        img, ws = make_image()
        pool = HierarchicalPool(64 << 20, 128 << 20)
        master = PoolMaster(pool)
        regions = master.publish("z", img, ws, compress_cold=True)
        assert regions.cold_compressed
        assert regions.cold_bytes < regions.cold_raw_bytes
        orch = Orchestrator("h", pool, master.catalog, use_async_rdma=True)
        ri = orch.restore("z")
        for p in range(img.total_pages):
            ri.engine.access(p)
        assert np.array_equal(ri.instance.image.buf, img.buf)
        ri.shutdown()

    def test_incompressible_pages_stored_raw(self):
        rng = np.random.default_rng(1)
        arrays = {"noise": rng.integers(0, 256, (64 * 4096,), dtype=np.uint8),
                  "hot": rng.standard_normal((512,)).astype(np.float32)}
        img = StateImage.build(arrays)
        rec = AccessRecorder(img.manifest)
        rec.touch_array("hot")
        pool = HierarchicalPool(32 << 20, 64 << 20)
        master = PoolMaster(pool)
        regions = master.publish("n", img, rec.working_set(), compress_cold=True)
        # random bytes don't compress: stored ~raw, restore still exact
        assert regions.cold_bytes >= regions.cold_raw_bytes * 0.95
        orch = Orchestrator("h", pool, master.catalog, use_async_rdma=False)
        ri = orch.restore("n")
        ri.engine.install_all_sync()
        assert np.array_equal(ri.instance.image.buf, img.buf)
        ri.shutdown()


class TestDedup:
    def test_shared_base_model_pages_dedup(self):
        """Two fine-tuned variants share base pages → stored once (§3.6)."""
        rng = np.random.default_rng(2)
        base = rng.standard_normal((256, 1024)).astype(np.float32)
        variant = base.copy()
        variant[:8] += 0.1  # fine-tune touches a few rows
        pool = HierarchicalPool(64 << 20, 64 << 20)
        store = DedupStore(pool.cxl)
        for arr in (base, variant):
            img = StateImage.build({"w": arr})
            mat = img.pages_matrix()
            for i in range(img.total_pages):
                store.put(mat[i])
        assert store.dedup_ratio() > 0.45, store.stats  # ~half the pages shared

    def test_vectorized_hash_matches_scalar(self):
        rng = np.random.default_rng(3)
        pages = rng.integers(0, 256, (16, 4096), dtype=np.uint8)
        vec = fnv1a_pages(pages)
        for i in range(16):
            assert int(vec[i]) == fnv1a_page(pages[i])

    def test_refcounted_drop(self):
        pool = HierarchicalPool(16 << 20, 16 << 20)
        store = DedupStore(pool.cxl)
        page = np.full(4096, 7, np.uint8)
        off1 = store.put(page)
        off2 = store.put(page)
        assert off1 == off2
        store.drop(page)
        assert pool.cxl.bytes_in_use > 0     # still referenced
        store.drop(page)
        assert pool.cxl.bytes_in_use == 0    # reclaimed


class TestFailover:
    def test_new_master_elected_and_resumes(self):
        img, ws = make_image()
        pool = HierarchicalPool(64 << 20, 64 << 20)
        lease = MasterLease(timeout_s=0.15)
        n1 = FailoverNode(1, pool, PoolMaster(pool).catalog, lease)
        # share one catalog across nodes (it lives in CXL)
        catalog = n1.catalog
        n2 = FailoverNode(2, pool, catalog, lease)
        n1.start()
        n2.start()
        time.sleep(0.3)
        first = 1 if n1.is_master else 2
        master_node = n1 if first == 1 else n2
        other = n2 if first == 1 else n1
        master_node.master.publish("snap", img, ws)

        # restores keep working without any master involvement (§3.6)
        orch = Orchestrator("h", pool, catalog, use_async_rdma=False)
        ri = orch.restore("snap")
        assert ri is not None
        ri.shutdown()

        # crash the master → the other node takes over and can publish
        master_node.crash()
        deadline = time.time() + 3
        while not other.is_master and time.time() < deadline:
            time.sleep(0.05)
        assert other.is_master, (n1.events, n2.events)
        other.master.publish("snap", img, ws)     # version continuity
        b = catalog.borrow("snap")
        assert b is not None and b.version == 1   # re-derived counters
        b.release()
        other.stop()

    def test_lease_cas_single_winner(self):
        lease = MasterLease(timeout_s=10.0)
        assert lease.try_elect(1)
        assert not lease.try_elect(2)   # fresh lease: takeover refused
        assert int(lease.term.load()) == 1


class TestHLOAnalyzer:
    def test_scan_equals_unroll(self):
        import jax
        import jax.numpy as jnp
        from repro.roofline.hlo_analyzer import analyze_hlo

        def f_scan(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            c, _ = jax.lax.scan(body, x, None, length=10)
            return c

        def f_unroll(x, w):
            for _ in range(10):
                x = jnp.tanh(x @ w)
            return x

        x = jnp.zeros((64, 64))
        w = jnp.zeros((64, 64))
        rs = analyze_hlo(jax.jit(f_scan).lower(x, w).compile().as_text())
        ru = analyze_hlo(jax.jit(f_unroll).lower(x, w).compile().as_text())
        assert rs["flops"] == pytest.approx(ru["flops"], rel=0.05)
        # 10 x 2*64^3 matmul flops dominate
        assert ru["flops"] == pytest.approx(10 * 2 * 64**3, rel=0.2)

    def test_collective_parse(self):
        from repro.roofline.hlo_analyzer import analyze_hlo
        hlo = """
HloModule m

ENTRY %main (p: f32[128,128]) -> f32[128,128] {
  %p = f32[128,128]{1,0} parameter(0)
  ROOT %ar = f32[128,128]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
}
"""
        r = analyze_hlo(hlo)
        assert r["coll_all-reduce"] == 128 * 128 * 4


@pytest.mark.slow
class TestSortedMoE:
    def test_matches_nodrop_dispatch(self):
        """Dropless sorted dispatch == capacity dispatch with no drops."""
        import dataclasses
        import jax
        import jax.numpy as jnp
        from repro.configs.base import get_config
        from repro.models.moe import init_moe, moe_ffn

        cfg0 = get_config("olmoe-1b-7b").reduced(compute_dtype="float32",
                                                 param_dtype="float32")
        nodrop = dataclasses.replace(cfg0, capacity_factor=float(cfg0.n_experts) / cfg0.top_k)
        srt = dataclasses.replace(cfg0, moe_impl="sorted")
        params = init_moe(jax.random.PRNGKey(0), cfg0, jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 32, cfg0.d_model)), jnp.float32)
        y1, _ = moe_ffn(params, x, nodrop)
        y2, _ = moe_ffn(params, x, srt)
        rel = float(jnp.abs(y1 - y2).max()) / float(jnp.abs(y1).max())
        assert rel < 1e-4, rel
