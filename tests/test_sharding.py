"""Sharding-rule validation without compilation: every param/cache/batch
spec must divide the production mesh axis sizes for every assigned arch —
this is the fast sanity layer under the dry-run."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import all_arch_names, get_config
from repro.configs.shapes import SHAPES, cell_supported
from repro.models.model_zoo import build
from repro.sharding.partition import batch_specs, cache_specs, param_specs
from repro.sharding.collectives import compress_tree

AXES = {"pod": 2, "data": 16, "model": 16}


def _check_divisible(tree_specs, tree_sds, what):
    problems = []

    def walk(spec, leaf):
        shape = leaf.shape
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= AXES[a]
            if dim >= len(shape) or shape[dim] % n != 0:
                problems.append(f"{what}: {shape} dim{dim} % {n} != 0 ({ax})")

    jax.tree.map(walk, tree_specs, tree_sds,
                 is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return problems


@pytest.mark.parametrize("arch", all_arch_names())
def test_param_specs_divide_production_mesh(arch):
    cfg = get_config(arch)
    model = build(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(sds)
    problems = _check_divisible(specs, sds, arch)
    assert not problems, problems[:5]


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "deepseek-v3-671b", "zamba2-2.7b",
                                  "xlstm-125m", "seamless-m4t-medium"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divide(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, _ = cell_supported(cfg, shape)
    if not ok:
        pytest.skip("cell not supported")
    from repro.launch.mesh import make_host_mesh  # any mesh: specs are static
    model = build(cfg)
    caches = jax.eval_shape(
        lambda: model.init_caches(None, shape.global_batch, shape.seq_len))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    specs = cache_specs(caches, cfg, FakeMesh(), shape.global_batch)
    problems = _check_divisible(specs, caches, f"{arch}/{shape_name}")
    assert not problems, problems[:5]


def test_batch_specs_shard_batch_dim():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    sds = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
           "odd": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    specs = batch_specs(sds, FakeMesh())
    assert specs["tokens"] == jax.sharding.PartitionSpec(("pod", "data"), None)
    assert specs["odd"] == jax.sharding.PartitionSpec(None, None)


def test_compress_tree_preserves_shapes_and_bounds_error():
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.standard_normal((32, 8)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((5,)) * 100, jnp.float32)}
    out = compress_tree(tree)
    for k in tree:
        assert out[k].shape == tree[k].shape
        scale = float(jnp.abs(tree[k]).max()) / 127.0
        assert float(jnp.abs(out[k] - tree[k]).max()) <= scale * 0.51
