"""Stateful property tests of the ownership protocol (§3.3, §3.6).

A ``RuleBasedStateMachine`` drives the production ``Catalog``/``PoolMaster``
through random publish / borrow / release / tombstone / delete / gc walks and
checks the model-level invariants after every rule:

* refcount == number of held borrows (the machine's own ledger);
* held borrows stay pinned to the regions/version they observed;
* borrowed bytes always match the canonical content of the pinned version
  (no torn or stale reads);
* pool free lists stay conserved, sorted, and disjoint.

Runs under real ``hypothesis`` when installed, else under the deterministic
fallback shim registered in conftest.py.
"""
import numpy as np
from hypothesis import settings
from hypothesis.stateful import (
    RuleBasedStateMachine,
    invariant,
    rule,
    run_state_machine_as_test,
)
from hypothesis import strategies as st

from repro.core import (
    Catalog,
    HierarchicalPool,
    PoolMaster,
    STATE_FREE,
    STATE_PUBLISHED,
    STATE_TOMBSTONE,
    SnapshotReader,
    StateImage,
)
from repro.core.profiler import AccessRecorder

NAMES = ["alpha", "beta", "gamma"]


class CoherenceMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.pool = HierarchicalPool(96 << 20, 96 << 20)
        self.master = PoolMaster(self.pool, Catalog(capacity=8))
        self.catalog = self.master.catalog
        self.held = []                      # (name, borrow, regions, version)
        self.content = {}                   # name -> version -> StateImage
        self.counter = 0.0

    # -- helpers -----------------------------------------------------------
    def _held_on(self, name):
        return [h for h in self.held if h[0] == name]

    def _publish(self, name):
        self.counter += 1.0
        arr = {
            "hot": np.full(2048, np.float32(self.counter), np.float32),
            "cold": np.arange(1024, dtype=np.float32) + np.float32(self.counter),
        }
        img = StateImage.build(arr)
        rec = AccessRecorder(img.manifest)
        rec.touch_array("hot")
        regions = self.master.publish(name, img, rec.working_set())
        self.content.setdefault(name, {})[regions.version] = img

    # -- rules -------------------------------------------------------------
    @rule(name=st.sampled_from(NAMES))
    def publish(self, name):
        # only update when the entry is drained: the blocking publish() waits
        # for refcount==0 and this machine is single-threaded
        entry = self.catalog.find(name)
        if entry is not None and entry.refcount.load() != 0:
            return
        self._publish(name)

    @rule(name=st.sampled_from(NAMES))
    def borrow(self, name):
        b = self.catalog.borrow(name)
        if b is not None:
            self.held.append((name, b, b.regions, b.version))

    @rule(i=st.integers(0, 5))
    def release(self, i):
        if self.held:
            name, b, _regions, _version = self.held.pop(i % len(self.held))
            b.release()

    @rule(name=st.sampled_from(NAMES))
    def tombstone(self, name):
        self.catalog.tombstone(name)

    @rule(name=st.sampled_from(NAMES))
    def delete(self, name):
        self.master.delete(name)

    @rule()
    def gc(self):
        self.master.gc()

    @rule()
    def verify_held_reads(self):
        """Every held borrow still reads the exact bytes of its version."""
        for name, b, regions, version in self.held:
            canonical = self.content[name][version].pages_matrix()
            view = self.pool.host_view(f"check{id(b)}")
            reader = SnapshotReader(regions, view, self.pool.rdma)
            reader.invalidate_cxl()
            for p in reader.hot_page_indices()[:2]:
                assert np.array_equal(reader.read_page(int(p)), canonical[int(p)]), \
                    f"torn/stale read of {name} v{version} page {int(p)}"

    # -- invariants ----------------------------------------------------------
    @invariant()
    def refcounts_match_held_borrows(self):
        per_entry = {}
        for _name, b, _regions, _version in self.held:
            per_entry[b.entry.index] = per_entry.get(b.entry.index, 0) + 1
        for entry in self.catalog.entries:
            assert entry.refcount.load() == per_entry.get(entry.index, 0), \
                f"entry {entry.index}: refcount drifted from held borrows"

    @invariant()
    def held_borrows_stay_pinned(self):
        for name, b, regions, version in self.held:
            assert b.entry.regions is regions, \
                f"{name} v{version}: regions rewritten under a live borrow"
            assert b.entry.version == version

    @invariant()
    def catalog_states_valid(self):
        for entry in self.catalog.entries:
            state = entry.state.load()
            assert state in (STATE_FREE, STATE_PUBLISHED, STATE_TOMBSTONE)
            if state == STATE_PUBLISHED:
                assert entry.regions is not None

    @invariant()
    def pool_bytes_conserved(self):
        for tier in (self.pool.cxl, self.pool.rdma):
            free = sorted(tier._free)
            assert sum(s for _o, s in free) + tier.bytes_in_use == tier.capacity
            prev_end = 0
            for off, size in free:
                assert off >= prev_end, f"tier {tier.name}: overlapping free list"
                prev_end = off + size

    def teardown(self):
        for _name, b, _regions, _version in self.held:
            b.release()
        self.master.gc()


def test_coherence_state_machine():
    run_state_machine_as_test(
        CoherenceMachine,
        settings=settings(max_examples=12, stateful_step_count=60, deadline=None),
    )


def test_lease_fallback_state_machine():
    """Same walk through the RPC-lease fallback acquire/release path."""
    from repro.core.coherence import LeaseFallback

    class LeaseMachine(CoherenceMachine):
        def __init__(self):
            super().__init__()
            self.leases = LeaseFallback(self.catalog)

        @rule(name=st.sampled_from(NAMES))
        def lease_borrow(self, name):
            b = self.leases.acquire(name)
            if b is not None:
                self.held.append((name, b, b.regions, b.version))

    run_state_machine_as_test(
        LeaseMachine,
        settings=settings(max_examples=8, stateful_step_count=50, deadline=None),
    )
