"""Fault-tolerant page serving (DESIGN.md §15).

Covers the production fault seam end to end: the deterministic
``FaultInjector`` schedules (and their parity with the sim's reference
``FlakyTier``), ``call_with_retries`` backoff behaviour, the ``TierHealth``
circuit breaker, checksum repair with dedup-store quarantine, CXL-brownout
degradation, and the fleet scheduler's health de-scoring.

Two property guarantees (hypothesis; the conftest fallback keeps them
running without it):

* a fixed seed + fault schedule yields an IDENTICAL retry/sleep trace and
  backoff ledger under ``VirtualClock`` — fault handling is replayable;
* a zero-fault schedule (injector armed but empty) leaves every cost
  ledger byte-identical to running with no injector at all — the
  fault-free overhead of the seam is exactly zero modeled seconds.
"""
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FaultInjector,
    HierarchicalPool,
    Instance,
    PoolMaster,
    RestoreEngine,
    RetryPolicy,
    SnapshotReader,
    StateImage,
    TierFaultError,
    TierHealth,
    TimeLedger,
    call_with_retries,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.serving import AsyncRDMAEngine
from repro.fleet.arrivals import FunctionType
from repro.fleet.model import RestoreProfile
from repro.fleet.placement import HostState, PlacementScheduler
from repro.kernels.snapshot_fuse import FusedScatter, make_fused_publish_fn
from repro.kernels.snapshot_fuse.ops import ChecksumMismatchError
from repro.sim import FlakyTier, VirtualClock

CLASSES = ("hot",) * 4 + ("cold",) * 4 + ("zero",) * 2


def build_layout(classes=CLASSES, fill_seed=0):
    n = len(classes)
    rng = np.random.default_rng(fill_seed + 1000 * n)
    buf = np.zeros(n * PAGE_SIZE, dtype=np.uint8)
    for i, cls in enumerate(classes):
        if cls == "zero":
            continue
        page = rng.integers(0, 256, size=PAGE_SIZE, dtype=np.uint8)
        page[0] = max(1, int(page[0]))
        buf[i * PAGE_SIZE : (i + 1) * PAGE_SIZE] = page
    img = StateImage.build({"blob": buf})
    ws = [i for i, cls in enumerate(classes) if cls == "hot"]
    return img, ws


def publish_stack(classes=CLASSES, fused=False, fill_seed=0):
    img, ws = build_layout(classes, fill_seed)
    pool = HierarchicalPool(64 << 20, 64 << 20)
    master = PoolMaster(pool)
    pf = make_fused_publish_fn(use_pallas=False) if fused else None
    master.publish("snap", img, ws, publish_fn=pf)
    borrow = master.catalog.borrow("snap")
    assert borrow is not None
    return img, pool, borrow


def run_restore(img, pool, borrow, host="h", scatter_fn=None, clock=None):
    view = pool.host_view(host)
    reader = SnapshotReader(borrow.regions, view, pool.rdma)
    reader.invalidate_cxl()
    inst = Instance(StateImage.empty_like(img.manifest), clock=clock)
    engine = RestoreEngine(reader, inst, None, scatter_fn=scatter_fn,
                           clock=clock)
    engine.install_all_sync(use_batch=True)
    return view, reader, inst, engine


# -- FaultInjector schedules --------------------------------------------------

class TestFaultInjector:
    def test_read_windows_count_and_bound(self):
        inj = FaultInjector(seed=1).fail_reads("rdma", 2, lo=PAGE_SIZE,
                                               hi=3 * PAGE_SIZE)
        # outside the byte window: clean
        inj.check_read("rdma", 0, PAGE_SIZE)
        # wrong tier: clean even inside the window
        inj.check_read("cxl", PAGE_SIZE, PAGE_SIZE)
        for _ in range(2):
            with pytest.raises(TierFaultError) as ei:
                inj.check_read("rdma", PAGE_SIZE, PAGE_SIZE)
            assert ei.value.kind == "timeout" and ei.value.tier == "rdma"
        inj.check_read("rdma", PAGE_SIZE, PAGE_SIZE)   # window drained
        assert inj.stats["injected_timeouts"] == 2
        assert inj.stats["reads"] == 5

    def test_write_faults_symmetric_to_reads(self):
        inj = FaultInjector(seed=1).fail_writes("cxl", 1)
        with pytest.raises(TierFaultError) as ei:
            inj.check_write("cxl", 0, PAGE_SIZE)
        assert ei.value.kind == "write"
        inj.check_write("cxl", 0, PAGE_SIZE)
        assert inj.stats["injected_write_faults"] == 1
        assert inj.stats["writes"] == 2

    def test_poison_corrupts_only_window_page_of_returned_copy(self):
        inj = FaultInjector(seed=1).poison_reads(
            "cxl", 1, lo=PAGE_SIZE, hi=2 * PAGE_SIZE)
        data = np.zeros(3 * PAGE_SIZE, dtype=np.uint8)
        hit = inj.filter_read("cxl", 0, data.nbytes, data)
        assert hit
        # exactly the page overlapping [lo, hi) was flipped, in place
        assert data[PAGE_SIZE] == 0xFF
        assert data[0] == 0 and data[2 * PAGE_SIZE] == 0
        assert int(np.count_nonzero(data)) == 1
        assert inj.stats["injected_poison"] == 1
        # window consumed: the re-read comes back clean (repairable)
        clean = np.zeros(3 * PAGE_SIZE, dtype=np.uint8)
        assert not inj.filter_read("cxl", 0, clean.nbytes, clean)

    def test_completion_errors(self):
        inj = FaultInjector(seed=1).fail_completions("rdma", 1)
        with pytest.raises(TierFaultError) as ei:
            inj.check_completion("rdma")
        assert ei.value.kind == "completion"
        inj.check_completion("rdma")
        assert inj.stats["injected_completion_errors"] == 1

    def test_brownout_hits_host_link_reads_only(self):
        clock = VirtualClock()
        inj = FaultInjector(clock=clock, seed=0).brownout(
            "cxl", start_s=1.0, duration_s=2.0)
        assert not inj.in_brownout("cxl")
        inj.check_read("cxl", 0, PAGE_SIZE, host_link=True)   # before window
        clock.advance(1.5)
        assert inj.in_brownout("cxl")
        with pytest.raises(TierFaultError) as ei:
            inj.check_read("cxl", 0, PAGE_SIZE, host_link=True)
        assert ei.value.kind == "brownout"
        # the owner-side pool-fabric path is NOT browned out
        inj.check_read("cxl", 0, PAGE_SIZE, host_link=False)
        clock.advance(2.0)
        assert not inj.in_brownout("cxl")
        inj.check_read("cxl", 0, PAGE_SIZE, host_link=True)   # after window
        assert inj.stats["brownout_rejections"] == 1


# -- FlakyTier is the reference implementation (satellite: parity) ------------

class TestFlakyTierParity:
    @staticmethod
    def _access_seq(rng, n=24):
        return [(int(rng.integers(0, 8)) * PAGE_SIZE,
                 int(rng.integers(1, 3)) * PAGE_SIZE) for _ in range(n)]

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_read_fault_pattern_matches_reference(self, seed):
        rng = np.random.default_rng(seed)
        windows = [(int(rng.integers(1, 4)),
                    int(rng.integers(0, 4)) * PAGE_SIZE,
                    int(rng.integers(4, 9)) * PAGE_SIZE)
                   for _ in range(int(rng.integers(1, 3)))]
        seq = self._access_seq(rng)

        pool = HierarchicalPool(16 << 20, 16 << 20)
        flaky = FlakyTier(pool.rdma)
        inj = FaultInjector(seed=seed)
        for n, lo, hi in windows:
            flaky.fail_reads(n, lo, hi)
            inj.fail_reads("rdma", n, lo, hi)

        def mask(fn):
            out = []
            for off, nb in seq:
                try:
                    fn(off, nb)
                    out.append(False)
                except TierFaultError:
                    out.append(True)
            return out

        ref = mask(flaky.read)
        got = mask(lambda off, nb: inj.check_read("rdma", off, nb))
        assert got == ref
        assert inj.stats["injected_timeouts"] == flaky.stats["injected_timeouts"]
        assert inj.stats["reads"] == flaky.stats["reads"] == len(seq)

    def test_write_fault_pattern_matches_reference(self):
        pool = HierarchicalPool(16 << 20, 16 << 20)
        flaky = FlakyTier(pool.rdma).fail_writes(2, lo=PAGE_SIZE,
                                                 hi=3 * PAGE_SIZE)
        inj = FaultInjector(seed=0).fail_writes("rdma", 2, lo=PAGE_SIZE,
                                                hi=3 * PAGE_SIZE)
        page = np.ones(PAGE_SIZE, dtype=np.uint8)
        seq = [0, PAGE_SIZE, 2 * PAGE_SIZE, PAGE_SIZE, 4 * PAGE_SIZE]
        ref, got = [], []
        for off in seq:
            try:
                flaky.write(off, page)
                ref.append(False)
            except TierFaultError:
                ref.append(True)
            try:
                inj.check_write("rdma", off, page.nbytes)
                got.append(False)
            except TierFaultError:
                got.append(True)
        assert got == ref == [False, True, True, False, False]
        assert (inj.stats["injected_write_faults"]
                == flaky.stats["injected_write_faults"] == 2)
        assert inj.stats["writes"] == flaky.stats["writes"] == len(seq)


# -- retry/backoff ------------------------------------------------------------

class TestCallWithRetries:
    @staticmethod
    def _run_once(seed, n_faults):
        clock = VirtualClock()
        ledger = TimeLedger()
        trace = []
        left = [n_faults]

        def fn():
            if left[0] > 0:
                left[0] -= 1
                raise TierFaultError("injected", tier="rdma")
            return 42

        out = call_with_retries(fn, rng=random.Random(seed), ledger=ledger,
                                clock=clock, trace=trace)
        return out, tuple(trace), dict(ledger.seconds), clock.monotonic()

    @given(st.integers(0, 2**31 - 1), st.integers(0, 4))
    @settings(max_examples=30, deadline=None)
    def test_same_seed_same_schedule_identical_trace(self, seed, n_faults):
        a = self._run_once(seed, n_faults)
        b = self._run_once(seed, n_faults)
        assert a == b, "retry/sleep behaviour must replay bit-identically"
        out, trace, ledger, elapsed = a
        assert out == 42 and len(trace) == n_faults
        # every backoff is slept on the clock AND charged to the ledger
        assert elapsed == sum(trace)
        assert ledger.get("retry_backoff", 0.0) == sum(trace)

    def test_exhaustion_raises_after_max_retries(self):
        calls = [0]

        def fn():
            calls[0] += 1
            raise TierFaultError("always", tier="rdma")

        with pytest.raises(TierFaultError):
            call_with_retries(fn, rng=random.Random(0), clock=VirtualClock())
        assert calls[0] == RetryPolicy().max_retries + 1

    def test_brownout_is_never_retried(self):
        calls = [0]

        def fn():
            calls[0] += 1
            raise TierFaultError("dark", tier="cxl", kind="brownout")

        with pytest.raises(TierFaultError):
            call_with_retries(fn, rng=random.Random(0), clock=VirtualClock())
        assert calls[0] == 1, "the breaker degrades; retries must not hammer"

    def test_deadline_bounds_cumulative_backoff(self):
        policy = RetryPolicy(max_retries=100, base_backoff_s=1e-3,
                             jitter_frac=0.0, extent_deadline_s=4e-3)
        clock = VirtualClock()

        def fn():
            raise TierFaultError("slow", tier="rdma")

        with pytest.raises(TierFaultError):
            call_with_retries(fn, policy=policy, clock=clock)
        assert clock.monotonic() <= policy.extent_deadline_s

    def test_demand_faults_escalate(self):
        policy = RetryPolicy()
        assert (policy.backoff_s(0, urgent=True)
                < policy.backoff_s(0, urgent=False))
        assert policy.deadline_s(urgent=True) < policy.deadline_s(urgent=False)


class TestEngineRetry:
    def test_engine_retries_through_transient_faults(self):
        pool = HierarchicalPool(16 << 20, 16 << 20)
        want = np.arange(PAGE_SIZE, dtype=np.uint8) % 251
        pool.rdma.write(0, want)
        pool.rdma.fault_injector = FaultInjector(seed=1).fail_reads("rdma", 2)
        ledger = TimeLedger()
        eng = AsyncRDMAEngine(pool.rdma, ledger, start=False)
        buf = np.empty(PAGE_SIZE, dtype=np.uint8)
        eng._execute_read(1, 0, PAGE_SIZE, buf, ledger)
        np.testing.assert_array_equal(buf, want)
        assert eng.stats["retries"] == 2
        assert eng.stats["injected_faults"] == 2
        assert eng.stats["retry_exhausted"] == 0
        # wasted wire time and backoff are both charged to modeled time
        assert ledger.seconds.get("rdma_retry", 0.0) > 0.0
        assert ledger.seconds.get("retry_backoff", 0.0) > 0.0

    def test_engine_exhaustion_degrades_to_final_clean_read(self):
        pool = HierarchicalPool(16 << 20, 16 << 20)
        want = np.full(PAGE_SIZE, 7, dtype=np.uint8)
        pool.rdma.write(0, want)
        # more scheduled faults than the retry budget: the engine must not
        # spin forever — it finishes with one clean (uninjected) read
        pool.rdma.fault_injector = FaultInjector(seed=1).fail_reads("rdma", 99)
        eng = AsyncRDMAEngine(pool.rdma, TimeLedger(), start=False)
        buf = np.empty(PAGE_SIZE, dtype=np.uint8)
        eng._execute_read(1, 0, PAGE_SIZE, buf, eng.ledger)
        np.testing.assert_array_equal(buf, want)
        assert eng.stats["retry_exhausted"] == 1
        assert eng.stats["retries"] == eng.retry.max_retries


# -- TierHealth circuit breaker -----------------------------------------------

class TestTierHealth:
    def test_soft_failures_trip_at_threshold(self):
        ht = TierHealth("cxl", VirtualClock(), failure_threshold=3)
        for _ in range(2):
            ht.record_failure()
            assert ht.allow() and not ht.degraded
        ht.record_failure()
        assert not ht.allow() and ht.degraded
        assert ht.stats == {"failures": 3, "trips": 1, "probes": 0,
                            "recoveries": 0}

    def test_hard_failure_trips_immediately(self):
        ht = TierHealth("cxl", VirtualClock())
        ht.record_failure(hard=True)
        assert not ht.allow() and ht.state == TierHealth.OPEN

    def test_success_resets_soft_failure_count(self):
        ht = TierHealth("cxl", VirtualClock(), failure_threshold=2)
        ht.record_failure()
        ht.record_success()
        ht.record_failure()
        assert ht.allow(), "success between failures resets the count"

    def test_half_open_probe_then_recovery(self):
        clock = VirtualClock()
        ht = TierHealth("cxl", clock, cooldown_s=1e-3)
        ht.record_failure(hard=True)
        assert not ht.allow()
        clock.advance(1e-3)
        assert ht.allow() and ht.state == TierHealth.HALF_OPEN
        assert ht.stats["probes"] == 1
        ht.record_success()
        assert ht.state == TierHealth.CLOSED and not ht.degraded
        assert ht.stats["recoveries"] == 1

    def test_half_open_failure_reopens(self):
        clock = VirtualClock()
        ht = TierHealth("cxl", clock, cooldown_s=1e-3)
        ht.record_failure(hard=True)
        clock.advance(1e-3)
        assert ht.allow()                       # HALF_OPEN probe admitted
        ht.record_failure()                     # probe failed
        assert ht.state == TierHealth.OPEN and not ht.allow()
        assert ht.stats["trips"] == 2


# -- ChecksumMismatchError (satellite: structured payload + message) ----------

class TestChecksumMismatchError:
    def test_bad_pages_is_structured_int64(self):
        err = ChecksumMismatchError(np.array([5, 2], dtype=np.int32))
        assert err.bad_pages.dtype == np.int64
        assert err.bad_pages.tolist() == [5, 2]
        assert isinstance(err, RuntimeError)
        # scalar input is normalized to a 1-D array
        assert ChecksumMismatchError(3).bad_pages.tolist() == [3]
        # back-compat alias
        assert err.pages.tolist() == [5, 2]

    def test_message_is_readable_and_truncated(self):
        short = ChecksumMismatchError(np.arange(3))
        assert str(short) == "checksum mismatch on 3 restored page(s): [0, 1, 2]"
        long = ChecksumMismatchError(np.arange(100))
        msg = str(long)
        assert "100 restored page(s)" in msg
        assert str(ChecksumMismatchError.MAX_SHOWN - 1) in msg
        assert "(+92 more)" in msg
        assert "99" not in msg.split("(+")[0], "tail pages must be elided"


# -- zero-fault overhead: the armed seam charges nothing ----------------------

def _restore_ledgers(arm_injector, fill_seed=0):
    img, pool, borrow = publish_stack(fused=True, fill_seed=fill_seed)
    if arm_injector:
        # armed but EMPTY schedule: every read takes the check branches
        pool.attach_fault_injector(FaultInjector(seed=123))
    view, reader, inst, engine = run_restore(
        img, pool, borrow, scatter_fn=FusedScatter(use_pallas=False))
    assert inst.all_present()
    np.testing.assert_array_equal(inst.image.buf, img.buf)
    return (dict(inst.ledger.seconds), dict(view.ledger.seconds),
            dict(inst.stats), dict(engine.repair_stats))


@given(st.integers(0, 100))
@settings(max_examples=5, deadline=None)
def test_zero_fault_schedule_leaves_ledger_byte_identical(fill_seed):
    base = _restore_ledgers(arm_injector=False, fill_seed=fill_seed)
    armed = _restore_ledgers(arm_injector=True, fill_seed=fill_seed)
    assert armed == base, (
        "an armed-but-empty injector (and attached TierHealth breakers) "
        "must not change any modeled charge or counter")


# -- checksum repair ----------------------------------------------------------

class TestChecksumRepair:
    def test_poisoned_page_is_repaired_from_home_tier(self):
        img, pool, borrow = publish_stack(fused=True)
        probe = SnapshotReader(borrow.regions, pool.host_view("probe"),
                               pool.rdma)
        hot0 = int(probe.hot_page_indices()[0])
        _kind, off = probe.lookup(hot0)
        inj = FaultInjector(seed=3).poison_reads("cxl", 1, lo=off,
                                                 hi=off + PAGE_SIZE)
        pool.attach_fault_injector(inj)
        view, reader, inst, engine = run_restore(
            img, pool, borrow, scatter_fn=FusedScatter(use_pallas=False))
        assert inst.all_present()
        np.testing.assert_array_equal(inst.image.buf, img.buf)
        assert inj.stats["injected_poison"] == 1
        assert engine.repair_stats["checksum_mismatches"] == 1
        assert engine.repair_stats["checksum_repairs"] == 1
        assert engine.repair_stats["repair_failures"] == 0
        # the repair re-read is charged like a fresh demand read
        assert inst.ledger.seconds.get("cxl_read", 0.0) > 0.0

    def test_at_rest_corruption_exhausts_repair_budget_and_surfaces(self):
        img, pool, borrow = publish_stack(fused=True)
        probe = SnapshotReader(borrow.regions, pool.host_view("probe"),
                               pool.rdma)
        hot0 = int(probe.hot_page_indices()[0])
        _kind, off = probe.lookup(hot0)
        # corrupt the pool bytes themselves: every budgeted re-read sees the
        # same bad content, so repair cannot succeed and must SURFACE
        pool.cxl.buf[off] ^= 0xFF
        view = pool.host_view("h")
        reader = SnapshotReader(borrow.regions, view, pool.rdma)
        reader.invalidate_cxl()
        inst = Instance(StateImage.empty_like(img.manifest))
        engine = RestoreEngine(reader, inst, None,
                               scatter_fn=FusedScatter(use_pallas=False))
        with pytest.raises(RuntimeError) as ei:
            engine.install_all_sync(use_batch=True)
        assert getattr(ei.value, "bad_pages", None) is not None
        assert engine.repair_stats["repair_failures"] == 1
        assert engine.repair_stats["checksum_repairs"] == 0


class TestQuarantine:
    @staticmethod
    def _dedup_stack():
        img, ws = build_layout(CLASSES, fill_seed=5)
        pool = HierarchicalPool(64 << 20, 64 << 20)
        master = PoolMaster(pool)
        master.publish("snap", img, ws, dedup=True,
                       publish_fn=make_fused_publish_fn(use_pallas=False))
        return pool, pool.dedup_cxl

    def test_quarantine_bars_sharing_without_touching_refs(self):
        pool, store = self._dedup_stack()
        off = min(store._hash_of)
        refs_before = store.refcounts()
        assert store.quarantine(off) is True
        assert store.quarantine(off) is False       # already quarantined
        assert store.quarantine(1 << 40) is False   # not a store offset
        assert store.quarantined_offsets() == [off]
        assert store.stats["quarantined"] == 1
        # I6: existing references are untouched by quarantine
        assert store.refcounts() == refs_before
        assert store.unique_pages() == len(refs_before)

    def test_rematerialize_verifies_content_hash(self):
        pool, store = self._dedup_stack()
        off = min(store._hash_of)
        clean = pool.cxl.buf[off : off + PAGE_SIZE].copy()
        store.quarantine(off)
        wrong = clean.copy()
        wrong[0] ^= 0xFF
        with pytest.raises(ValueError):
            store.rematerialize(off, wrong)
        store.rematerialize(off, clean)
        assert store.quarantined_offsets() == []
        assert store.stats["rematerialized"] == 1
        # un-quarantined offsets cannot be rematerialized
        with pytest.raises(ValueError):
            store.rematerialize(off, clean)


# -- brownout degradation -----------------------------------------------------

class TestBrownoutDegradation:
    def test_restore_degrades_to_rdma_only_and_stays_bit_identical(self):
        clock = VirtualClock()
        img, pool, borrow = publish_stack(fused=True)
        inj = FaultInjector(clock=clock, seed=0).brownout(
            "cxl", start_s=0.0, duration_s=1e9)
        pool.attach_fault_injector(inj)
        view, reader, inst, engine = run_restore(
            img, pool, borrow, scatter_fn=FusedScatter(use_pallas=False),
            clock=clock)
        assert inst.all_present()
        np.testing.assert_array_equal(inst.image.buf, img.buf)
        assert engine.degraded_cxl
        assert engine.repair_stats["degraded_preinstalls"] == 1
        assert engine.repair_stats["degraded_faults"] > 0
        assert pool.health["cxl"].degraded
        assert inj.stats["brownout_rejections"] >= 1
        # hot pages arrived over the RNIC: charged as rdma_read, and the
        # host-link ledger carries no CXL hot-chunk charges
        assert view.stats.get("degraded_reads", 0) > 0
        assert view.ledger.seconds.get("rdma_read", 0.0) > 0.0

    def test_degraded_model_upper_bounds_the_healthy_one(self):
        from repro.serve.strategies import (
            modeled_concurrent_restore_s,
            modeled_degraded_restore_s,
        )
        img, pool, borrow = publish_stack(fused=True)
        view = pool.host_view("m")
        reader = SnapshotReader(borrow.regions, view, pool.rdma)
        healthy = modeled_concurrent_restore_s(reader, 1)
        degraded = modeled_degraded_restore_s(reader, 1)
        assert degraded > healthy > 0.0, (
            "page-at-a-time RNIC hot transfer must cost more than the "
            "chunked CXL pre-install")


# -- health feeds placement ---------------------------------------------------

class _DegradedHealth:
    degraded = True


class TestPlacementHealth:
    def test_unhealthy_host_is_descored_and_avoided(self):
        prof = RestoreProfile(
            name="fn0", version=1, total_pages=3072,
            hot_bytes=4 << 20, cold_bytes=8 << 20,
            meta_terms=((4e-7 + 4096 / 50e9, 4096),),
            flush_s=1e-5, hot_serial_s=(4 << 20) / 50e9, hot_chunks=16,
            hot_install_s=3e-5, zero_install_s=1e-6,
            cold_serial_s=(8 << 20) / 12.5e9, cold_install_s=5e-5)
        fn = FunctionType(0, "fn0", 0, 10.0, "poisson", 0.5)
        sched = PlacementScheduler("locality")
        healthy, unhealthy = HostState(0), HostState(1)
        unhealthy.note_health(_DegradedHealth())
        assert not unhealthy.cxl_healthy
        assert (sched.score(unhealthy, fn, prof)
                < sched.score(healthy, fn, prof))
        assert sched.choose([healthy, unhealthy], fn, prof) is healthy
        # recovery: the breaker closing restores the score symmetrically
        unhealthy.note_health(None)
        assert unhealthy.cxl_healthy
        assert (sched.score(unhealthy, fn, prof)
                == sched.score(healthy, fn, prof))
