"""Deterministic cluster-simulator scenario matrix (DESIGN.md §9).

Every scenario is a pure function of a seed: it builds a :class:`SimCluster`,
schedules host programs against the *real* production objects, injects the
scripted faults, and asserts the outcome.  The invariant checker (I1–I5)
runs after every step inside the simulator, so a scenario passing means the
invariants held across the whole interleaving, not just at the end.

Seed control: ``AQUIFER_SIM_SEED`` (default 0) offsets every scenario's
seed — CI's nightly job rotates it.  Any failure message embeds
``[seed=... step=...]``; re-running the same scenario with that seed replays
the identical interleaving.
"""
import os
import random

import numpy as np
import pytest

from repro.core import (STATE_FREE, STATE_PUBLISHED, STATE_TOMBSTONE,
                        SnapshotReader, TouchEvent)
from repro.core.coherence import LeaseFallback
from repro.sim import FlakyTier, SimCluster, SimTimeout

SEED = int(os.environ.get("AQUIFER_SIM_SEED", "0"))


# ---------------------------------------------------------------------------
# scenario library: name -> callable(seed) -> SimCluster (assertions inside)
# ---------------------------------------------------------------------------

def scenario_steady_borrow_release(seed):
    """2 hosts looping borrow/verify/release against a stable snapshot."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("snap", 1.0)
    c.add_program("h1", c.borrower_program("h1", "snap", attempts=4))
    c.add_program("h2", c.borrower_program("h2", "snap", attempts=4))
    c.run()
    assert "borrower_done:h1:4/4" in c.events
    assert "borrower_done:h2:4/4" in c.events
    assert c.catalog.find("snap").refcount.load() == 0
    return c


def scenario_owner_update_vs_borrowers(seed):
    """3 borrower hosts racing owner updates: every successful borrow reads
    single-version data; the final catalog version is the owner's last."""
    c = SimCluster(n_hosts=3, seed=seed)
    c.publish("snap", 1.0)
    c.add_program("owner", c.publish_program("snap", 2.0))
    for h in ("h1", "h2", "h3"):
        c.add_program(h, c.borrower_program(h, "snap", attempts=3))
    c.run()
    assert "published:snap:v1" in c.events
    entry = c.catalog.find("snap")
    assert entry.version == 1 and entry.state.load() == STATE_PUBLISHED
    assert entry.refcount.load() == 0
    return c


def scenario_doomed_borrow_interleaving(seed):
    """PR-1 regression, exact interleaving: owner tombstones *between* the
    borrower's refcount increment and its state CAS.  The borrower must back
    out and cold-start; the owner must drain without a single stall poll."""
    c = SimCluster(n_hosts=2, seed=seed, schedule="round_robin")
    c.publish("s", 1.0)

    def borrower_once(host):
        rec = yield from c.borrow_program_steps(host, "s")
        assert rec is None, "borrow should be doomed by the interleaved tombstone"
        c.events.append(f"cold_start:{host}")
        yield "borrower:cold_start"

    c.add_program("h1", borrower_once("h1"))       # rr slot 1: refcount++
    c.add_program("owner", c.publish_program("s", 2.0))  # rr slot 2: tombstone
    c.run()
    labels = [l for _s, _p, l in c.trace]
    assert "borrow:refcount_incremented" in labels and "borrow:doomed" in labels
    assert labels.index("borrow:refcount_incremented") \
        < labels.index("publish:tombstoned") < labels.index("borrow:doomed")
    assert "publish:draining" not in labels, "owner stalled on a doomed borrow"
    assert "cold_start:h1" in c.events and "published:s:v1" in c.events
    return c


def scenario_livelock_when_fix_reverted(seed):
    """Reverting the PR-1 state pre-check (state_precheck=False) livelocks
    the owner's drain against tight-loop borrowers; the same seed with the
    fix present completes.  This is the pre-PR-1 bug, reproduced on demand.
    Round-robin scheduling pins the adversarial interleaving (every owner
    poll lands while a borrower is paused mid-increment) for ANY seed."""
    def run(precheck):
        c = SimCluster(n_hosts=3, seed=seed, schedule="round_robin")
        c.publish("s", 1.0)
        c.add_program("owner", c.publish_program("s", 2.0, drain_limit=50))
        c.add_program("b1", c.tight_borrower_program("b1", "s", precheck=precheck))
        c.add_program("b2", c.tight_borrower_program("b2", "s", precheck=precheck))
        c.run(max_steps=5000, until=lambda cl: cl._programs["owner"].done)
        return c

    broken = run(precheck=False)
    assert "drain_timeout:s" in broken.events, \
        f"[seed={seed}] expected livelock with the fix reverted"
    assert "published:s:v1" not in broken.events
    fixed = run(precheck=True)
    assert "published:s:v1" in fixed.events and "drain_timeout:s" not in fixed.events
    return fixed


def scenario_host_crash_mid_borrow(seed):
    """Host dies between refcount++ and the CAS: the increment leaks, the
    owner's drain times out, and the checker's accounting still matches the
    shared word exactly (the leak is tracked, not drifted)."""
    c = SimCluster(n_hosts=2, seed=seed, schedule="round_robin")
    c.publish("s", 1.0)
    c.fault_plan.kill_after("h1", "borrow:refcount_incremented")
    c.add_program("h1", c.borrower_program("h1", "s", attempts=1))
    c.add_program("owner", c.publish_program("s", 2.0, drain_limit=30))
    c.run(max_steps=2000)
    entry = c.catalog.find("s")
    assert "crashed:h1" in c.events
    assert "drain_timeout:s" in c.events, "owner should time out on the leaked refcount"
    assert entry.refcount.load() == 1 and c.midflight[(0, entry.index)] == 1
    assert entry.state.load() == STATE_TOMBSTONE
    return c


def scenario_host_crash_holding_borrow(seed):
    """Host dies while holding a successful borrow: the refcount leak is an
    orphan record; the owner cannot drain; data stays pinned (never freed
    under the dead host's feet)."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("s", 1.0)
    c.fault_plan.kill_after("h1", "borrower:flushed")
    c.add_program("h1", c.borrower_program("h1", "s", attempts=1))
    c.add_program("owner", c.delayed(0.01, c.publish_program("s", 2.0, drain_limit=30)))
    c.run(max_steps=2000)
    assert "crashed:h1" in c.events and "drain_timeout:s" in c.events
    assert len(c.orphaned_records) == 1
    orphan = c.orphaned_records[0]
    assert orphan.borrow.entry.regions is orphan.regions, \
        "orphaned borrow's data was rewritten"
    return c


def scenario_owner_crash_between_tombstone_and_republish(seed):
    """Owner dies mid-update (after tombstone, before republish): borrowers
    cold-start but never see torn bytes; an elected failover master
    republishes from the shared catalog and borrows succeed again."""
    c = SimCluster(n_hosts=3, seed=seed)
    c.publish("s", 1.0)
    for nid in c.nodes:
        c.add_heartbeat(nid)
    c.fault_plan.kill_after("owner", "publish:tombstoned")
    c.add_program("owner", c.publish_program("s", 2.0))
    c.add_program("h1", c.delayed(0.01, c.borrower_program("h1", "s", attempts=2)))
    c.run(max_steps=4000, until=lambda cl: cl._programs["h1"].done)
    assert "crashed:owner" in c.events
    assert c.events.count("cold_start:h1") == 2, "tombstoned entry must cold-start"
    new_master = c.elected_master()
    assert new_master is not None, "no failover master elected"
    c.add_program("recovery", c.publish_program("s", 3.0, master=new_master))
    c.add_program("h2", c.delayed(0.005, c.borrower_program("h2", "s", attempts=2)))
    c.run(max_steps=8000, until=lambda cl: cl._programs["h2"].done)
    assert "published:s:v1" in c.events
    assert "borrower_done:h2:2/2" in c.events
    return c


def scenario_master_failover_basic(seed):
    """4 nodes: first election, master crash, exactly one successor."""
    c = SimCluster(n_hosts=4, seed=seed)
    c.publish("snap", 1.0)
    for nid in c.nodes:
        c.add_heartbeat(nid)
    c.run(max_steps=200, until=lambda cl: cl.elected_master() is not None)
    first = [n.node_id for n in c.nodes.values() if n.is_master]
    assert len(first) == 1 and c.lease.term.load() == 1
    c.crash_node(first[0])
    c.run(max_steps=6000,
          until=lambda cl: any(n.is_master for n in cl.nodes.values()
                               if n.node_id != first[0]))
    second = [n.node_id for n in c.nodes.values() if n.is_master]
    assert len(second) == 1 and second[0] != first[0]
    assert c.lease.term.load() == 2
    assert c.checker.term_history == {1: first[0], 2: second[0]}
    return c


def scenario_master_failover_races_8_hosts(seed):
    """8 nodes race a repeatedly-crashing master: every term has exactly one
    winner (the I2 invariant is checked at every step of every election)."""
    c = SimCluster(n_hosts=8, seed=seed)
    for nid in c.nodes:
        c.add_heartbeat(nid)
    dead = []
    for round_no in range(3):
        c.run(max_steps=c.step_no + 8000,
              until=lambda cl: any(n.is_master for n in cl.nodes.values()
                                   if n.node_id not in dead))
        masters = [n.node_id for n in c.nodes.values() if n.is_master]
        assert len(masters) == 1, f"round {round_no}: masters={masters}"
        dead.append(masters[0])
        c.crash_node(masters[0])
    assert c.lease.term.load() == 3
    assert sorted(c.checker.term_history) == [1, 2, 3]
    assert len(set(c.checker.term_history.values())) == 3, \
        "a node won two terms it shouldn't have"
    return c


def scenario_lease_expiry_during_gc(seed):
    """The master's heartbeat stalls mid-GC (lease expires while a tombstoned
    entry drains); a new master is elected, the old GC still completes, and
    pool accounting stays conserved throughout."""
    c = SimCluster(n_hosts=3, seed=seed)
    for nid in c.nodes:
        c.add_heartbeat(nid)
    c.run(max_steps=200, until=lambda cl: cl.elected_master() is not None)
    old_master = c.elected_master()
    old_id = [n.node_id for n in c.nodes.values() if n.is_master][0]
    c.publish("s0", 1.0, master=old_master)
    in_use_before = c.pool.cxl.bytes_in_use

    def holder(host):
        rec = yield from c.borrow_program_steps(host, "s0")
        assert rec is not None
        yield ("sleep", 0.3)        # hold across the lease expiry
        yield "holder:waking"
        c.release(rec)
        yield "holder:released"

    c.add_program("h1", holder("h1"))
    c.add_program("gc", c.delayed(0.01, c.delete_program(
        "s0", master=old_master, gc_polls=40, gc_sleep=0.02)))
    # the stall: the old master's heartbeat dies right after the delete starts
    c.fault_plan.kill_after(f"hb{old_id}", "tick", occurrence=3)
    c.run(max_steps=20000)
    assert c.lease.term.load() >= 2, "lease should have changed hands mid-GC"
    assert len(set(c.checker.term_history.values())) >= 2
    entry_states = [e.state.load() for e in c.catalog.entries if e.name == "s0"]
    assert not entry_states, "s0 should be fully reclaimed after the held borrow"
    assert c.pool.cxl.bytes_in_use < in_use_before
    return c


def scenario_rdma_extent_timeout_retry(seed):
    """Injected RDMA extent timeouts: the restore retries with backoff and
    still produces a bit-identical image (verified inside the program)."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("snap", 3.0, hot_pages=4, cold_pages=6, zero_pages=2)
    flaky = FlakyTier(c.pool.rdma).fail_reads(3)
    c.add_program("h1", c.restore_program("h1", "snap", rdma=flaky))
    c.run()
    assert len(c.restored) == 1
    assert c.restored[0]["retries"] == 3
    assert flaky.stats["injected_timeouts"] == 3
    assert c.catalog.find("snap").refcount.load() == 0
    return c


def scenario_rdma_timeout_exhausts_retries(seed):
    """Unrecoverable RDMA timeouts: the restore aborts cleanly — the borrow
    is released (no refcount leak) before the failure propagates."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("snap", 3.0, cold_pages=4)
    flaky = FlakyTier(c.pool.rdma).fail_reads(100)
    c.add_program("h1", c.restore_program("h1", "snap", rdma=flaky, max_retries=2))
    try:
        c.run()
        raised = False
    except SimTimeout:
        raised = True
    assert raised, "restore should abort once retries are exhausted"
    assert c.catalog.find("snap").refcount.load() == 0, "borrow leaked on abort"
    assert not c.restored
    return c


def scenario_batched_vs_perpage_restore_under_updates(seed):
    """Batched and per-page restores of the same snapshot, concurrent with an
    owner update: both are bit-identical to the version they borrowed, and
    both install the same page counts (accounting parity)."""
    c = SimCluster(n_hosts=3, seed=seed)
    c.publish("snap", 1.0, hot_pages=5, cold_pages=7, zero_pages=3)
    c.add_program("batched", c.restore_program("batched", "snap", use_batch=True))
    c.add_program("perpage", c.restore_program("perpage", "snap", use_batch=False))
    c.add_program("owner", c.publish_program("snap", 2.0))
    c.run()
    assert "published:snap:v1" in c.events
    done = {r["host"]: r for r in c.restored}
    # a restore that borrowed before the tombstone sees v0; after republish, v1
    for host in ("batched", "perpage"):
        assert host in done or f"cold_start:{host}" in c.events
    if "batched" in done and "perpage" in done \
            and done["batched"]["version"] == done["perpage"]["version"]:
        assert done["batched"]["uffd_copies"] == done["perpage"]["uffd_copies"]
        assert done["batched"]["uffd_zeropages"] == done["perpage"]["uffd_zeropages"]
    return c


def scenario_eviction_under_borrows(seed):
    """§3.6 eviction racing a live borrow: victims are reclaimed, but the
    borrowed snapshot's bytes stay resident until release, then drain."""
    c = SimCluster(n_hosts=2, seed=seed)
    for i in range(3):
        c.publish(f"s{i}", float(i))

    def borrower_hold(host):
        rec = yield from c.borrow_program_steps(host, "s0")
        assert rec is not None
        yield ("sleep", 0.02)
        yield "holder:waking"
        c.release(rec)
        yield "holder:released"

    def evictor():
        yield ("sleep", 0.005)      # let the borrow land first
        evicted = c.master.evict_for(1 << 30)
        c.events.append("evicted:" + ",".join(sorted(evicted)))
        yield "evicted"
        for _ in range(40):
            c.master.gc()
            if not c.master._pending_reclaim:
                break
            yield ("sleep", 1e-3)
            yield "gc_poll"
        yield "evictor:done"

    c.add_program("h1", borrower_hold("h1"))
    c.add_program("evict", evictor())
    c.run(max_steps=20000)
    assert "evicted:s0,s1,s2" in c.events
    assert c.pool.cxl.bytes_in_use == 0, "everything should drain post-release"
    assert all(e.state.load() == STATE_FREE for e in c.catalog.entries)
    return c


def scenario_catalog_churn(seed):
    """4 hosts doing seeded random publish/delete/borrow/release churn over a
    shared namespace — the invariant checker is the oracle."""
    c = SimCluster(n_hosts=4, seed=seed)
    names = ["a", "b"]
    for i, n in enumerate(names):
        c.publish(n, float(i))

    def churn(host, sub_seed):
        rng = random.Random(sub_seed)
        held = []
        for i in range(25):
            op = rng.choice(["borrow", "borrow", "release", "publish", "delete", "gc"])
            name = rng.choice(names)
            if op == "borrow":
                rec = yield from c.borrow_program_steps(host, name)
                if rec is not None:
                    held.append(rec)
            elif op == "release" and held:
                c.release(held.pop(rng.randrange(len(held))))
                yield "churn:released"
            elif op == "publish":
                yield from c.publish_program(name, 10.0 * sub_seed + i,
                                             drain_limit=200)
            elif op == "delete":
                c.master.delete(name)
                yield "churn:deleted"
            else:
                c.master.gc()
                yield "churn:gc"
            yield ("sleep", 1e-5)
        for rec in held:
            c.release(rec)
        yield "churn:drained"

    for i in range(4):
        c.add_program(f"h{i}", churn(f"h{i}", seed * 13 + i))
    c.run(max_steps=30000)
    c.master.gc()
    for e in c.catalog.entries:
        assert e.refcount.load() == 0
    return c


def scenario_delete_during_update_drain(seed):
    """A delete()+gc() issued while an update is draining must not
    double-free the old regions: gc() defers entries with an update in
    flight (I3 would catch the duplicate free on the very step it happens)."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("s", 1.0)

    def holder(host):
        rec = yield from c.borrow_program_steps(host, "s")
        assert rec is not None
        yield ("sleep", 0.01)       # keep the update draining for a while
        yield "holder:waking"
        c.release(rec)
        yield "holder:released"

    def deleter():
        yield ("sleep", 0.002)      # land mid-drain, after the tombstone
        c.master.delete("s", gc_now=False)
        yield "deleter:deleted"
        for _ in range(30):         # hammer gc across the drain window
            c.master.gc()
            yield "deleter:gc"
            yield ("sleep", 1e-3)

    c.add_program("h1", holder("h1"))
    c.add_program("owner", c.delayed(0.001, c.publish_program("s", 2.0)))
    c.add_program("del", deleter())
    c.run(max_steps=30000)
    assert "published:s:v1" in c.events     # the update completed safely
    assert c.catalog.find("s").refcount.load() == 0
    # the superseded delete's pending reclaim was cancelled at republish
    assert not c.master._pending_reclaim
    return c


def scenario_owner_crash_after_freeing_old(seed):
    """Owner dies after freeing the old regions but before republish: the
    tombstoned entry must not keep a dangling regions pointer — a follow-up
    delete+gc reclaims it WITHOUT freeing the same bytes twice (I3 would
    fire on the duplicate free at that exact step)."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("s", 1.0)
    c.fault_plan.kill_after("owner", "publish:freed_old")
    c.add_program("owner", c.publish_program("s", 2.0))
    c.run(max_steps=2000)
    assert "crashed:owner" in c.events
    entry = c.catalog.find("s")
    assert entry is not None and entry.regions is None, \
        "freed regions must not dangle off the entry"

    def janitor():
        c.master.delete("s", gc_now=False)
        yield "janitor:deleted"
        c.master.gc()
        yield "janitor:gc"

    c.add_program("janitor", janitor())
    c.run(max_steps=4000)
    assert c.catalog.find("s") is None, "entry should be reclaimed"
    assert not c.master._pending_reclaim
    return c


def scenario_lease_fallback(seed):
    """§3.6 RPC-lease fallback (no cross-host atomics): acquire/release from
    two hosts against owner churn; refcount accounting holds (I1 covers the
    fallback path too via track_borrow)."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("s", 1.0)
    leases = LeaseFallback(c.catalog)

    def lease_user(host, n):
        for i in range(n):
            rec = c.track_borrow(host, "s", leases.acquire("s"))
            yield "lease:acquire"
            if rec is not None:
                canonical = c.content["s"][rec.version].pages_matrix()
                view = c.pool.host_view(f"{host}:{i}")
                from repro.core import SnapshotReader
                reader = SnapshotReader(rec.borrow.regions, view, c.pool.rdma)
                reader.invalidate_cxl()
                page = int(reader.hot_page_indices()[0])
                assert np.array_equal(reader.read_page(page), canonical[page])
                c.release(rec)
                yield "lease:release"
            yield ("sleep", 1e-4)

    c.add_program("h1", lease_user("h1", 3))
    c.add_program("h2", lease_user("h2", 3))
    c.add_program("owner", c.delayed(2e-4, c.publish_program("s", 2.0)))
    c.run(max_steps=10000)
    assert leases.rpc_count >= 6
    assert c.catalog.find("s").refcount.load() == 0
    return c


def scenario_drift_recuration_feedback(seed):
    """Working-set drift closed-loop: borrowers demand-fault cold pages and
    record heat; the owner re-curates once the modeled benefit clears the
    break-even; a later restore of the re-curated version must be
    bit-identical and find the drifted pages promoted into the hot set.
    I1–I5 are checked after every step throughout (re-curation is an owner
    update, so borrow pinning / refcount accounting cover it unchanged)."""
    from repro.core import HeatRegistry

    c = SimCluster(n_hosts=3, seed=seed)
    c.publish("s", 1.0, cold_pages=4)
    registry = HeatRegistry(clock=c.clock, half_life_s=1e6)
    c.add_program("h1", c.drift_borrower_program("h1", "s", registry,
                                                 attempts=3, cold_reads=3))
    c.add_program("h2", c.drift_borrower_program("h2", "s", registry,
                                                 attempts=3, cold_reads=3))
    c.add_program("owner", c.delayed(1e-3, c.recurate_program(
        "s", registry, expected_restores=10000, min_restores=1)))
    c.add_program("h3", c.delayed(4e-3, c.restore_program("h3", "s")))
    c.run(max_steps=30000)
    assert any(e.startswith("recurated:s:v1") for e in c.events), c.events
    entry = c.catalog.find("s")
    assert entry.state.load() == STATE_PUBLISHED
    assert entry.version == 1
    # the drift pages (first 3 cold pages) were promoted into the hot region
    assert entry.regions.n_hot >= 3
    # the post-recuration restore completed and verified bit-identity
    assert any(r["name"] == "s" and r["version"] == 1 for r in c.restored)
    return c


def scenario_predicted_order_restore(seed):
    """Predicted-order installs stay bit-identical (§17): drift borrowers
    feed first-touch sequence telemetry, then a restore drains its cold
    extents in the fitted model's order instead of snapshot layout.  The
    bytes must verify against the canonical image, and a cold-start
    predicted restore of a telemetry-free snapshot must also verify (layout
    fallback).  I1–I5 are checked after every step throughout."""
    from repro.core import HeatRegistry

    c = SimCluster(n_hosts=3, seed=seed)
    c.publish("s", 1.0, cold_pages=6)
    c.publish("fresh", 2.0, cold_pages=4)
    registry = HeatRegistry(clock=c.clock, half_life_s=1e6)
    c.add_program("h1", c.drift_borrower_program("h1", "s", registry,
                                                 attempts=2, cold_reads=4))
    c.add_program("h2", c.delayed(2e-3, c.predicted_restore_program(
        "h2", "s", registry)))
    # no telemetry for "fresh": the policy must fall back to layout order
    c.add_program("h3", c.predicted_restore_program("h3", "fresh", registry))
    c.run(max_steps=30000)
    assert any(e.startswith("predicted_restore:h2:s:model")
               for e in c.events), c.events
    assert any(e.startswith("predicted_restore:h3:fresh:layout")
               for e in c.events), c.events
    assert any(r["name"] == "s" and r.get("predicted_order")
               for r in c.restored)
    return c


def scenario_recuration_owner_crash_mid_republish(seed):
    """Host crash mid-re-curation: the recurator dies between rebuilding
    the data regions and republishing the catalog entry.  Borrowers fall
    back to cold starts (never stale bytes), invariants hold throughout,
    and a fresh publish of the same name recovers the entry."""
    from repro.core import HeatRegistry

    c = SimCluster(n_hosts=2, seed=seed)
    regions0 = c.publish("s", 1.0)
    registry = HeatRegistry(clock=c.clock, half_life_s=1e6)
    hm = registry.map_for("s", 0, regions0.total_pages)
    hm.record(TouchEvent(pages=np.arange(regions0.total_pages),
                         kind="demand_fault"))
    hm.record(TouchEvent(pages=np.arange(regions0.total_pages),
                         kind="demand_fault"))
    hm.note_restore()
    hm.note_restore()
    c.add_program("recurator", c.recurate_program("s", registry, force=True,
                                                  expected_restores=10000))
    c.fault_plan.kill_after("recurator", "recurate:rebuilt")
    c.add_program("h1", c.borrower_program("h1", "s", attempts=3))
    c.run(max_steps=30000)
    assert "crashed:recurator" in c.events
    entry = c.catalog.find("s")
    assert entry is not None and entry.state.load() == STATE_TOMBSTONE
    assert entry.regions is None, "crashed mid-republish: no regions visible"
    # recovery: a fresh publish through the production path heals the entry
    rr = c.publish("s", 2.0)
    assert rr.version == 2
    c.add_program("h2", c.borrower_program("h2", "s", attempts=2))
    c.run(max_steps=60000)
    entry = c.catalog.find("s")
    assert entry.state.load() == STATE_PUBLISHED and entry.version == 2
    assert any(e.startswith("borrower_done:h2") for e in c.events)
    return c


def scenario_dedup_owner_crash_mid_republish(seed):
    """ISSUE 5: owner crash mid-republish of a DEDUP snapshot whose pages
    are shared with a live sibling.  'base' and 'var' are bit-identical
    publishes, so every stored page carries refcount 2.  The owner rebuilds
    'var' with new content and dies between the build and the catalog
    republish: the rebuilt pages leak (their references stay counted — I6
    is checked after every step), the shared pages survive via 'base', and
    a borrower of 'base' keeps reading correct bytes throughout.  A fresh
    publish then heals the entry."""
    c = SimCluster(n_hosts=2, seed=seed)
    c.publish("base", 1.0, dedup=True, distinct_hot=True,
              hot_pages=4, cold_pages=4)
    c.publish("var", 1.0, dedup=True, distinct_hot=True,
              hot_pages=4, cold_pages=4)
    store = c.pool.dedup_cxl
    assert store.logical_pages() == 2 * store.unique_pages(), \
        "setup: every hot page should be shared exactly twice"
    c.fault_plan.kill_after("owner", "publish:rebuilt")
    c.add_program("owner", c.publish_program("var", 2.0, dedup=True,
                                             distinct_hot=True,
                                             hot_pages=4, cold_pages=4))
    c.add_program("h1", c.borrower_program("h1", "base", attempts=3))
    c.run(max_steps=30000)
    assert "crashed:owner" in c.events
    assert "borrower_done:h1:3/3" in c.events, \
        "borrows of the sharing sibling must keep succeeding"
    entry = c.catalog.find("var")
    assert entry is not None and entry.state.load() == STATE_TOMBSTONE
    assert entry.regions is None, "crashed mid-republish: no regions visible"
    # the rebuilt-but-never-published regions leaked — still tracked
    assert len(c.pending_regions) == 1 and c.pending_regions[0].dedup
    # the shared pages survived var's free: base still resolves bit-exactly
    c.add_program("h2", c.restore_program("h2", "base"))
    c.run(max_steps=60000)
    assert any(r["name"] == "base" for r in c.restored)
    # recovery: a fresh publish of the crashed name through the production
    # path (version numbering continues past the crashed update's claim)
    rr = c.publish("var", 3.0, dedup=True, distinct_hot=True)
    assert rr.version == 2 and rr.dedup
    c.add_program("h3", c.borrower_program("h3", "var", attempts=2))
    c.run(max_steps=90000)
    assert any(e.startswith("borrower_done:h3") for e in c.events)
    return c


def scenario_dedup_eviction_shared_with_live_borrower(seed):
    """ISSUE 5: the capacity clock demotes a dedup snapshot that SHARES
    pages with a snapshot a live borrower holds.  'shared1' (6 hot pages)
    and 'shared2' (4 hot pages) share a 4-page prefix; a borrower pins
    'shared2' while an over-budget publish sweeps the clock.  The sweep
    must demote 'shared1' (it has exclusive bytes), must NOT touch the
    borrowed 'shared2' (refcount pin), and the shared prefix must survive
    the demotion — the borrower and a later restore read exact bytes, I6
    holding at every step."""
    c = SimCluster(n_hosts=2, seed=seed, cxl_budget=14 * 4096)
    c.publish("shared1", 1.0, dedup=True, distinct_hot=True,
              hot_pages=6, cold_pages=2)
    c.publish("shared2", 1.0, dedup=True, distinct_hot=True,
              hot_pages=4, cold_pages=2)
    assert c.pool.dedup_cxl.unique_pages() == 6, "prefix must be shared"

    def holder(host):
        rec = yield from c.borrow_program_steps(host, "shared2")
        assert rec is not None
        yield ("sleep", 0.02)           # hold across the capacity sweep
        view = c.pool.host_view(host)
        reader = SnapshotReader(rec.borrow.regions, view, c.pool.rdma)
        reader.invalidate_cxl()
        canonical = c.content["shared2"][rec.version].pages_matrix()
        for p in reader.hot_page_indices():
            assert np.array_equal(reader.read_page(int(p)), canonical[int(p)]), \
                f"[seed={seed}] borrower read wrong bytes post-demotion"
            yield "holder:read"
        c.release(rec)
        yield "holder:released"

    c.add_program("h1", holder("h1"))
    c.add_program("publisher", c.delayed(0.005, c.publish_program(
        "big", 5.0, dedup=True, distinct_hot=True, hot_pages=8, cold_pages=2)))
    c.run(max_steps=60000)
    stats = c.master.capacity.budget.report()
    assert stats["demotions"] >= 1, f"clock never demoted: {stats}"
    assert "published:big:v0" in c.events
    # the borrowed sibling was never evicted and still restores bit-exactly
    entry = c.catalog.find("shared2")
    assert entry.state.load() == STATE_PUBLISHED
    assert entry.regions.n_hot == 4, "borrowed snapshot must keep its hot set"
    c.add_program("h2", c.restore_program("h2", "shared2"))
    c.run(max_steps=90000)
    assert any(r["name"] == "shared2" for r in c.restored)
    # shared1 was demoted all-cold, its exclusive pages left the CXL store;
    # the shared prefix is still resident for shared2
    s1 = c.catalog.find("shared1")
    assert s1.regions.n_hot == 0, "victim should have been demoted to all-cold"
    assert c.pool.dedup_cxl.unique_pages() == 4
    return c


# -- PR 8 chaos scenarios: production fault seam under the simulator --------

def scenario_rdma_flap_under_fanout_burst(seed):
    """A flapping RNIC during a 3-restore burst of the same snapshot: the
    core FaultInjector (production seam, not the FlakyTier proxy) times out
    the first 4 RDMA extent reads; every restore retries through and the
    restored memory is bit-identical (checked inside restore_program) with
    I1–I6 held at every step."""
    from repro.core import FaultInjector

    c = SimCluster(n_hosts=3, seed=seed)
    c.publish("snap", 4.0, hot_pages=4, cold_pages=8, zero_pages=2)
    inj = FaultInjector(clock=c.clock, seed=seed).fail_reads("rdma", 4)
    c.pool.attach_fault_injector(inj)
    for i, host in enumerate(("h1", "h2", "h3")):
        c.add_program(f"r{i}", c.restore_program(host, "snap"))
    c.run(max_steps=30000)
    assert len(c.restored) == 3
    assert sum(r["retries"] for r in c.restored) == 4
    assert inj.stats["injected_timeouts"] == 4
    assert c.catalog.find("snap").refcount.load() == 0
    return c


def scenario_cxl_poison_during_shared_restore(seed):
    """Per-page CXL poison on a SHARED dedup store page while two variants
    restore concurrently with checksum-verifying fused scatters: the
    poisoned install is detected, the store offset is quarantined while it
    keeps failing, then repaired from the (clean) home tier and
    re-materialized back into circulation — both restores end bit-identical
    and I6 (dedup refcount conservation) holds at every step."""
    from repro.core import FaultInjector
    from repro.kernels.snapshot_fuse import FusedScatter, make_fused_publish_fn

    c = SimCluster(n_hosts=2, seed=seed)
    pf = make_fused_publish_fn(use_pallas=False)
    c.publish("va", 2.0, dedup=True, distinct_hot=True, publish_fn=pf,
              hot_pages=4, cold_pages=4)
    c.publish("vb", 2.0, dedup=True, distinct_hot=True, publish_fn=pf,
              hot_pages=4, cold_pages=4)
    store = c.pool.dedup_cxl
    # poison one shared hot page's store offset: the install read, then the
    # first TWO repair re-reads (forcing a quarantine), then clean
    off = min(store._hash_of)
    inj = FaultInjector(clock=c.clock, seed=seed).poison_reads(
        "cxl", 3, lo=off, hi=off + 4096)
    c.pool.attach_fault_injector(inj)
    sf = FusedScatter(use_pallas=False)
    c.add_program("r1", c.restore_program("h1", "va", scatter_fn=sf))
    c.add_program("r2", c.restore_program("h2", "vb", scatter_fn=sf))
    c.run(max_steps=30000)
    assert len(c.restored) == 2         # bit-identity asserted in-program
    assert inj.stats["injected_poison"] == 3
    assert sum(r["repairs"] for r in c.restored) >= 1
    assert store.stats["quarantined"] >= 1
    assert store.stats["rematerialized"] >= 1
    assert not store.quarantined_offsets(), "repaired offset back in service"
    return c


def scenario_brownout_during_recuration(seed):
    """A CXL host-link brownout window opens while the owner re-curates and
    a host restores: the owner-side re-curation (pool-fabric reads, never
    browned out) completes normally, while the restore's breaker degrades
    it to the RDMA-only path instead of failing — restored memory is still
    bit-identical and I1–I5 hold throughout."""
    from repro.core import FaultInjector, HeatRegistry

    c = SimCluster(n_hosts=3, seed=seed)
    c.publish("s", 1.0, cold_pages=4)
    registry = HeatRegistry(clock=c.clock, half_life_s=1e6)
    c.add_program("h1", c.drift_borrower_program("h1", "s", registry,
                                                 attempts=3, cold_reads=3))
    c.add_program("owner", c.delayed(1e-3, c.recurate_program(
        "s", registry, expected_restores=10000, min_restores=1)))
    c.add_program("h3", c.delayed(4e-3, c.restore_program("h3", "s")))
    # the brownout window opens just before the delayed restore begins and
    # outlasts the run: every host-link CXL access inside it fails hard
    inj = FaultInjector(clock=c.clock, seed=seed).brownout(
        "cxl", start_s=3.5e-3, duration_s=10.0)
    c.pool.attach_fault_injector(inj)
    c.run(max_steps=30000)
    # owner-side re-curation was untouched by the host-link brownout
    assert any(e.startswith("recurated:s:v1") for e in c.events), c.events
    # the restore completed degraded (RDMA-only), not failed
    assert any(e.startswith("degraded_restore:h3:s") for e in c.events), c.events
    degraded = [r for r in c.restored if r["host"] == "h3"]
    assert degraded and degraded[0]["degraded"]
    assert inj.stats["brownout_rejections"] >= 1
    return c


SCENARIOS = {
    "steady_borrow_release": scenario_steady_borrow_release,
    "rdma_flap_under_fanout_burst": scenario_rdma_flap_under_fanout_burst,
    "cxl_poison_during_shared_restore":
        scenario_cxl_poison_during_shared_restore,
    "brownout_during_recuration": scenario_brownout_during_recuration,
    "dedup_owner_crash_mid_republish": scenario_dedup_owner_crash_mid_republish,
    "dedup_eviction_shared_with_live_borrower":
        scenario_dedup_eviction_shared_with_live_borrower,
    "drift_recuration_feedback": scenario_drift_recuration_feedback,
    "predicted_order_restore": scenario_predicted_order_restore,
    "recuration_owner_crash_mid_republish":
        scenario_recuration_owner_crash_mid_republish,
    "owner_update_vs_borrowers": scenario_owner_update_vs_borrowers,
    "doomed_borrow_interleaving": scenario_doomed_borrow_interleaving,
    "livelock_when_fix_reverted": scenario_livelock_when_fix_reverted,
    "host_crash_mid_borrow": scenario_host_crash_mid_borrow,
    "host_crash_holding_borrow": scenario_host_crash_holding_borrow,
    "owner_crash_between_tombstone_and_republish":
        scenario_owner_crash_between_tombstone_and_republish,
    "master_failover_basic": scenario_master_failover_basic,
    "master_failover_races_8_hosts": scenario_master_failover_races_8_hosts,
    "lease_expiry_during_gc": scenario_lease_expiry_during_gc,
    "rdma_extent_timeout_retry": scenario_rdma_extent_timeout_retry,
    "rdma_timeout_exhausts_retries": scenario_rdma_timeout_exhausts_retries,
    "batched_vs_perpage_restore_under_updates":
        scenario_batched_vs_perpage_restore_under_updates,
    "eviction_under_borrows": scenario_eviction_under_borrows,
    "catalog_churn": scenario_catalog_churn,
    "delete_during_update_drain": scenario_delete_during_update_drain,
    "owner_crash_after_freeing_old": scenario_owner_crash_after_freeing_old,
    "lease_fallback": scenario_lease_fallback,
}


def test_scenario_matrix_is_large_enough():
    assert len(SCENARIOS) >= 12


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario(name):
    SCENARIOS[name](SEED + 17 * (sorted(SCENARIOS).index(name) + 1))


@pytest.mark.parametrize("offset", [0, 1, 2])
def test_drift_recuration_multi_seed(offset):
    """ISSUE 4 acceptance: the drift + re-curation scenario (and its
    crash-mid-republish variant) pass the I1–I5 invariant checks across
    >= 3 distinct seeds."""
    scenario_drift_recuration_feedback(SEED + 101 * offset + 7)
    scenario_recuration_owner_crash_mid_republish(SEED + 101 * offset + 8)


@pytest.mark.parametrize("offset", [0, 1, 2])
def test_dedup_scenarios_multi_seed(offset):
    """ISSUE 5 acceptance: the dedup crash-mid-republish and shared-page
    eviction scenarios pass the I1–I6 invariant checks (I6 = refcount
    conservation, checked after every sim step) across >= 3 distinct
    seeds."""
    scenario_dedup_owner_crash_mid_republish(SEED + 131 * offset + 11)
    scenario_dedup_eviction_shared_with_live_borrower(SEED + 131 * offset + 12)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_is_deterministic(name):
    """Same seed ⇒ identical interleaving (trace), events, and invariants."""
    seed = SEED + 1000 + sorted(SCENARIOS).index(name)
    a = SCENARIOS[name](seed)
    b = SCENARIOS[name](seed)
    assert a.trace == b.trace, f"[seed={seed}] {name}: interleaving not reproducible"
    assert a.events == b.events


def test_different_seeds_change_the_interleaving():
    """Sanity: the scheduler actually randomizes across seeds."""
    traces = set()
    for s in range(4):
        c = SimCluster(n_hosts=3, seed=SEED + s)
        c.publish("snap", 1.0)
        c.add_program("owner", c.publish_program("snap", 2.0))
        for h in ("h1", "h2", "h3"):
            c.add_program(h, c.borrower_program(h, "snap", attempts=3))
        c.run()
        traces.add(tuple(c.trace))
    assert len(traces) > 1
